"""Core library: the paper's contribution as composable modules.

Dissection (faithful methodology, device-model backend):
  ``simulator``  — cycle-level memory-hierarchy device model
  ``pchase``     — pointer-chase geometry inference (Mei & Chu / paper ch.3)
  ``regbank``    — register bank conflicts + reuse caches + Table 1.1
  ``regremap``   — the Ch.1 conflict-free remapping, as an algorithm
  ``scheduler``  — warp-to-processing-block mapping model (Table 2.1)
  ``tensorcore`` — HMMA fragment maps + emulation (Figs 4.2-4.7)
  ``latency``    — instruction latency measurement method (Table 4.1)
  ``atomics``    — contention models (Table 4.2 / Fig 4.1)
  ``isa``        — encoding facts + control-word codec (ch.2 + appendix)
  ``dissect``    — full-device orchestration (Table 3.1 reproduction)

TPU transfer (the production framework's brain):
  ``hwmodel``      — GPU specs (ground truth) + TPU v5e target constants
  ``hlo_analysis`` — compiled-HLO dissection (collective bytes, op census)
  ``roofline``     — three-term roofline engine
  ``interconnect`` — alpha-beta ICI/NVLink models
  ``collectives``  — mesh collective microbenchmarks
  ``autotune``     — microbench-informed BlockSpec + sharding selection

Keep this package import-light: jax-importing modules (``collectives``,
``latency`` harness) are imported lazily by their users.
"""

from repro.core import (atomics, autotune, dissect, hlo_analysis, hwmodel,
                        interconnect, isa, pchase, regbank, regremap,
                        roofline, scheduler, simulator, tensorcore)

__all__ = [
    "atomics", "autotune", "collectives", "dissect", "hlo_analysis",
    "hwmodel", "interconnect", "isa", "latency", "pchase", "regbank",
    "regremap", "roofline", "scheduler", "simulator", "tensorcore",
]
