"""Instruction latency dissection — paper §4.1, Table 4.1.

Two backends:

* **Model**: a scoreboard pipeline over the published latency tables
  (``hwmodel.VOLTA_INSTR_LATENCY`` / ``PASCAL_INSTR_LATENCY``). The paper's
  measurement method — shrink the control-word stall count of instruction A
  until its dependent consumer B reads a stale value — is reproduced as
  ``measure_fixed_latency``: the smallest stall preserving correctness is
  the latency.

* **Wall-clock harness**: dependent-chain timing of real JAX ops on the host
  CPU (``measure_op_chain``). On a TPU deployment the same harness yields
  per-op dependent-issue latencies; here it demonstrates the methodology and
  feeds the CPU rows of the benchmark CSV.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ----------------------------------------------------------------------------
# Scoreboard model + control-word measurement method
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelInstr:
    op: str
    dst: int
    srcs: Tuple[int, ...]
    stall: int = 0              # control-word stall cycles (paper §2.1)


class Scoreboard:
    """In-order issue with control-word stalls, per the paper's description:
    fixed-latency instructions are *statically* scheduled — the hardware does
    not interlock; a too-small stall lets a consumer read a stale value."""

    def __init__(self, latencies: Dict[str, int]):
        self.latencies = latencies

    def run(self, instrs: Sequence[ModelInstr]) -> Tuple[int, bool]:
        """Returns (total_cycles, correct). ``correct`` is False if any
        consumer issued before its producer's result was ready."""
        ready: Dict[int, int] = {}
        t = 0
        correct = True
        for ins in instrs:
            for s in ins.srcs:
                if ready.get(s, 0) > t:
                    correct = False
            lat = self.latencies[ins.op]
            ready[ins.dst] = t + lat
            t += 1 + ins.stall
        return t, correct


def measure_fixed_latency(board: Scoreboard, op: str,
                          max_stall: int = 32) -> int:
    """The paper's §4.1 method: decrease A's stall cycles until B consumes a
    stale value; the smallest correct stall + 1 issue cycle is A's latency."""
    for stall in range(max_stall, -1, -1):
        prog = [ModelInstr(op, dst=1, srcs=(0,), stall=stall),
                ModelInstr(op, dst=2, srcs=(1,), stall=0)]
        _, ok = board.run(prog)
        if not ok:
            return stall + 2            # failing stall +1 back, +1 issue cycle
    return 1


def dependent_chain_cycles(board: Scoreboard, op: str, n: int) -> int:
    """Cycles to retire an n-deep dependent chain with correct scheduling."""
    lat = board.latencies[op]
    prog = [ModelInstr(op, dst=i + 1, srcs=(i,), stall=lat - 1)
            for i in range(n)]
    cycles, ok = board.run(prog)
    assert ok
    return cycles


# ----------------------------------------------------------------------------
# Wall-clock dependent-chain harness (real measurement on the host backend)
# ----------------------------------------------------------------------------

def measure_op_chain(op: Callable, x0, n: int = 1024,
                     repeats: int = 5) -> float:
    """Nanoseconds per dependent application of ``op`` on this host.

    ``op`` must map an array to a same-shaped array; the chain forces
    serialization the same way the paper's SASS chains do."""
    import jax

    def chain(x):
        return jax.lax.fori_loop(0, n, lambda i, v: op(v), x)

    fn = jax.jit(chain)
    y = fn(x0)
    jax.block_until_ready(y)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter_ns()
        jax.block_until_ready(fn(x0))
        best = min(best, (time.perf_counter_ns() - t0) / n)
    return best


def standard_op_suite() -> Dict[str, Callable]:
    import jax.numpy as jnp

    return {
        "add": lambda x: x + 1.0,
        "mul": lambda x: x * 1.0000001,
        "fma": lambda x: x * 1.0000001 + 1e-9,
        "exp": lambda x: jnp.exp(x) * 1e-9,
        "rsqrt": lambda x: 1.0 / jnp.sqrt(jnp.abs(x) + 1.0),
        "tanh": lambda x: jnp.tanh(x),
    }
