"""Cycle-level device model of a GPU memory hierarchy.

This is the *device under test* for the dissection engine. The paper probes a
real Volta with pointer-chase microbenchmarks; this container has no GPU (nor
a TPU), so the probes run against this model instead. The model is configured
from published specs (``hwmodel.GPUSpec``) and the dissector must recover the
configuration *without looking at it* — only through ``access()`` timings,
exactly like the paper's p-chase kernels.

Modeled behaviours (paper sections in parens):

* set-associative caches, LRU / non-LRU("prio") replacement (§3.1, Table 3.3)
* virtual-indexed L1, physical-indexed L2 behind TLBs (§3.8)
* two-level TLBs with page-entry granularity (§3.8, Fig 3.12)
* latency classes 28/193/375/1029 (Fig 3.2)
* shared-memory bank conflicts (§3.6, Fig 3.9)
* constant-cache broadcast vs serialized divergence (§3.4, Fig 3.7)

The model is deliberately *not* a performance model of a TPU — it is the
faithful-methodology backend. TPU rooflines live in ``core/roofline.py``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import hwmodel


class SetAssocCache:
    """A set-associative cache with pluggable replacement policy.

    Policies:
      * ``lru``    — classic least-recently-used.
      * ``prio``   — Volta-like preservation-priority model (§3.1.2): each set
                     reserves ``reserved_ways`` low-priority slots that behave
                     as a bypass once the protected region is full. This
                     reproduces the paper's Table 3.3 observation that the
                     detectable L1 size falls ~7 KiB short of nominal, and its
                     observation that large-array scans survive sparse
                     thrashing better than under LRU.
      * ``random`` — seeded pseudo-random victim (used for constant caches).
    """

    def __init__(self, size: int, line: int, sets: Optional[int] = None,
                 ways: Optional[int] = None, policy: str = "lru",
                 reserved_ways: int = 0, seed: int = 0):
        lines = size // line
        if sets is None and ways is None:
            sets, ways = 1, lines          # fully associative
        elif sets is None:
            sets = lines // ways
        elif ways is None:
            ways = lines // sets
        assert sets * ways == lines, (size, line, sets, ways)
        self.size, self.line, self.sets, self.ways = size, line, sets, ways
        self.policy = policy
        self.reserved_ways = reserved_ways if policy == "prio" else 0
        self.rng = np.random.RandomState(seed)
        self.flush()

    def reset_stats(self):
        self.hits = 0
        self.misses = 0

    def flush(self):
        # Per-set state: tag -> way map plus per-way LRU stamps.
        self._map = [dict() for _ in range(self.sets)]
        self._stamp = np.zeros((self.sets, self.ways), dtype=np.int64)
        self._waytag = np.full((self.sets, self.ways), -1, dtype=np.int64)
        self._free = [list(range(self.ways - self.reserved_ways - 1, -1, -1))
                      for _ in range(self.sets)]
        self.clock = 0
        self.reset_stats()

    def access(self, addr: int) -> bool:
        """Access one byte address; returns True on hit."""
        line_addr = addr // self.line
        s = line_addr % self.sets
        tag = line_addr // self.sets
        self.clock += 1
        w = self._map[s].get(tag)
        if w is not None:
            self.hits += 1
            self._stamp[s, w] = self.clock
            return True
        self.misses += 1
        self._fill(s, tag)
        return False

    def _fill(self, s: int, tag: int):
        if self._free[s]:
            v = self._free[s].pop()
        elif self.policy == "prio":
            # Protected region full: low-priority slots act as a transient
            # bypass — the line is not retained (lowest preservation
            # priority; replaced first).
            return
        elif self.policy == "random":
            v = int(self.rng.randint(self.ways - self.reserved_ways))
            del self._map[s][int(self._waytag[s, v])]
        else:  # lru
            v = int(np.argmin(self._stamp[s, :self.ways - self.reserved_ways]))
            del self._map[s][int(self._waytag[s, v])]
        self._map[s][tag] = v
        self._waytag[s, v] = tag
        self._stamp[s, v] = self.clock


class TLB:
    """Fully-associative LRU TLB over fixed-size page entries."""

    def __init__(self, coverage: int, page_entry: int):
        self.page = page_entry
        self.entries = max(1, coverage // page_entry)
        self.flush()

    def flush(self):
        self._map = {}                      # vpn -> slot
        self._slottag = np.full(self.entries, -1, dtype=np.int64)
        self._stamp = np.zeros(self.entries, dtype=np.int64)
        self._free = list(range(self.entries - 1, -1, -1))
        self.hits = self.misses = self.clock = 0

    def access(self, addr: int) -> bool:
        vpn = addr // self.page
        self.clock += 1
        w = self._map.get(vpn)
        if w is not None:
            self.hits += 1
            self._stamp[w] = self.clock
            return True
        self.misses += 1
        if self._free:
            v = self._free.pop()
        else:
            v = int(np.argmin(self._stamp))
            del self._map[int(self._slottag[v])]
        self._map[vpn] = v
        self._slottag[v] = vpn
        self._stamp[v] = self.clock
        return False


@dataclasses.dataclass
class LatencyConfig:
    """Latency classes of Fig 3.2 (cycles)."""

    l1_hit: int = 28
    l2_hit: int = 193
    dram: int = 375          # L2 miss, TLB hit
    l2_tlb_extra: int = 40   # extra on L1-TLB miss / L2-TLB hit
    walk_extra: int = 654    # extra on full TLB miss (1029 - 375)


class MemoryHierarchy:
    """L1 (virtual-indexed) -> TLBs -> L2 (physical-indexed) -> DRAM."""

    def __init__(self, l1: SetAssocCache, l2: SetAssocCache,
                 l1_tlb: TLB, l2_tlb: TLB, lat: LatencyConfig,
                 l1_enabled: bool = True, caches_enabled: bool = True):
        self.l1, self.l2 = l1, l2
        self.l1_tlb, self.l2_tlb = l1_tlb, l2_tlb
        self.lat = lat
        self.l1_enabled = l1_enabled
        # caches_enabled=False models the paper's TLB sweeps (Fig 3.12):
        # page-entry strides alias into a handful of physical L2 sets, so in
        # steady state every access is an L2 miss and latency isolates the
        # TLB hierarchy on top of the DRAM latency.
        self.caches_enabled = caches_enabled
        self.tlb_accesses = 0

    def flush(self):
        for c in (self.l1, self.l2, self.l1_tlb, self.l2_tlb):
            c.flush()
        self.tlb_accesses = 0

    def access(self, addr: int) -> int:
        """Load one address; returns latency in cycles."""
        if self.caches_enabled and self.l1_enabled and self.l1.access(addr):
            return self.lat.l1_hit                      # virtual-indexed: no TLB
        # L1 miss (or disabled): physical L2 access goes through the TLBs.
        self.tlb_accesses += 1
        extra = 0
        if not self.l1_tlb.access(addr):
            if self.l2_tlb.access(addr):
                extra = self.lat.l2_tlb_extra
            else:
                extra = self.lat.walk_extra
        if self.caches_enabled and self.l2.access(addr):
            return self.lat.l2_hit + extra
        return self.lat.dram + extra

    def scan(self, addrs: np.ndarray) -> np.ndarray:
        """Access a sequence of byte addresses, returning per-access latency."""
        out = np.empty(len(addrs), dtype=np.int64)
        for i, a in enumerate(addrs):
            out[i] = self.access(int(a))
        return out

    def chase(self, chain: np.ndarray, start: int = 0, steps: int = 0,
              flush: bool = False) -> np.ndarray:
        """Pointer-chase through ``chain``: load the element at the current
        address; the loaded value is the next address. Records the latency of
        every dependent load. This is the model-side equivalent of the
        fine-grained p-chase kernel of Mei & Chu used throughout ch. 3."""
        if flush:
            self.flush()
        steps = steps or len(chain)
        out = np.empty(steps, dtype=np.int64)
        pos = start
        for k in range(steps):
            out[k] = self.access(pos)
            pos = int(chain[pos // 8])
        return out


def volta_reserved_ways(spec: hwmodel.GPUSpec) -> int:
    """Volta's ~7 KiB undetectable L1 region (Table 3.3): 7 KiB of lines
    spread across the sets."""
    if spec.l1d.policy != "prio":
        return 0
    lines_short = (7 * 1024) // spec.l1d.line
    return lines_short // (spec.l1d.sets or 1)


def build_hierarchy(spec: hwmodel.GPUSpec,
                    l1_size_override: Optional[int] = None,
                    l1_enabled: bool = True,
                    caches_enabled: bool = True) -> MemoryHierarchy:
    """Build the device model for one GPU column of Table 3.1."""
    l1_size = l1_size_override or spec.l1d.size
    l1 = SetAssocCache(l1_size, spec.l1d.line, sets=spec.l1d.sets,
                       policy=spec.l1d.policy,
                       reserved_ways=volta_reserved_ways(spec))
    l2 = SetAssocCache(spec.l2d.size, spec.l2d.line, ways=spec.l2d.ways or 16,
                       policy="lru")
    lat = LatencyConfig(
        l1_hit=spec.l1d.hit_latency or 28,
        l2_hit=spec.l2d.hit_latency or 193,
        dram=spec.global_latency_l2_miss or 375,
        walk_extra=(spec.global_latency_cold or 1029)
                   - (spec.global_latency_l2_miss or 375),
    )
    return MemoryHierarchy(
        l1, l2,
        TLB(spec.l1_tlb.coverage, spec.l1_tlb.page_entry),
        TLB(spec.l2_tlb.coverage, spec.l2_tlb.page_entry),
        lat, l1_enabled=l1_enabled, caches_enabled=caches_enabled)


# ----------------------------------------------------------------------------
# Shared memory bank model (§3.6, Fig 3.9).
# ----------------------------------------------------------------------------

def smem_conflict_degree(spec: hwmodel.GPUSpec, stride_words: int,
                         warp: int = 32, word: int = 4) -> int:
    """Max number of threads hitting the same bank for a strided warp access."""
    banks = spec.smem_banks
    width = spec.smem_bank_width
    counts = {}
    for t in range(warp):
        byte = t * stride_words * word
        bank = (byte // width) % banks
        counts.setdefault(bank, set()).add(byte // width)
    # Accesses to the same bank but the same word broadcast; distinct words
    # within a bank serialize.
    return max(len(words) for words in counts.values())


def smem_latency(spec: hwmodel.GPUSpec, stride_words: int) -> float:
    """Average shared-memory load latency for a warp with given stride.

    Kepler (8-byte banks) serves two 4-byte words per bank per cycle, so a
    2-way conflict costs nothing (Fig 3.9)."""
    degree = smem_conflict_degree(spec, stride_words)
    per_cycle = 2 if spec.smem_bank_width >= 8 else 1
    serial = -(-degree // per_cycle)   # ceil
    return spec.smem_no_conflict_latency + (serial - 1) * 2.0 * per_cycle


# ----------------------------------------------------------------------------
# Constant cache broadcast model (§3.4, Fig 3.7).
# ----------------------------------------------------------------------------

def constant_latency(spec: hwmodel.GPUSpec, level: str,
                     distinct_addrs: int) -> float:
    """Latency of a warp constant load touching ``distinct_addrs`` distinct
    locations: same-address accesses broadcast, diverging accesses
    serialize."""
    base = {"l1": spec.l1c.hit_latency or 27,
            "l1.5": spec.l15c.hit_latency or 89,
            "l2": 245}[level]
    return base * distinct_addrs


def make_chain(n_bytes: int, stride: int, start: int = 0) -> np.ndarray:
    """Build a circular pointer chain over [start, start+n_bytes) with the
    given byte stride. Element i holds the byte address of element i+1.
    Addresses are 8-byte aligned slots (chain is indexed by addr//8)."""
    n = max(1, n_bytes // stride)
    idx = (start + np.arange(n) * stride) // 8
    chain = np.zeros(int(idx.max()) + 1, dtype=np.int64)
    nxt = np.roll(idx, -1) * 8
    chain[idx] = nxt
    return chain
