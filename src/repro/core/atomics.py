"""Atomic-operation latency/throughput models — paper §4.2, Table 4.2, Fig 4.1.

Shared-memory atomics serialize under intra-warp contention; the paper's
Table 4.2 shows near-linear growth on Volta/Pascal/Maxwell (hardware atomics)
and explosive growth on Kepler (emulated via lock/unlock). We fit the
published table with a base + slope serialization model and report residuals;
the four Fig 4.1 throughput scenarios are modeled from the same serialization
cost plus L2-line parallelism.

TPU note: the TPU programming model exposes no atomics (reductions happen in
the MXU/VPU or via collectives), so this chapter is model-only. The
framework-level analogue — contended accumulation — is handled by
deterministic reduction collectives (see ``dist/``).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core import hwmodel


def fit_serialization(table: Dict[int, Tuple[int, int]], which: int
                      ) -> Tuple[float, float]:
    """Least-squares fit latency(R) = base + slope * R over the published
    contention table. ``which``: 0 = shared, 1 = global."""
    r = np.array(sorted(table))
    y = np.array([table[k][which] for k in sorted(table)], dtype=float)
    a = np.vstack([np.ones_like(r, dtype=float), r]).T
    (base, slope), *_ = np.linalg.lstsq(a, y, rcond=None)
    return float(base), float(slope)


def modeled_latency(spec: hwmodel.GPUSpec, contention: int,
                    space: str = "shared") -> float:
    """Serialization model: base latency + per-extra-thread cost."""
    table = spec.atomic_latency
    if table is None:
        raise ValueError(f"no atomic data for {spec.name}")
    which = 0 if space == "shared" else 1
    base, slope = fit_serialization(table, which)
    return base + slope * contention


def model_residuals(spec: hwmodel.GPUSpec, space: str = "shared"
                    ) -> Dict[int, Tuple[float, float]]:
    """(published, modeled) latency per contention level."""
    which = 0 if space == "shared" else 1
    out = {}
    for r, vals in sorted(spec.atomic_latency.items()):
        out[r] = (float(vals[which]), modeled_latency(spec, r, space))
    return out


def throughput_scenario(spec: hwmodel.GPUSpec, scenario: int,
                        blocks: int = 80, contention: int = 32) -> float:
    """Modeled atomicAdd throughput (ops/cycle, whole chip) for the four
    Fig 4.1 scenarios.

    1: one block, R threads contend on one address, rest spread over a line
    2: like 1 but each group on its own L2 line
    3: many blocks, all threads on one address (global serialization)
    4: many blocks, block-private addresses (no cross-block contention)
    """
    base, slope = fit_serialization(spec.atomic_latency, 1)
    serial_cost = base + slope * contention
    per_block_rate = 1024.0 / serial_cost
    if scenario == 1:
        return per_block_rate
    if scenario == 2:
        return per_block_rate * 2.0        # line-level parallelism recovered
    if scenario == 3:
        return 1024.0 * blocks / (serial_cost * blocks)   # one hot address
    if scenario == 4:
        return per_block_rate * blocks     # scales with SM count
    raise ValueError(scenario)
