"""Three-term roofline engine (the §Roofline deliverable).

For every compiled (architecture x shape x mesh) cell, derive:

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

FLOPs and bytes come from ``compiled.cost_analysis()``; collective bytes
come from parsing the HLO text (``core/hlo_analysis``). Hardware constants
are the mandated v5e-class numbers: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Important accounting notes (documented in EXPERIMENTS.md §Roofline):

* ``cost_analysis`` on the CPU backend reports per-*program* totals of the
  SPMD-partitioned module, i.e. already per-device quantities; we therefore
  do NOT divide by chip count again. We cross-check with MODEL_FLOPS/chips.
* Layer scans (``lax.while``) report one trip's cost; we scale flops/bytes
  by detected trip counts when XLA annotates them (``known_trip_count``).
* The collective term assumes ring scheduling on the axis links; it is the
  serial upper bound — overlap with compute is what the §Perf hillclimbs
  buy back.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.core import hlo_analysis, hwmodel


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per chip, per step
    hlo_bytes: float            # per chip, per step
    collective_bytes: float     # per chip, per step (wire bytes)
    model_flops: float          # 6*N*D (or serving analogue), whole step
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Serial upper bound (no overlap)."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def step_time_overlapped_s(self) -> float:
        """Perfect-overlap lower bound: the max of the three engines."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the overlapped bound: how close the
        step is to the chip's peak given perfect overlap."""
        if self.step_time_overlapped_s == 0:
            return 0.0
        useful_s = (self.model_flops / self.chips) / _TPU.peak_bf16_flops
        return useful_s / self.step_time_overlapped_s

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization against the serial step-time bound."""
        if self.step_time_s == 0:
            return 0.0
        useful_s = (self.model_flops / self.chips) / _TPU.peak_bf16_flops
        return useful_s / self.step_time_s

    @property
    def flops_efficiency(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — how much compiled compute is useful
        (catches remat/redundancy waste). >1 means XLA folded work away."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant,
                 step_time_s=self.step_time_s,
                 step_time_overlapped_s=self.step_time_overlapped_s,
                 roofline_fraction=self.roofline_fraction,
                 mfu=self.mfu,
                 flops_efficiency=self.flops_efficiency)
        return d


_TPU = hwmodel.DEFAULT_TPU


def compute_terms(arch: str, shape: str, mesh_name: str, chips: int,
                  hlo_flops: float, hlo_bytes: float,
                  collective_bytes: float, model_flops: float,
                  tpu: hwmodel.TPUSpec = _TPU,
                  ici_links: int = 2) -> RooflineTerms:
    """Build the three terms (seconds) from per-chip HLO quantities."""
    t = RooflineTerms(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                      hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
                      collective_bytes=collective_bytes,
                      model_flops=model_flops)
    t.compute_s = hlo_flops / tpu.peak_bf16_flops
    t.memory_s = hlo_bytes / tpu.hbm_bandwidth
    t.collective_s = collective_bytes / (tpu.ici_link_bandwidth * ici_links)
    return t


def collective_matmul_terms(m: int, k: int, n: int, axis_size: int,
                            in_bytes: int = 2,
                            tpu: hwmodel.TPUSpec = _TPU,
                            ici_links: int = 2
                            ) -> Dict[str, RooflineTerms]:
    """Price the lowerings of one TP matmul ``(m,k) @ (k,n)`` with the
    contraction dim sharded over ``axis_size`` devices, as roofline cells:

    * ``all_gather`` — the naive SPMD lowering: gather x, then GEMM. Wire
      bytes land *before* the first MAC, so its honest time is the serial
      ``step_time_s``.
    * ``ag_ring`` — ``dist.collective_matmul.ag_matmul``: same wire bytes
      moved as n-1 collective-permutes that hide under the per-step GEMMs,
      so its honest time is ``step_time_overlapped_s`` (out replicated).
    * ``rs_ring`` — ``dist.collective_matmul.rs_matmul``: the ring
      circulates (m, n/axis) *partial sums* instead of (m, k/axis) input
      blocks, output stays sharded — cheaper wire when n < k, and the
      consumer-side layout MoE dispatch wants.
    * ``all_reduce`` — row-parallel x@w then psum: 2x the reduce-scatter
      wire bytes, the baseline ``rs_ring`` halves.

    Per-chip compute/memory terms are identical across variants except the
    output residency (replicated for gather variants, sharded for
    ``rs_ring``); the table exists to show where the ring variants win.
    """
    from repro.core import interconnect

    f = axis_size
    flops = 2.0 * m * k * n / f                     # GEMM evenly sharded
    x_b, w_b = m * k * in_bytes / f, k * n * in_bytes
    out_full, out_shard = m * n * in_bytes, m * n * in_bytes / f
    wire = {
        "all_gather": interconnect.collective_time(
            "all_gather", m * k * in_bytes, f, tpu,
            links=ici_links).bytes_on_wire,
        "ag_ring": interconnect.collective_time(
            "all_gather", m * k * in_bytes, f, tpu,
            links=ici_links).bytes_on_wire,     # same bytes, overlapped
        "rs_ring": interconnect.collective_time(
            "reduce_scatter", m * n * in_bytes, f, tpu,
            links=ici_links).bytes_on_wire,
        "all_reduce": interconnect.collective_time(
            "all_reduce", m * n * in_bytes, f, tpu,
            links=ici_links).bytes_on_wire,
    }
    resident = {"all_gather": out_full, "ag_ring": out_full,
                "rs_ring": out_shard, "all_reduce": out_full}
    out: Dict[str, RooflineTerms] = {}
    for variant, coll in wire.items():
        out[variant] = compute_terms(
            arch=f"matmul_{variant}", shape=f"{m}x{k}x{n}",
            mesh_name=f"tp{f}", chips=f, hlo_flops=flops,
            hlo_bytes=x_b + w_b + resident[variant],
            collective_bytes=coll, model_flops=2.0 * m * k * n,
            tpu=tpu, ici_links=ici_links)
    return out


def terms_from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                        compiled, model_flops: float,
                        hlo_text: Optional[str] = None,
                        scan_trips: Optional[int] = None) -> RooflineTerms:
    """Derive roofline terms from a compiled executable.

    Quantities come from the auditable HLO parser (``hlo_analysis``):
    dot-level FLOPs, post-fusion operand/result bytes, and collective
    payload bytes — each with while-loop bodies scaled by ``scan_trips``
    (the layer-scan length; XLA does not annotate CPU trip counts, and its
    aggregate ``cost_analysis`` has inconsistent loop semantics on
    SPMD-partitioned modules, which we verified on controlled cases).
    """
    text = hlo_text if hlo_text is not None else compiled.as_text()
    trips = scan_trips or 1
    flops = hlo_analysis.parsed_flops(text, trips)
    bytes_ = hlo_analysis.parsed_bytes(text, trips)
    coll = hlo_analysis.parsed_collective_bytes(text, trips)
    return compute_terms(arch, shape, mesh_name, chips, flops, bytes_, coll,
                         model_flops)


def format_table(rows) -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | MODEL/HLO flops | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for t in rows:
        lines.append(
            f"| {t.arch} | {t.shape} | {t.mesh} | {t.compute_s:.3e} | "
            f"{t.memory_s:.3e} | {t.collective_s:.3e} | {t.dominant} | "
            f"{t.flops_efficiency:.2f} | {t.roofline_fraction:.3f} |")
    return "\n".join(lines)


def save_rows(rows, path: str):
    with open(path, "w") as f:
        json.dump([t.to_dict() for t in rows], f, indent=1)


def load_rows(path: str):
    with open(path) as f:
        data = json.load(f)
    out = []
    for d in data:
        t = RooflineTerms(
            arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
            chips=d["chips"], hlo_flops=d["hlo_flops"],
            hlo_bytes=d["hlo_bytes"],
            collective_bytes=d["collective_bytes"],
            model_flops=d["model_flops"])
        t.compute_s = d["compute_s"]
        t.memory_s = d["memory_s"]
        t.collective_s = d["collective_s"]
        out.append(t)
    return out
