"""Instruction encoding facts — paper ch. 2 + appendix.

TPU has no public ISA, so this chapter does not transfer to executable form
(DESIGN.md §7); we keep the discovered encoding as machine-readable data plus
faithful encode/decode of the *control information*, which is the part the
paper actually uses operationally (stall counts, barriers, reuse flags drive
the Ch.1 optimization and the §4.1 latency measurements).

Control section layout (all of Volta/Pascal/Maxwell, paper §2.1):

    | width (bits) | 4     | 6         | 3        | 3         | 1     | 4     |
    | meaning      | reuse | wait mask | read bar | write bar | yield | stall |

Volta packs one 21-bit section per 128-bit instruction word; Pascal/Maxwell
pack 3 sections in a 64-bit control word (1 zero MSB); Kepler packs 7 8-bit
sections (6 zero MSBs + 2 zero LSBs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

# Field widths, LSB-first: stall(4), yield(1), write_bar(3), read_bar(3),
# wait_mask(6), reuse(4) = 21 bits.
_FIELDS = (("stall", 4), ("yield_flag", 1), ("write_bar", 3),
           ("read_bar", 3), ("wait_mask", 6), ("reuse", 4))
SECTION_BITS = 21


@dataclasses.dataclass(frozen=True)
class ControlInfo:
    stall: int = 0
    yield_flag: int = 0
    write_bar: int = 7          # 7 = none
    read_bar: int = 7
    wait_mask: int = 0
    reuse: int = 0

    def encode(self) -> int:
        word = 0
        shift = 0
        for name, width in _FIELDS:
            val = getattr(self, name)
            assert 0 <= val < (1 << width), (name, val)
            word |= val << shift
            shift += width
        return word


def decode_control(word: int) -> ControlInfo:
    vals = {}
    shift = 0
    for name, width in _FIELDS:
        vals[name] = (word >> shift) & ((1 << width) - 1)
        shift += width
    return ControlInfo(**vals)


def pack_volta(instr_bits: int, ctrl: ControlInfo,
               ctrl_offset: int = 105) -> int:
    """One 128-bit Volta word: >=91 instruction bits, 21+2 control bits.

    The paper reports control information is "preceded and followed by
    instruction encoding bits"; we place the section at a fixed offset, with
    the 2 zero guard bits above it."""
    assert instr_bits < (1 << 105)
    return instr_bits | (ctrl.encode() << ctrl_offset)


def unpack_volta(word: int, ctrl_offset: int = 105
                 ) -> Tuple[int, ControlInfo]:
    mask = (1 << SECTION_BITS) - 1
    ctrl = decode_control((word >> ctrl_offset) & mask)
    instr = word & ~(mask << ctrl_offset)
    return instr, ctrl


def pack_pascal_control_word(sections: List[ControlInfo]) -> int:
    """Pascal/Maxwell: 3 x 21-bit sections in one 64-bit word, MSB zero."""
    assert len(sections) == 3
    word = 0
    for i, s in enumerate(sections):
        word |= s.encode() << (i * SECTION_BITS)
    return word


def unpack_pascal_control_word(word: int) -> List[ControlInfo]:
    mask = (1 << SECTION_BITS) - 1
    return [decode_control((word >> (i * SECTION_BITS)) & mask)
            for i in range(3)]


# ----------------------------------------------------------------------------
# Opcode tables (appendix; representative, cleanly transcribed subset).
# Volta opcodes sit in the LSBs of the first 64-bit half and are 10-13 bits.
# ----------------------------------------------------------------------------

VOLTA_OPCODES: Dict[str, str] = {
    # floating point
    "FADD": "010 0010 0001", "FCHK": "011 0000 0010", "FFMA": "010 0010 0011",
    "FMNMX": "010 0000 1001", "FMUL": "010 0010 0000", "FSET": "010 0000 1010",
    "FSETP": "010 0000 1011", "FSWZADD": "0 1000 0010 0010",
    "MUFU": "011 0000 1000", "DADD": "010 0010 1001", "DFMA": "010 0010 1011",
    "DMUL": "010 0010 1000", "DSETP": "010 0010 1010",
    "HADD2": "010 0011 0000", "HFMA2": "010 0011 0001",
    "HMMA2": "0 0010 0011 0110", "HMUL2": "010 0011 0010",
    "HSETP2": "010 0011 0100", "HSET2": "010 0011 0011",
    "FSEL": "010 0000 1000",
    # integer
    "FLO": "011 0000 0000", "IADD3": "010 0001 0000",
    "IMAD": "010 0010 0100", "ISETP": "010 0000 1100",
    "LEA": "010 0001 0001", "LOP3": "010 0001 0010", "POPC": "011 0000 1001",
    "SHF": "010 0001 1001", "VABSDIFF": "010 0001 0100",
    "VABSDIFF4": "010 0001 0101", "BREV": "011 0000 0001",
    "IABS": "010 0001 0011", "IDP": "010 0010 0110",
    "QSPC": "0 0011 1010 1010", "BMSK": "010 0001 1011",
    # conversion / movement
    "MOV": "010 0000 0010", "PRMT": "010 0001 0110", "SEL": "010 0000 0111",
    "SHFL": "0 1001 1000 1001", "P2R": "010 0000 0011",
    "R2P": "010 0000 0100", "GETLMEMBASE": "0 0011 1100 0000",
    # load/store
    "LD": "0 1001 1000 0000", "LDC": "0 1011 1000 0010",
    "LDG": "0 0011 1000 0001", "LDL": "0 1001 1000 0011",
    "LDS": "0 1001 1000 0100", "ST": "0 0011 1000 0101",
    "STG": "0 0011 1000 0110", "STL": "0 0011 1000 0111",
    "STS": "0 0011 1000 1000", "ATOM": "0 0011 1000 1010",
    "ATOMS": "0 0011 1000 1100", "ATOMG": "0 0011 1010 1000",
    "RED": "0 1001 1000 1110", "CCTL": "0 1001 1000 1111",
    "MEMBAR": "0 1001 1001 0010", "ERRBAR": "0 1001 1010 1011",
    "CCTLL": "0 1001 1001 0000", "MATCH": "0 0011 1010 0001",
    # control
    "BRA": "0 1001 0100 0111", "BRX": "0 1001 0100 1001",
    "JMP": "0 1001 0100 1010", "JMX": "0 1001 0100 1100",
    "BSYNC": "0 1001 0100 0001", "WARPSYNC": "011 0100 1000",
    "CALL": "011 0100 0011", "RET": "0 1001 0101 0000",
    "EXIT": "0 1001 0100 1101", "BMOV": "0 0011 0101 0101",
    "YIELD": "0 1001 0100 0110", "RTT": "0 1001 0100 1111",
    "KILL": "0 1001 0101 1011", "IDE": "0 1001 0101 0001",
    "PMTRIG": "0 1000 0000 0001", "BREAK": "0 1001 0100 0010",
    "BSSY": "0 1001 0100 0101",
    # other
    "NOP": "0 1001 0001 1000", "CS2R": "0 1000 0000 0101",
    "S2R": "0 1001 0001 1001", "B2R": "0 0011 0001 1100",
    "BAR": "011 0001 1101", "R2B": "0 0011 0001 1110",
    "VOTE": "0 1000 0000 0110", "TMML": "0 1011 0110 1001",
    "TXD": "0 1011 0110 1100", "SGXT": "010 0001 1010",
}


def opcode_bits(name: str) -> int:
    return len(VOLTA_OPCODES[name].replace(" ", ""))


def opcode_length_histogram() -> Dict[int, int]:
    """Paper §2.3: Volta opcodes vary from 10 to 13 bits."""
    hist: Dict[int, int] = {}
    for name in VOLTA_OPCODES:
        hist[opcode_bits(name)] = hist.get(opcode_bits(name), 0) + 1
    return hist


ENCODING_FACTS = {
    "word_bits": 128,
    "min_instruction_bits": 91,
    "min_control_bits": 23,     # 21-bit section + 2 guard zeros
    "unused_bits": 14,
    "opcode_bits_range": (10, 13),
    "opcode_position": "least-significant bits of the first 64-bit half",
}
