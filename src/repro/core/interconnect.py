"""Interconnect models — paper ch. 5 (NVLink/PCIe) adapted to TPU ICI/DCN.

The paper benchmarks peer-to-peer bandwidth/latency across link generations.
The TPU-idiomatic equivalent is the alpha-beta cost model of ICI collectives
that the roofline engine's third term consumes, plus per-collective byte
accounting from compiled HLO (``core/hlo_analysis.py``).

alpha-beta model: time(bytes) = alpha (hops x per-hop latency) + bytes / beta.
Ring algorithms on an ICI torus move 2*(n-1)/n of the payload per participating
link; we expose per-collective effective-byte factors used consistently by
the roofline engine and the collective microbenchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import hwmodel


@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    bytes_on_wire: float       # per chip, per direction
    time_s: float
    alpha_s: float
    beta_s: float


def _ring_factor(kind: str, n: int) -> float:
    """Payload multiplier per chip for ring algorithms over n participants."""
    if n <= 1:
        return 0.0
    if kind == "all_reduce":
        return 2.0 * (n - 1) / n          # reduce-scatter + all-gather
    if kind in ("all_gather", "reduce_scatter"):
        return (n - 1) / n
    if kind == "all_to_all":
        return (n - 1) / n
    if kind == "collective_permute":
        return 1.0
    raise ValueError(kind)


def collective_time(kind: str, payload_bytes: float, axis_size: int,
                    tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU,
                    links: Optional[int] = None,
                    inter_pod: bool = False) -> CollectiveCost:
    """alpha-beta time of one collective over a mesh axis.

    ``payload_bytes`` is the full logical tensor size. ``links`` is how many
    ICI links serve this axis (a 2D-mesh axis gets 2 of the 4)."""
    links = links or (tpu.ici_links_per_chip // 2)
    beta = (tpu.dcn_bandwidth if inter_pod
            else tpu.ici_link_bandwidth * links)
    n = max(axis_size, 1)
    factor = _ring_factor(kind, axis_size)
    # Per-chip wire bytes for ring algorithms over the logical payload:
    #   all-gather / reduce-scatter: P (n-1)/n     all-reduce: 2 P (n-1)/n
    #   all-to-all: P (n-1)/n^2                    permute: P/n (one shard)
    if kind == "all_to_all":
        per_chip = payload_bytes * factor / n
    elif kind == "collective_permute":
        per_chip = payload_bytes / n
    else:
        per_chip = payload_bytes * factor
    hops = axis_size - 1 if axis_size > 1 else 0
    alpha = hops * tpu.ici_latency_us * 1e-6
    t = alpha + per_chip / beta
    return CollectiveCost(bytes_on_wire=per_chip, time_s=t,
                          alpha_s=alpha, beta_s=per_chip / beta)


def link_comparison() -> Dict[str, Tuple[float, float]]:
    """Paper Table 5.1 rows + the TPU ICI link for context:
    name -> (unidirectional GB/s, latency us)."""
    out = {name: (l.unidir_gbs, l.latency_us)
           for name, l in hwmodel.LINKS.items()}
    tpu = hwmodel.DEFAULT_TPU
    out["TPU-ICI-link"] = (tpu.ici_link_bandwidth / 1e9, tpu.ici_latency_us)
    return out


def measured_vs_theoretical() -> Dict[str, float]:
    """Measured/theoretical link efficiency (paper emphasizes 83.3% HBM2
    efficiency on Volta vs 69.6% on Pascal; links behave similarly)."""
    out = {}
    for name, l in hwmodel.LINKS.items():
        if l.theoretical_gbs:
            out[name] = l.unidir_gbs / l.theoretical_gbs
    return out
