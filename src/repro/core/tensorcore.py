"""Tensor-core (HMMA.884) dissection — paper §4.3, Figures 4.2–4.7.

The paper discovered, by probing registers at runtime, how ``wmma::mma_sync``
distributes a 16x16x16 half-precision matrix multiplication across the 32
threads of a warp: which threads load which elements of A and B (Figs 4.2,
4.3), how the 4 HMMA instruction *sets* (k-chunks) x 4 *steps* (output
sub-tiles) cover C (Figs 4.4–4.6), and which threads write back each element
of C (Fig 4.7).

We encode the discovered mappings in closed form (derived from the published
address tables), emulate the 16-instruction HMMA sequence at thread-group
granularity, and verify that the emulation reproduces ``A @ B + C`` exactly —
the same consistency check the paper's tables must satisfy.

TPU transfer note (DESIGN.md §2): the MXU analogue of this dissection is the
shape-alignment cliff probe in ``benchmarks/tpu_mxu.py`` — the MXU consumes
128x128 tiles the way tensor cores consume 16x16x16 fragments.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

M = N = K = 16
GROUPS = 8                    # thread groups of 4 (group_id = thread_id / 4)
SETS = 4                      # HMMA instruction sets: k-chunks of 4
STEPS = 4                     # steps: 2x4 output sub-tiles of a group's block


def a_fragment_threads(row: int, col: int) -> Tuple[int, int]:
    """Fig 4.2: the two threads loading A[row, col] (column-major, fp16)."""
    base = {0: 0, 1: 16, 2: 4, 3: 20}[row // 4]
    t = base + col % 4
    return (t, t + 8)


def b_fragment_threads(row: int, col: int) -> Tuple[int, int]:
    """Fig 4.3: the two threads loading B[row, col] (column-major, fp16)."""
    base = {0: 0, 1: 16, 2: 8, 3: 24}[col // 4]
    t = base + col % 4
    return (t, t + 4)


def c_fragment_thread(row: int, col: int) -> int:
    """Fig 4.7: the thread that stores C[row, col] (column-major, fp32)."""
    rowpat = (0, 1, 0, 1, 16, 17, 16, 17)
    colpat = 8 * (col // 8) + 2 * ((col // 2) % 2)
    return rowpat[row % 8] + 4 * (row // 8) + colpat


def c_group(row: int, col: int) -> int:
    """Fig 4.5: thread group owning C[row, col]."""
    return c_fragment_thread(row, col) // 4


def group_block(group: int) -> Tuple[slice, slice]:
    """The 4x8 block of C computed by one thread group (from Fig 4.5)."""
    rows = {0: 0, 4: 4, 1: 8, 5: 12, 2: 0, 6: 4, 3: 8, 7: 12}[group]
    cols = 0 if group in (0, 4, 1, 5) else 8
    return slice(rows, rows + 4), slice(cols, cols + 8)


def step_subtile(step: int) -> Tuple[slice, slice]:
    """Fig 4.4: the 2x4 sub-tile of a group's 4x8 block per HMMA step."""
    r = slice(0, 2) if step in (0, 2) else slice(2, 4)
    c = slice(0, 4) if step in (0, 1) else slice(4, 8)
    return r, c


def emulate_mma_sync(a: np.ndarray, b: np.ndarray,
                     c: np.ndarray) -> np.ndarray:
    """Emulate the 4-set x 4-step HMMA.884 sequence of Listing 4.1.

    Sets execute in order (set 0 first), each accumulating one k-chunk of 4;
    within a set, the 4 steps fill the group's four 2x4 output sub-tiles.
    """
    assert a.shape == (M, K) and b.shape == (K, N) and c.shape == (M, N)
    out = c.astype(np.float32).copy()
    af = a.astype(np.float32)
    bf = b.astype(np.float32)
    for g in range(GROUPS):
        rs, cs = group_block(g)
        block = out[rs, cs]
        for s in range(SETS):
            kk = slice(4 * s, 4 * s + 4)
            for st in range(STEPS):
                sr, sc = step_subtile(st)
                block[sr, sc] += (af[rs, kk][sr, :]
                                  @ bf[kk, cs][:, sc])
        out[rs, cs] = block
    return out


def fragment_table(matrix: str) -> np.ndarray:
    """Reproduce the paper's address->thread tables (Figs 4.2/4.3/4.7).

    Returns an array of shape (16, 16, 2) of thread indices for A and B
    ((16, 16) for C), indexed [row, col]."""
    if matrix == "A":
        return np.array([[a_fragment_threads(r, c) for c in range(K)]
                         for r in range(M)])
    if matrix == "B":
        return np.array([[b_fragment_threads(r, c) for c in range(N)]
                         for r in range(K)])
    if matrix == "C":
        return np.array([[c_fragment_thread(r, c) for c in range(N)]
                         for r in range(M)])
    raise ValueError(matrix)


def loads_per_thread(matrix: str) -> np.ndarray:
    """Elements of A/B loaded per thread — the paper reports 16 each."""
    table = fragment_table(matrix)
    counts = np.zeros(32, dtype=int)
    for pair in table.reshape(-1, table.shape[-1] if table.ndim == 3 else 1):
        for t in np.atleast_1d(pair):
            counts[int(t)] += 1
    return counts
