"""Pointer-chase dissection algorithms (paper ch. 3, after Mei & Chu [12]).

Every routine here treats the device as a black box exposing only
``access(addr) -> latency``. Geometry is inferred purely from timing, exactly
as the paper does on real silicon. ``tests/test_pchase.py`` property-tests
these routines against *randomized* ground-truth geometries, not just the
published ones.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import MemoryHierarchy


# --------------------------------------------------------------------------
# Generic helpers
# --------------------------------------------------------------------------

def measure_hit_latency(hier: MemoryHierarchy, stride: int) -> int:
    """Steady-state latency of a trivially cache-resident scan."""
    addrs = np.arange(0, 16 * stride, stride, dtype=np.int64)
    hier.flush()
    hier.scan(addrs)
    return int(hier.scan(addrs).min())


def _second_scan_miss_fraction(hier: MemoryHierarchy, n_bytes: int,
                               stride: int, hit_latency: int) -> float:
    """Scan [0, n_bytes) twice; fraction of second-scan accesses slower than
    a known-resident access (= cache misses). The paper's Table 3.3
    benchmark."""
    addrs = np.arange(0, n_bytes, stride, dtype=np.int64)
    hier.flush()
    hier.scan(addrs)                       # warm
    lat = hier.scan(addrs)                 # measure
    return float(np.mean(lat > hit_latency))


def detect_size(hier: MemoryHierarchy, lo: int, hi: int, stride: int,
                resolution: int = 1024, threshold: float = 0.005) -> int:
    """Largest array size with (almost) no second-scan misses.

    Monotone in size for LRU and for Volta's priority policy alike, so a
    bracket + binary search replaces the paper's exhaustive sweep (same
    answer, fewer simulated cycles).
    """
    hit_lat = measure_hit_latency(hier, stride)

    def frac(n: int) -> float:
        return _second_scan_miss_fraction(hier, n, stride, hit_lat)

    if frac(lo) > threshold:
        return 0
    # Bracket: double until misses appear.
    good, bad = lo, None
    size = lo
    while size < hi:
        size = min(size * 2, hi)
        if frac(size) > threshold:
            bad = size
            break
        good = size
    if bad is None:
        return good
    while bad - good > resolution:
        mid = (good + bad) // 2
        if frac(mid) > threshold:
            bad = mid
        else:
            good = mid
    return good


def detect_line(hier: MemoryHierarchy, detected_size: int,
                probe_stride: int = 8) -> int:
    """Line size = periodicity of misses in a fine-grained cold scan
    (Fig 3.2: one slow access per line, fast hits inside the line)."""
    n = min(detected_size // 2, 64 * 1024)
    addrs = np.arange(0, n, probe_stride, dtype=np.int64)
    hier.flush()
    lat = hier.scan(addrs)
    lo = lat.min()
    miss_idx = np.nonzero(lat > lo)[0]
    if len(miss_idx) < 2:
        return probe_stride
    gaps = np.diff(miss_idx)
    period = int(np.bincount(gaps).argmax())
    return period * probe_stride


def detect_ways(hier: MemoryHierarchy, size_hint: int, miss_threshold: int,
                max_ways: int = 512) -> int:
    """Effective associativity: chase k addresses spaced by the cache size —
    they all map to one set. The largest k with a clean second scan is the
    (effective) way count. ``miss_threshold`` separates this level's hits
    from its misses (TLB-side latency noise stays below it)."""
    lo_ok, hi_bad = 1, None
    k = 1
    while k <= max_ways:
        k = min(k * 2, max_ways + 1)
        if _same_set_misses(hier, size_hint, k, miss_threshold):
            hi_bad = k
            break
        lo_ok = k
    if hi_bad is None:
        return lo_ok
    while hi_bad - lo_ok > 1:
        mid = (lo_ok + hi_bad) // 2
        if _same_set_misses(hier, size_hint, mid, miss_threshold):
            hi_bad = mid
        else:
            lo_ok = mid
    return lo_ok


def _same_set_misses(hier: MemoryHierarchy, spacing: int, k: int,
                     miss_threshold: int) -> bool:
    addrs = np.arange(k, dtype=np.int64) * spacing
    hier.flush()
    hier.scan(addrs)
    lat = hier.scan(addrs)
    return bool(np.any(lat >= miss_threshold))


def detect_policy(detected_size: int, nominal_size: int) -> str:
    """Table 3.3's observable: a detectable size short of nominal reveals a
    non-LRU preservation-priority policy (Volta / Kepler); matching sizes are
    consistent with LRU."""
    return "non-LRU" if detected_size < nominal_size * 97 // 100 else "LRU"


# --------------------------------------------------------------------------
# Latency classes (Fig 3.2)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class LatencyClasses:
    l1_hit: int
    l2_hit: int
    dram: int
    cold: int


def measure_next_level_latency(hier: MemoryHierarchy, level_size: int,
                               stride: int = 8) -> int:
    """Steady-state latency one level below: scan an array several times the
    detected capacity twice — the first level thrashes (LRU) or overflows
    (priority policy), so the slow class of the second scan is the next
    level's hit latency. Needed where L1 and L2 share a line size and the
    cold-scan classes of Fig 3.2 collapse (P100/P4/M60/K80)."""
    addrs = np.arange(0, 4 * level_size, stride, dtype=np.int64)
    hier.flush()
    hier.scan(addrs)
    return int(hier.scan(addrs).max())


def latency_classes(hier: MemoryHierarchy, span: int = 256 * 1024,
                    stride: int = 8) -> LatencyClasses:
    """Cold fine-grained scan: the distinct latencies observed are the cache
    hit/miss classes (28 / 193 / 375 / 1029 on V100)."""
    addrs = np.arange(0, span, stride, dtype=np.int64)
    hier.flush()
    lat = hier.scan(addrs)
    classes = np.unique(lat)
    l1_hit = int(classes[0])
    cold = int(lat[0])
    mids = [int(c) for c in classes if l1_hit < c < cold]
    l2_hit = mids[0] if mids else cold
    dram = mids[1] if len(mids) > 1 else l2_hit
    return LatencyClasses(l1_hit=l1_hit, l2_hit=l2_hit, dram=dram, cold=cold)


# --------------------------------------------------------------------------
# TLB dissection (§3.8, Fig 3.12)
# --------------------------------------------------------------------------

def _tlb_round(hier: MemoryHierarchy, n_pages: int,
               stride: int) -> np.ndarray:
    addrs = np.arange(n_pages, dtype=np.int64) * stride
    hier.flush()
    hier.scan(addrs)           # warm TLB + caches
    return hier.scan(addrs)


def _tlb_round_latency(hier: MemoryHierarchy, n_pages: int,
                       stride: int) -> float:
    return float(_tlb_round(hier, n_pages, stride).mean())


def detect_tlb_entries(hier: MemoryHierarchy, page_stride: int,
                       baseline: float, max_pages: int = 600) -> Tuple[int, float]:
    """Largest page count chaseable at ``page_stride`` without leaving the
    steady-state latency ``baseline``: that is the level's entry count.
    Returns (entries, latency_after_the_jump)."""
    good, bad = 1, None
    n = 1
    while n < max_pages:
        n = min(n * 2, max_pages)
        if _tlb_round_latency(hier, n, page_stride) > baseline + 2.0:
            bad = n
            break
        good = n
    if bad is None:
        return good, baseline
    while bad - good > 1:
        mid = (good + bad) // 2
        if _tlb_round_latency(hier, mid, page_stride) > baseline + 2.0:
            bad = mid
        else:
            good = mid
    return good, _tlb_round_latency(hier, bad, page_stride)


def detect_page_size(hier: MemoryHierarchy, candidates: Sequence[int],
                     elevated_threshold: float, n_probe: int = 512) -> int:
    """Smallest stride at which (essentially) every access of a
    beyond-coverage sweep pays this level's TLB miss. At half the true page
    size, pairs of accesses share an entry and only half the accesses are
    elevated, so the 0.9 fraction test singles out the page size."""
    for stride in sorted(candidates):
        lat = _tlb_round(hier, n_probe, stride)
        frac = float(np.mean(lat > elevated_threshold))
        if frac > 0.9:
            return stride
    return max(candidates)


def dissect_tlbs(hier: MemoryHierarchy,
                 page_candidates_l1: Sequence[int],
                 page_candidates_l2: Sequence[int],
                 max_pages: int = 600) -> List["DiscoveredTLB"]:
    """Full two-level TLB dissection (Fig 3.12): page sizes then coverages.

    ``hier`` must have the L1 data cache disabled (the paper uses ld.global.cg
    for the same reason: L1 is virtually indexed and would mask TLB traffic).
    """
    base = _tlb_round_latency(hier, 2, min(page_candidates_l1))
    page1 = detect_page_size(hier, page_candidates_l1,
                             elevated_threshold=base + 2.0)
    entries1, plateau2 = detect_tlb_entries(hier, page1, base, max_pages)
    l1 = DiscoveredTLB(page_entry=page1, coverage=entries1 * page1)
    page2 = detect_page_size(hier, [c for c in page_candidates_l2 if c >= page1],
                             elevated_threshold=plateau2 + 2.0)
    entries2, _ = detect_tlb_entries(hier, page2, plateau2, max_pages)
    l2 = DiscoveredTLB(page_entry=page2, coverage=entries2 * page2)
    return [l1, l2]


# --------------------------------------------------------------------------
# Full-geometry record
# --------------------------------------------------------------------------

@dataclasses.dataclass
class DiscoveredCache:
    size: int
    line: int
    ways: int
    sets: int
    policy: str
    hit_latency: int


@dataclasses.dataclass
class DiscoveredTLB:
    page_entry: int
    coverage: int
