"""Hardware specification registry.

Two families of specs live here:

* GPU specs transcribed from the paper's Table 3.1 / Ch. 4 / Ch. 5 — these are
  the *published ground truth* that the dissection engine (``core/dissect.py``)
  must recover when run against a simulator configured with them.

* TPU specs (v5e is the roofline target of the framework) — these feed the
  three-term roofline engine (``core/roofline.py``) and the autotuner.

All sizes are in bytes, latencies in cycles (GPU) and seconds (TPU link/HBM
terms are expressed as rates), unless noted.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

KiB = 1024
MiB = 1024 * KiB
GiB = 1024 * MiB


@dataclasses.dataclass(frozen=True)
class CacheGeometry:
    """Geometry of one cache level, as in paper Table 3.1."""

    size: int                 # capacity in bytes
    line: int                 # line size in bytes
    sets: Optional[int] = None
    ways: Optional[int] = None
    hit_latency: Optional[int] = None   # cycles
    load_granularity: Optional[int] = None
    update_granularity: Optional[int] = None
    policy: str = "lru"       # "lru" | "prio" (Volta's non-LRU) | "random"
    physical_indexed: bool = False

    @property
    def num_lines(self) -> int:
        return self.size // self.line


@dataclasses.dataclass(frozen=True)
class TLBGeometry:
    coverage: int             # bytes covered
    page_entry: int           # bytes per entry
    latency_penalty: int = 0  # extra cycles on miss into next level

    @property
    def entries(self) -> int:
        return self.coverage // self.page_entry


@dataclasses.dataclass(frozen=True)
class RegisterFileSpec:
    banks: int
    bank_width_bits: int
    reuse_slots: int = 4      # register reuse cache slots (paper §2.1)


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    """One column of paper Table 3.1 (+ latency data from ch. 4/5)."""

    name: str
    arch: str
    sms: int                        # "processors per chip (P)"
    max_clock_mhz: float            # f_g
    regfile: RegisterFileSpec
    l1d: CacheGeometry
    l2d: CacheGeometry
    l1c: CacheGeometry              # L1 constant
    l15c: CacheGeometry             # L1.5 constant
    icache_sizes: tuple             # (L0 or L1, L1 or L1.5, L2) bytes
    l1_tlb: TLBGeometry
    l2_tlb: TLBGeometry
    smem_size_per_sm: int
    smem_banks: int
    smem_bank_width: int            # bytes (B_s width w_s)
    smem_no_conflict_latency: int   # cycles
    smem_theoretical_gibs: Optional[float]
    smem_measured_gibs: Optional[float]
    gmem_bus: str
    gmem_size: int
    gmem_clock_mhz: Optional[float]
    gmem_theoretical_gibs: float
    gmem_measured_gibs: float
    l1_bw_bytes_per_cycle: Optional[float] = None   # Table 3.2 measured
    l1_bw_upper_bytes_per_cycle: Optional[float] = None
    l2_bw_gbs: Optional[float] = None               # Table 3.4
    global_latency_l2_miss: Optional[int] = None    # cycles, TLB hit (Fig 3.2)
    global_latency_cold: Optional[int] = None       # cycles, L2+TLB miss
    schedulers_per_sm: int = 4
    fp32_cores_per_sm: int = 64
    # dependent-issue latency table (paper Table 4.1): instr -> cycles
    instr_latency: Optional[dict] = None
    # atomic latency (paper Table 4.2): contention -> (shared, global) cycles
    atomic_latency: Optional[dict] = None


# ----------------------------------------------------------------------------
# Paper Table 3.1, transcribed column by column.
# ----------------------------------------------------------------------------

VOLTA_INSTR_LATENCY = {
    # Table 4.1, Volta rows.
    "IADD3": 4, "SHF": 4, "LOP3": 4, "SEL": 4, "MOV": 4, "FADD": 4,
    "FFMA": 4, "FMUL": 4, "ISETP": 4, "FSET": 4, "FSETP": 4,
    "IMAD": 5, "FMNMX": 5, "DSET": 5, "DSETP": 5,
    "HADD2": 6, "HMUL2": 6, "HFMA2": 6,
    "DADD": 8, "DMUL": 8, "DFMA": 8,
    "POPC": 10,
    "FLO": 14, "BREV": 14, "MUFU": 14,
}

PASCAL_INSTR_LATENCY = {
    # Table 4.1, Pascal rows.
    "BFE": 6, "BFI": 6, "IADD": 6, "IADD32I": 6, "FADD": 6, "FMUL": 6,
    "FFMA": 6, "FMNMX": 6, "HADD2": 6, "HMUL2": 6, "HFMA2": 6, "IMNMX": 6,
    "ISCADD": 6, "LOP": 6, "LOP32I": 6, "LOP3": 6, "MOV": 6, "MOV32I": 6,
    "SEL": 6, "SHL": 6, "SHR": 6, "VADD": 6, "VABSDIFF": 6, "VMNMX": 6,
    "XMAD": 6,
    "DADD": 8, "DMUL": 8, "DFMA": 8, "DMNMX": 8,
    "FSET": 12, "DSET": 12, "DSETP": 12, "ISETP": 12, "FSETP": 12,
    "POPC": 14, "FLO": 14, "MUFU": 14, "F2F": 14, "F2I": 14, "I2F": 14,
    "I2I": 14,
    "IMUL": 86, "IMAD": 86,
}

VOLTA_ATOMIC_LATENCY = {
    # Table 4.2, V100 columns: contention -> (shared, global).
    1: (6, 36), 2: (7, 31), 4: (11, 32), 8: (18, 41), 16: (24, 58),
    32: (66, 76),
}
PASCAL_P100_ATOMIC_LATENCY = {
    1: (15, 26), 2: (17, 31), 4: (19, 48), 8: (30, 48), 16: (46, 50),
    32: (78, 50),
}
MAXWELL_ATOMIC_LATENCY = {
    1: (17, 24), 2: (19, 26), 4: (25, 41), 8: (31, 41), 16: (47, 46),
    32: (79, 46),
}
KEPLER_ATOMIC_LATENCY = {
    1: (93, 29), 2: (214, 69), 4: (460, 96), 8: (952, 152), 16: (1936, 264),
    32: (4257, 488),
}

V100 = GPUSpec(
    name="V100", arch="volta", sms=80, max_clock_mhz=1380.0,
    regfile=RegisterFileSpec(banks=2, bank_width_bits=64),
    l1d=CacheGeometry(size=128 * KiB, line=32, sets=4, hit_latency=28,
                      load_granularity=32, update_granularity=128,
                      policy="prio", physical_indexed=False),
    l2d=CacheGeometry(size=6144 * KiB, line=64, ways=16, hit_latency=193,
                      policy="lru", physical_indexed=True),
    l1c=CacheGeometry(size=2 * KiB, line=64, sets=8, ways=4, hit_latency=27,
                      policy="random"),
    l15c=CacheGeometry(size=64 * KiB, line=256, hit_latency=89),
    icache_sizes=(12 * KiB, 128 * KiB, 6144 * KiB),  # L0 / L1 / L2
    l1_tlb=TLBGeometry(coverage=32 * MiB, page_entry=2 * MiB),
    l2_tlb=TLBGeometry(coverage=8192 * MiB, page_entry=32 * MiB),
    smem_size_per_sm=96 * KiB, smem_banks=32, smem_bank_width=4,
    smem_no_conflict_latency=19,
    smem_theoretical_gibs=13800.0, smem_measured_gibs=12080.0,
    gmem_bus="HBM2", gmem_size=16152 * MiB, gmem_clock_mhz=877.0,
    gmem_theoretical_gibs=900.0, gmem_measured_gibs=750.0,
    l1_bw_bytes_per_cycle=108.3, l1_bw_upper_bytes_per_cycle=256.0,
    l2_bw_gbs=2155.0,
    global_latency_l2_miss=375, global_latency_cold=1029,
    instr_latency=VOLTA_INSTR_LATENCY,
    atomic_latency=VOLTA_ATOMIC_LATENCY,
)

P100 = GPUSpec(
    name="P100", arch="pascal", sms=56, max_clock_mhz=1328.0,
    regfile=RegisterFileSpec(banks=4, bank_width_bits=32),
    l1d=CacheGeometry(size=24 * KiB, line=32, sets=4, hit_latency=82,
                      load_granularity=32, update_granularity=128,
                      policy="lru"),
    l2d=CacheGeometry(size=4096 * KiB, line=32, hit_latency=234, policy="lru",
                      physical_indexed=True),
    l1c=CacheGeometry(size=2 * KiB, line=64, sets=8, ways=4, hit_latency=24,
                      policy="random"),
    l15c=CacheGeometry(size=64 * KiB, line=256, hit_latency=96),
    icache_sizes=(8 * KiB, 128 * KiB, 4096 * KiB),
    l1_tlb=TLBGeometry(coverage=32 * MiB, page_entry=2 * MiB),
    l2_tlb=TLBGeometry(coverage=2048 * MiB, page_entry=32 * MiB),
    smem_size_per_sm=64 * KiB, smem_banks=32, smem_bank_width=4,
    smem_no_conflict_latency=24,
    smem_theoretical_gibs=None, smem_measured_gibs=7763.0,
    gmem_bus="HBM2", gmem_size=16276 * MiB, gmem_clock_mhz=715.0,
    gmem_theoretical_gibs=732.0, gmem_measured_gibs=510.0,
    l1_bw_bytes_per_cycle=31.3, l1_bw_upper_bytes_per_cycle=128.0,
    l2_bw_gbs=1624.0,
    instr_latency=PASCAL_INSTR_LATENCY,
    atomic_latency=PASCAL_P100_ATOMIC_LATENCY,
)

P4 = GPUSpec(
    name="P4", arch="pascal", sms=20, max_clock_mhz=1531.0,
    regfile=RegisterFileSpec(banks=4, bank_width_bits=32),
    l1d=CacheGeometry(size=24 * KiB, line=32, sets=4, hit_latency=82,
                      load_granularity=32, update_granularity=128,
                      policy="lru"),
    l2d=CacheGeometry(size=2048 * KiB, line=32, hit_latency=216, policy="lru",
                      physical_indexed=True),
    l1c=CacheGeometry(size=2 * KiB, line=64, sets=8, ways=4, hit_latency=25,
                      policy="random"),
    l15c=CacheGeometry(size=32 * KiB, line=256, hit_latency=87),
    icache_sizes=(8 * KiB, 32 * KiB, 2048 * KiB),
    l1_tlb=TLBGeometry(coverage=32 * MiB, page_entry=2 * MiB),
    l2_tlb=TLBGeometry(coverage=2048 * MiB, page_entry=32 * MiB),
    smem_size_per_sm=64 * KiB, smem_banks=32, smem_bank_width=4,
    smem_no_conflict_latency=23,
    smem_theoretical_gibs=None, smem_measured_gibs=3555.0,
    gmem_bus="GDDR5", gmem_size=8115 * MiB, gmem_clock_mhz=None,
    gmem_theoretical_gibs=192.0, gmem_measured_gibs=162.0,
    l1_bw_bytes_per_cycle=15.7, l1_bw_upper_bytes_per_cycle=128.0,
    l2_bw_gbs=979.0,
    instr_latency=PASCAL_INSTR_LATENCY,
)

M60 = GPUSpec(
    name="M60", arch="maxwell", sms=16, max_clock_mhz=1177.0,
    regfile=RegisterFileSpec(banks=4, bank_width_bits=32),
    l1d=CacheGeometry(size=24 * KiB, line=32, sets=4, hit_latency=82,
                      load_granularity=32, update_granularity=128,
                      policy="lru"),
    l2d=CacheGeometry(size=2048 * KiB, line=32, hit_latency=207, policy="lru",
                      physical_indexed=True),
    l1c=CacheGeometry(size=2 * KiB, line=64, sets=8, ways=4, hit_latency=25,
                      policy="random"),
    l15c=CacheGeometry(size=32 * KiB, line=256, hit_latency=81),
    icache_sizes=(8 * KiB, 32 * KiB, 2048 * KiB),
    l1_tlb=TLBGeometry(coverage=2 * MiB, page_entry=128 * KiB),
    l2_tlb=TLBGeometry(coverage=128 * MiB, page_entry=2 * MiB),
    smem_size_per_sm=96 * KiB, smem_banks=32, smem_bank_width=4,
    smem_no_conflict_latency=23,
    smem_theoretical_gibs=2410.0, smem_measured_gibs=2122.0,
    gmem_bus="GDDR5", gmem_size=8155 * MiB, gmem_clock_mhz=2505.0,
    gmem_theoretical_gibs=160.0, gmem_measured_gibs=127.0,
    l1_bw_bytes_per_cycle=15.7, l1_bw_upper_bytes_per_cycle=256.0,
    l2_bw_gbs=446.0,
    atomic_latency=MAXWELL_ATOMIC_LATENCY,
)

K80 = GPUSpec(
    name="K80", arch="kepler", sms=13, max_clock_mhz=875.0,
    regfile=RegisterFileSpec(banks=4, bank_width_bits=32),
    l1d=CacheGeometry(size=48 * KiB, line=128, sets=32, hit_latency=35,
                      load_granularity=128, update_granularity=128,
                      policy="prio"),
    l2d=CacheGeometry(size=1536 * KiB, line=32, hit_latency=200, policy="lru",
                      physical_indexed=True),
    l1c=CacheGeometry(size=2 * KiB, line=64, sets=8, ways=4, hit_latency=30,
                      policy="random"),
    l15c=CacheGeometry(size=32 * KiB, line=256, hit_latency=92),
    icache_sizes=(8 * KiB, 32 * KiB, 1536 * KiB),
    l1_tlb=TLBGeometry(coverage=2 * MiB, page_entry=128 * KiB),
    l2_tlb=TLBGeometry(coverage=128 * MiB, page_entry=2 * MiB),
    smem_size_per_sm=48 * KiB, smem_banks=32, smem_bank_width=8,
    smem_no_conflict_latency=26,
    smem_theoretical_gibs=None, smem_measured_gibs=2540.0,
    gmem_bus="GDDR5", gmem_size=12237 * MiB, gmem_clock_mhz=2505.0,
    gmem_theoretical_gibs=240.0, gmem_measured_gibs=191.0,
    l2_bw_gbs=339.0,
    atomic_latency=KEPLER_ATOMIC_LATENCY,
)

GPUS = {g.name: g for g in (V100, P100, P4, M60, K80)}


# ----------------------------------------------------------------------------
# Interconnect specs (paper Ch. 5).
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinkSpec:
    name: str
    unidir_gbs: float            # per direction, measured (Table 5.1)
    latency_us: float
    theoretical_gbs: Optional[float] = None


PCIE3 = LinkSpec("V100-PCIe", unidir_gbs=10.63, latency_us=7.21,
                 theoretical_gbs=16.0)
NVLINK1 = LinkSpec("P100-NVLink1", unidir_gbs=36.72, latency_us=9.47,
                   theoretical_gbs=40.0)
NVLINK2 = LinkSpec("V100-NVLink2", unidir_gbs=47.99, latency_us=8.55,
                   theoretical_gbs=50.0)
LINKS = {l.name: l for l in (PCIE3, NVLINK1, NVLINK2)}

HOST_BANDWIDTH_MBS = {
    # Table 5.2 (host-to-device, device-to-host) in MB/s.
    "V100-PCIe": (12152.4, 12881.1),
    "P100-NVLink1": (12135.9, 12845.9),
    "V100-NVLink2": (12147.8, 12858.0),
}


# ----------------------------------------------------------------------------
# TPU target (roofline constants mandated for this repro: v5e-class chip).
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_bf16_flops: float        # FLOP/s per chip
    hbm_bandwidth: float          # bytes/s per chip
    ici_link_bandwidth: float     # bytes/s per link, per direction
    ici_links_per_chip: int
    hbm_bytes: int
    vmem_bytes: int
    mxu_dim: int                  # systolic array edge (128)
    vpu_sublanes: int             # 8
    vpu_lanes: int                # 128
    ici_latency_us: float = 1.0   # per-hop latency (alpha term)
    dcn_bandwidth: float = 25e9   # bytes/s per host for pod-to-pod (multi-pod axis)


TPU_V5E = TPUSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links_per_chip=4,         # 2D torus on v5e
    hbm_bytes=16 * GiB,
    vmem_bytes=128 * MiB,
    mxu_dim=128,
    vpu_sublanes=8,
    vpu_lanes=128,
)

TPUS = {TPU_V5E.name: TPU_V5E}
DEFAULT_TPU = TPU_V5E
