"""Collective microbenchmarks over a device mesh (paper ch.5, TPU-idiomatic).

The paper measures NVLink p2p bandwidth with explicit copy benchmarks. On a
TPU mesh the unit of communication is the collective; this harness lowers
each collective over a real mesh (placeholder devices in the dry-run),
extracts the *wire bytes the compiler actually scheduled* from the HLO, and
prices them with the alpha-beta ICI model. The same machinery feeds the
roofline engine's collective term.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hlo_analysis, hwmodel, interconnect


@dataclasses.dataclass
class CollectiveBench:
    kind: str
    payload_bytes: int
    axis: str
    axis_size: int
    hlo_bytes: int              # from compiled HLO
    modeled_bytes: float        # alpha-beta ring accounting
    modeled_time_s: float
    effective_gbs: float        # payload / modeled time


def _op(kind: str, axis: str):
    if kind == "all_reduce":
        return lambda x: jax.lax.psum(x, axis)
    if kind == "all_gather":
        return lambda x: jax.lax.all_gather(x, axis, tiled=True)
    if kind == "reduce_scatter":
        return lambda x: jax.lax.psum_scatter(x, axis, tiled=True)
    if kind == "all_to_all":
        return lambda x: jax.lax.all_to_all(x, axis, split_axis=0,
                                            concat_axis=0, tiled=True)
    if kind == "collective_permute":
        def permute(x):
            n = jax.lax.axis_size(axis)
            perm = [(i, (i + 1) % n) for i in range(n)]
            return jax.lax.ppermute(x, axis, perm)
        return permute
    raise ValueError(kind)


def bench_collective(mesh, kind: str, payload_bytes: int, axis: str,
                     dtype=jnp.bfloat16,
                     tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU
                     ) -> CollectiveBench:
    """Lower one collective over ``mesh`` and account its wire bytes."""
    from jax.sharding import PartitionSpec as P
    from jax import shard_map

    axis_size = mesh.shape[axis]
    itemsize = jnp.dtype(dtype).itemsize
    n_elems = max(axis_size, payload_bytes // itemsize)
    n_elems = (n_elems // axis_size) * axis_size
    spec = P(axis)
    out_spec = P(None) if kind == "all_gather" else spec
    fn = shard_map(_op(kind, axis), mesh=mesh, in_specs=(spec,),
                   out_specs=out_spec, check_vma=False)
    x = jax.ShapeDtypeStruct((n_elems,), dtype)
    lowered = jax.jit(fn).lower(x)
    compiled = lowered.compile()
    stats = hlo_analysis.collective_stats(compiled.as_text())
    cost = interconnect.collective_time(kind, n_elems * itemsize, axis_size,
                                        tpu)
    eff = (n_elems * itemsize) / cost.time_s / 1e9 if cost.time_s else 0.0
    return CollectiveBench(kind=kind, payload_bytes=n_elems * itemsize,
                           axis=axis, axis_size=axis_size,
                           hlo_bytes=stats.total_bytes,
                           modeled_bytes=cost.bytes_on_wire,
                           modeled_time_s=cost.time_s,
                           effective_gbs=eff)


def bandwidth_curve(mesh, kind: str, axis: str,
                    sizes_bytes: Optional[List[int]] = None
                    ) -> List[CollectiveBench]:
    """Effective bandwidth vs message size — the ch.5 Figure analogue: small
    messages are alpha-bound (latency), large ones beta-bound (bandwidth)."""
    sizes = sizes_bytes or [2 ** p for p in range(12, 28, 2)]
    return [bench_collective(mesh, kind, s, axis) for s in sizes]
