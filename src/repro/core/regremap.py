"""Conflict-free register remapping for FFMA accumulation tiles (paper Ch.1).

This module implements, as an *algorithm*, what the paper did by hand: given
the register slices of an 8x8 (or any m x n) outer-product accumulation tile,
produce an instruction order, accumulator register mapping and reuse-flag
assignment with zero register-bank conflicts and maximal reuse-cache hits.

Strategy (generalizes the paper's hand schedule in Table 1.1, right column):

* Walk B in 64-bit aligned register *pairs* — the two registers of a pair
  live in one bank entry and share one operand-slot reuse cache, so
  alternating them in slot 1 costs a single bank read per pair-group.
* Serpentine over A rows (forward, then backward for the next B pair) so the
  A operand stays in the slot-0 reuse cache across the turn.
* Choose each accumulator C[i][j] from the opposite bank whenever A[i] and
  B[j] share a bank, so even reuse-cache-cold instructions cannot assemble
  three same-bank reads.

The result is validated by the issue-cycle model in ``regbank`` under *both*
reuse-lifetime semantics, and property-tested for random register slices in
``tests/test_regremap.py``.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.core.hwmodel import RegisterFileSpec
from repro.core.regbank import FFMA, bank, instruction_cycles, pair_of


def _b_pair_groups(b_regs: Sequence[int]) -> List[List[int]]:
    """Group B registers into aligned 64-bit pairs where possible."""
    groups: Dict[int, List[int]] = {}
    for r in b_regs:
        groups.setdefault(pair_of(r), []).append(r)
    return [sorted(g) for _, g in sorted(groups.items())]


def assign_accumulators(spec: RegisterFileSpec, a_regs: Sequence[int],
                        b_regs: Sequence[int],
                        c_pool: Sequence[int]) -> Dict[Tuple[int, int], int]:
    """Pick an accumulator register for every (a, b) product such that no
    product has all three registers in one bank."""
    by_bank: Dict[int, List[int]] = {}
    for r in sorted(c_pool, reverse=True):
        by_bank.setdefault(bank(spec, r), []).append(r)
    mapping: Dict[Tuple[int, int], int] = {}
    # Constrained products first (a and b share a bank).
    items = sorted(((a, b) for a in a_regs for b in b_regs),
                   key=lambda ab: bank(spec, ab[0]) != bank(spec, ab[1]))
    for a, b in items:
        if bank(spec, a) == bank(spec, b):
            forbidden = bank(spec, a)
            choices = [bk for bk in by_bank if bk != forbidden and by_bank[bk]]
        else:
            choices = [bk for bk in by_bank if by_bank[bk]]
        if not choices:
            raise ValueError("accumulator pool cannot avoid conflicts")
        # Keep banks balanced so later constrained picks stay feasible.
        bk = max(choices, key=lambda k: len(by_bank[k]))
        mapping[(a, b)] = by_bank[bk].pop()
    return mapping


def remap_tile(spec: RegisterFileSpec, a_regs: Sequence[int],
               b_regs: Sequence[int], c_pool: Sequence[int]) -> List[FFMA]:
    """Produce the optimized FFMA schedule for C[i][j] += A[i] * B[j]."""
    acc = assign_accumulators(spec, a_regs, b_regs, c_pool)
    schedule: List[Tuple[int, int]] = []           # (a, b) issue order
    rows = list(a_regs)
    for gi, group in enumerate(_b_pair_groups(b_regs)):
        row_iter = rows if gi % 2 == 0 else rows[::-1]
        for a in row_iter:
            for b in group:
                schedule.append((a, b))
    instrs: List[FFMA] = []
    for k, (a, b) in enumerate(schedule):
        nxt = schedule[k + 1] if k + 1 < len(schedule) else None
        # Flag an operand for reuse when the next instruction reads the same
        # 64-bit pair in the same slot (valid under both lifetime semantics).
        fa = nxt is not None and pair_of(nxt[0]) == pair_of(a)
        fb = nxt is not None and pair_of(nxt[1]) == pair_of(b)
        c = acc[(a, b)]
        instrs.append(FFMA(c, (a, b, c), (fa, fb, False)))
    return instrs


def conflict_free(spec: RegisterFileSpec, instrs: Sequence[FFMA]) -> bool:
    for mode in ("pair", "next"):
        _, stalls = instruction_cycles(spec, instrs, reuse_mode=mode)
        if stalls:
            return False
    return True
