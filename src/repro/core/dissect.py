"""Full-device dissection orchestrator — reproduces paper Table 3.1.

Given only black-box access to a device model (``simulator.MemoryHierarchy``
plus the register/constant/shared-memory probes), recover the geometry the
paper published, then diff against the published spec. The benchmark
``benchmarks/table_3_1.py`` runs this for all five GPUs of Table 3.1.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import hwmodel, pchase, regbank, simulator

KiB = 1024
MiB = 1024 * KiB


@dataclasses.dataclass
class DissectionReport:
    gpu: str
    l1: pchase.DiscoveredCache
    l2: pchase.DiscoveredCache
    latency: pchase.LatencyClasses
    tlbs: List[pchase.DiscoveredTLB]
    reg_banks: int
    reg_bank_width: int
    smem_latency_curve: Dict[int, float]
    matches: Dict[str, bool] = dataclasses.field(default_factory=dict)


def dissect_l1(spec: hwmodel.GPUSpec,
               l1_size_override: Optional[int] = None) -> pchase.DiscoveredCache:
    hier = simulator.build_hierarchy(spec, l1_size_override=l1_size_override)
    classes = pchase.latency_classes(hier, span=4 * KiB)
    size = pchase.detect_size(hier, lo=2 * KiB, hi=512 * KiB, stride=8)
    line = pchase.detect_line(hier, size)
    # L1-miss latency threshold: where L1 and L2 share a line size, the cold
    # scan of Fig 3.2 never shows an L2 hit, so probe it by thrashing L1.
    l2_hit = pchase.measure_next_level_latency(hier, size)
    ways = pchase.detect_ways(hier, size, miss_threshold=l2_hit,
                              max_ways=4096)
    sets = max(1, size // (line * ways))
    nominal = l1_size_override or spec.l1d.size
    policy = pchase.detect_policy(size, nominal)
    return pchase.DiscoveredCache(size=size, line=line, ways=ways, sets=sets,
                                  policy=policy, hit_latency=classes.l1_hit)


def dissect_l2(spec: hwmodel.GPUSpec) -> pchase.DiscoveredCache:
    # The paper bypasses L1 (ld.global.cg) so L2 is visible.
    hier = simulator.build_hierarchy(spec, l1_enabled=False)
    line = pchase.detect_line(hier, 512 * KiB)
    hit = pchase.measure_hit_latency(hier, 8)
    miss_threshold = spec.global_latency_l2_miss or hit + 100
    size = pchase.detect_size(hier, lo=256 * KiB, hi=16 * MiB, stride=line,
                              resolution=64 * KiB)
    ways = pchase.detect_ways(hier, size, miss_threshold=miss_threshold,
                              max_ways=64)
    sets = max(1, size // (line * ways))
    return pchase.DiscoveredCache(size=size, line=line, ways=ways, sets=sets,
                                  policy=pchase.detect_policy(size, spec.l2d.size),
                                  hit_latency=hit)


def dissect_tlbs(spec: hwmodel.GPUSpec) -> List[pchase.DiscoveredTLB]:
    # The paper's TLB sweep chases global memory with page-entry strides;
    # power-of-two strides alias physically-indexed L2 sets, so steady state
    # is all L2 misses — modeled by disabling the caches (see simulator).
    hier = simulator.build_hierarchy(spec, l1_enabled=False,
                                     caches_enabled=False)
    return pchase.dissect_tlbs(
        hier,
        page_candidates_l1=[64 * KiB, 128 * KiB, 256 * KiB, 512 * KiB,
                            1 * MiB, 2 * MiB, 4 * MiB],
        page_candidates_l2=[2 * MiB, 4 * MiB, 8 * MiB, 16 * MiB, 32 * MiB,
                            64 * MiB],
        max_pages=600)


def dissect_registers(spec: hwmodel.GPUSpec):
    rf = spec.regfile

    def probe2(pair):
        return regbank.ffma_probe(rf, pair)

    def probe3(triple):
        return regbank.ffma_probe(rf, triple)

    return regbank.dissect_register_banks(probe2, probe3)


def dissect(spec: hwmodel.GPUSpec, include_l2: bool = True,
            include_tlb: bool = True) -> DissectionReport:
    l1 = dissect_l1(spec)
    hier = simulator.build_hierarchy(spec)
    classes = pchase.latency_classes(hier, span=64 * KiB)
    l2 = dissect_l2(spec) if include_l2 else None
    tlbs = dissect_tlbs(spec) if include_tlb else []
    banks, width = dissect_registers(spec)
    smem = {s: simulator.smem_latency(spec, s) for s in
            (1, 2, 4, 8, 16, 32)}
    report = DissectionReport(gpu=spec.name, l1=l1, l2=l2, latency=classes,
                              tlbs=tlbs, reg_banks=banks,
                              reg_bank_width=width, smem_latency_curve=smem)
    report.matches = compare_to_spec(report, spec)
    return report


def _expected_effective_l1(spec: hwmodel.GPUSpec) -> int:
    """Nominal size minus the non-LRU reserved region (Table 3.3)."""
    reserved = simulator.volta_reserved_ways(spec)
    return spec.l1d.size - reserved * (spec.l1d.sets or 1) * spec.l1d.line


def compare_to_spec(rep: DissectionReport,
                    spec: hwmodel.GPUSpec) -> Dict[str, bool]:
    out = {}
    out["l1_size"] = rep.l1.size == _expected_effective_l1(spec)
    out["l1_line"] = rep.l1.line == spec.l1d.line
    out["l1_sets"] = (spec.l1d.sets is None) or rep.l1.sets == spec.l1d.sets
    out["l1_hit_latency"] = rep.l1.hit_latency == (spec.l1d.hit_latency or 0)
    out["l1_policy"] = ((rep.l1.policy == "non-LRU")
                        == (spec.l1d.policy == "prio"))
    if rep.l2 is not None:
        out["l2_size"] = abs(rep.l2.size - spec.l2d.size) <= spec.l2d.size // 16
        out["l2_line"] = rep.l2.line == spec.l2d.line
        out["l2_hit_latency"] = rep.l2.hit_latency == (spec.l2d.hit_latency or 0)
        if spec.l2d.ways:
            out["l2_ways"] = rep.l2.ways == spec.l2d.ways
    # Only the classes the paper published for this GPU are checkable; the
    # Fig 3.2 L2-hit class is visible in a cold scan only when the L2 line is
    # wider than the L1 line (V100).
    checks = [rep.latency.l1_hit == (spec.l1d.hit_latency or 0)]
    if spec.l2d.line > spec.l1d.line:
        checks.append(rep.latency.l2_hit == (spec.l2d.hit_latency or 0))
    if spec.global_latency_l2_miss:
        checks.append(rep.latency.dram == spec.global_latency_l2_miss)
    if spec.global_latency_cold:
        checks.append(rep.latency.cold == spec.global_latency_cold)
    out["latency_classes"] = all(checks)
    if rep.tlbs:
        out["l1_tlb"] = (rep.tlbs[0].page_entry == spec.l1_tlb.page_entry
                         and rep.tlbs[0].coverage == spec.l1_tlb.coverage)
        out["l2_tlb"] = (rep.tlbs[1].page_entry == spec.l2_tlb.page_entry
                         and rep.tlbs[1].coverage == spec.l2_tlb.coverage)
    out["reg_banks"] = rep.reg_banks == spec.regfile.banks
    out["reg_bank_width"] = rep.reg_bank_width == spec.regfile.bank_width_bits
    return out


def table_3_3(spec: hwmodel.GPUSpec = hwmodel.V100) -> Dict[int, int]:
    """Reproduce Table 3.3: detected L1 size vs configured shared memory."""
    out = {}
    for smem_kib, l1_kib in ((0, 128), (64, 64), (96, 32)):
        rep = dissect_l1(spec, l1_size_override=l1_kib * KiB)
        out[smem_kib] = rep.size
    return out
