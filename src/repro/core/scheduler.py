"""Warp-scheduler model — paper §2.2, Table 2.1.

The Volta SM is split into four processing blocks; a warp is pinned to block
``warp_id % 4``. The paper proves the mapping by running FFMA streams on warp
pairs: co-resident pairs (same block) achieve ~42 GFLOPS, split pairs ~66.

Model: each warp sustains an empirical issue rate of ``R_W`` FFMA
instructions/cycle (from the paper's 66.04 GFLOPS for two independent warps
at 1380 MHz: 66.04e9 / 1.38e9 / 64 flops / 2 warps = 0.374); each processing
block's FP32 pipe executes one 32-lane FFMA every 2 cycles (16 FP32 units),
capping co-resident warps at 0.5 instructions/cycle combined.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

FLOPS_PER_INSTR = 64            # 32 lanes x fused multiply-add
R_W = 0.374                     # per-warp sustained issue rate (instr/cycle)
PIPE_RATE = 0.5                 # per-block FP32 pipe (instr/cycle)
N_BLOCKS = 4


def scheduler_id(warp_id: int) -> int:
    """Paper §2.2: scheduler_id = warp_id % 4."""
    return warp_id % N_BLOCKS


def pair_throughput_gflops(warp_a: int, warp_b: int,
                           clock_mhz: float = 1380.0) -> float:
    """Aggregate FFMA throughput of two active warps (Table 2.1)."""
    per_block: Dict[int, float] = {}
    for w in (warp_a, warp_b):
        blk = scheduler_id(w)
        per_block[blk] = per_block.get(blk, 0.0) + R_W
    instr_rate = sum(min(r, PIPE_RATE) for r in per_block.values())
    return instr_rate * FLOPS_PER_INSTR * clock_mhz * 1e6 / 1e9


def table_2_1(clock_mhz: float = 1380.0) -> Dict[Tuple[int, int], float]:
    """Reproduce Table 2.1: warp A in 0..3, warp B in 4..7."""
    return {(a, b): pair_throughput_gflops(a, b, clock_mhz)
            for a in range(4) for b in range(4, 8)}


def min_threads_to_saturate() -> int:
    """Paper §2.2 conclusion: at least 128 threads (one warp per processing
    block) are required to engage every FP32 pipe."""
    return N_BLOCKS * 32


# Paper Table 2.1 measured values (GFLOPS), for benchmark comparison.
PAPER_TABLE_2_1 = {
    (0, 4): 42.27, (1, 4): 66.05, (2, 4): 66.04, (3, 4): 65.29,
    (0, 5): 66.05, (1, 5): 41.98, (2, 5): 66.04, (3, 5): 66.04,
    (0, 6): 66.02, (1, 6): 66.04, (2, 6): 42.06, (3, 6): 66.04,
    (0, 7): 66.04, (1, 7): 66.04, (2, 7): 66.02, (3, 7): 42.08,
}
