"""Register-file bank model and the Ch.1 FFMA case study (Table 1.1).

The paper's headline demonstration: NVCC 9.0's register mapping for an 8x8
FFMA accumulation tile suffers register-bank conflicts that hand-written
machine code avoids, worth +15.4% measured on a V100 (132.05 -> 152.43
GFLOPS/SM at 128 threads).

Model facts (paper §2.1, §3.5):
  * Volta: 2 banks, 64-bit wide; ``bank(r) = r % 2``. An FFMA stalls only if
    all three source reads hit one bank (3 x 32b > 64b/cycle).
  * Pascal/Maxwell: 4 banks, 32-bit wide; ``bank(r) = r % 4``; two reads from
    one bank already stall.
  * 4 operand-slot reuse caches, 8 bytes each: a flagged read caches the full
    64-bit bank entry (the aligned even/odd register *pair*), so later reads
    of either register of the pair in the same slot skip the bank. This
    pair-width is exactly why the paper's hand mapping interleaves
    R80/R81 (one aligned pair) in one slot.

Reuse-lifetime semantics are not fully documented; we support two variants
and report both (see EXPERIMENTS.md):
  * ``pair``  — cache persists until a flagged read of a different pair
                replaces it (hardware-plausible given the 8-byte slots).
  * ``next``  — a flag only serves the immediately following instruction.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import List, Optional, Sequence, Tuple

from repro.core.hwmodel import RegisterFileSpec


@dataclasses.dataclass(frozen=True)
class FFMA:
    dst: int
    srcs: Tuple[int, int, int]          # operand slots 0..2
    reuse: Tuple[bool, bool, bool]

    def __str__(self):
        ops = ", ".join(f"R{r}{'.reuse' if f else ''}"
                        for r, f in zip(self.srcs, self.reuse))
        return f"FFMA R{self.dst}, {ops}, R{self.dst};"


_INSTR_RE = re.compile(
    r"FFMA\s+R(\d+),\s*R(\d+)(\.reuse)?,\s*R(\d+)(\.reuse)?,\s*R(\d+)(\.reuse)?")


def parse_listing(text: str) -> List[FFMA]:
    out = []
    for line in text.strip().splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        d, a, fa, b, fb, c, fc = m.groups()
        out.append(FFMA(int(d), (int(a), int(b), int(c)),
                        (bool(fa), bool(fb), bool(fc))))
    return out


def bank(spec: RegisterFileSpec, reg: int) -> int:
    return reg % spec.banks


def reads_per_bank_per_cycle(spec: RegisterFileSpec) -> int:
    return spec.bank_width_bits // 32


def pair_of(reg: int) -> int:
    """64-bit-aligned register pair index (R2k,R2k+1 share a bank entry)."""
    return reg // 2


def instruction_cycles(spec: RegisterFileSpec, instrs: Sequence[FFMA],
                       reuse_mode: str = "pair") -> Tuple[int, int]:
    """Issue-cycle model for an FFMA stream.

    Returns (total_cycles, conflict_stalls). Each instruction takes 1 issue
    cycle plus ``ceil(reads_on_worst_bank / bank_width) - 1`` stall cycles.
    """
    assert reuse_mode in ("pair", "next")
    per_cycle = reads_per_bank_per_cycle(spec)
    cache: List[Optional[int]] = [None] * 4     # per-slot cached pair (or reg)
    stalls = 0
    for ins in instrs:
        next_cache = list(cache) if reuse_mode == "pair" else [None] * 4
        reads = []
        for slot, (reg, flag) in enumerate(zip(ins.srcs, ins.reuse)):
            key = pair_of(reg) if reuse_mode == "pair" else reg
            if cache[slot] is not None and cache[slot] == key:
                hit = True
            else:
                hit = False
                reads.append(reg)
            if flag:
                next_cache[slot] = key
            elif reuse_mode == "next":
                next_cache[slot] = None
        cache = next_cache
        per_bank = Counter(bank(spec, r) for r in reads)
        if per_bank:
            worst = max(per_bank.values())
            stalls += max(0, -(-worst // per_cycle) - 1)
    return len(instrs) + stalls, stalls


def gflops_per_sm(spec: RegisterFileSpec, instrs: Sequence[FFMA],
                  clock_mhz: float, warps: int = 4,
                  issue_rate: float = 0.4316,
                  reuse_mode: str = "next") -> float:
    """Modeled FFMA throughput for ``warps`` warps, one per processing block.

    ``issue_rate`` is the per-warp sustained issue rate calibrated so the
    conflict-free Table 1.1 kernel reproduces the paper's measured 152.43
    GFLOPS/SM (0.4316 instr/cycle/warp at 1380 MHz); conflict stalls then
    *predict* the NVCC kernel's throughput (paper measured 132.05; the
    prediction error is reported in benchmarks/table_1_1.py).
    """
    cycles, _ = instruction_cycles(spec, instrs, reuse_mode)
    eff = issue_rate * len(instrs) / cycles
    flops_per_instr = 32 * 2                    # 32 lanes x FMA
    return warps * eff * flops_per_instr * clock_mhz * 1e6 / 1e9


# ----------------------------------------------------------------------------
# Fig 3.8 probe: discover bank structure by sweeping one source register.
# ----------------------------------------------------------------------------

def ffma_probe(spec: RegisterFileSpec, srcs: Tuple[int, ...]) -> int:
    """Elapsed cycles of one probe instruction reading ``srcs`` (no reuse
    flags) — the measurement primitive of Fig 3.8. Two-source probes model
    FADD-like instructions, three-source probes model FFMA."""
    per_cycle = reads_per_bank_per_cycle(spec)
    per_bank = Counter(bank(spec, r) for r in srcs)
    worst = max(per_bank.values())
    return 1 + max(0, -(-worst // per_cycle) - 1)


def conflict_sweep(probe3, fixed: Tuple[int, int],
                   rx_range: Sequence[int]) -> List[int]:
    """Fig 3.8: elapsed cycles of ``FFMA R6, R<fixed0>, R<fixed1>, RX``
    while sweeping RX."""
    return [probe3((fixed[0], fixed[1], rx)) for rx in rx_range]


def dissect_register_banks(probe2, probe3) -> Tuple[int, int]:
    """Infer (banks, bank_width_bits) purely from conflict timings.

    ``probe2((a, b)) -> cycles`` times a two-source instruction (FADD-like);
    ``probe3((a, b, c)) -> cycles`` a three-source one (FFMA), as in Fig 3.8.

    32-bit banks: two same-bank reads already stall, so the smallest operand
    spacing ``d`` with ``probe2((r, r+d))`` elevated is the bank count.
    64-bit banks: no two-read probe ever stalls; three same-bank reads do,
    so the smallest ``d`` with ``probe3((r, r+d, r+2d))`` elevated is the
    bank count.
    """
    base2 = probe2((96, 97))
    for d in (1, 2, 4, 8, 16):
        if probe2((96, 96 + d)) > base2:
            return d, 32
    # No 2-read conflict -> banks are (at least) 64-bit wide.
    base3 = probe3((96, 97, 99))
    for d in (1, 2, 4, 8, 16):
        if probe3((96, 96 + d, 96 + 2 * d)) > base3:
            return d, 64
    return 1, 128


def _pattern_period(pattern: Sequence[int]) -> int:
    n = len(pattern)
    for p in range(1, n // 2 + 1):
        if all(pattern[i] == pattern[i % p] for i in range(n)):
            if any(pattern[:p]):
                return p
    return 0


# ----------------------------------------------------------------------------
# Table 1.1 listings (transcribed; OCR artifacts in the source normalized).
# ----------------------------------------------------------------------------

NVCC_LISTING = """
FFMA R16, R12, R80, R16;
FFMA R17, R80.reuse, R13, R17;
FFMA R18, R80.reuse, R14, R18;
FFMA R19, R80, R15, R19;
FFMA R20, R80.reuse, R8, R20;
FFMA R21, R80.reuse, R9, R21;
FFMA R22, R80.reuse, R10, R22;
FFMA R23, R80, R11, R23;
FFMA R24, R12, R81.reuse, R24;
FFMA R25, R13, R81, R25;
FFMA R26, R14, R81.reuse, R26;
FFMA R27, R15, R81.reuse, R27;
FFMA R28, R8, R81.reuse, R28;
FFMA R29, R9, R81.reuse, R29;
FFMA R30, R10, R81.reuse, R30;
FFMA R31, R11, R81, R31;
FFMA R32, R12, R82.reuse, R32;
FFMA R33, R13, R82.reuse, R33;
FFMA R34, R14, R82.reuse, R34;
FFMA R35, R15, R82.reuse, R35;
FFMA R36, R8, R82.reuse, R36;
FFMA R37, R9, R82, R37;
FFMA R38, R10, R82.reuse, R38;
FFMA R39, R11, R82, R39;
FFMA R40, R12, R83.reuse, R40;
FFMA R41, R13, R83.reuse, R41;
FFMA R42, R14, R83.reuse, R42;
FFMA R43, R15, R83, R43;
FFMA R44, R8, R83.reuse, R44;
FFMA R45, R9, R83.reuse, R45;
FFMA R46, R10, R83.reuse, R46;
FFMA R47, R11, R83, R47;
FFMA R48, R12, R4.reuse, R48;
FFMA R49, R13, R4, R49;
FFMA R50, R14, R4.reuse, R50;
FFMA R51, R15, R4.reuse, R51;
FFMA R52, R8, R4.reuse, R52;
FFMA R53, R9, R4.reuse, R53;
FFMA R54, R10, R4.reuse, R54;
FFMA R55, R11, R4, R55;
FFMA R56, R12, R5.reuse, R56;
FFMA R57, R13, R5.reuse, R57;
FFMA R58, R14, R5.reuse, R58;
FFMA R59, R15, R5.reuse, R59;
FFMA R60, R8, R5.reuse, R60;
FFMA R61, R9, R5, R61;
FFMA R62, R10, R5.reuse, R62;
FFMA R63, R11, R5, R63;
FFMA R64, R12, R6.reuse, R64;
FFMA R65, R13, R6.reuse, R65;
FFMA R66, R14, R6.reuse, R66;
FFMA R67, R15, R6, R67;
FFMA R68, R8, R6.reuse, R68;
FFMA R69, R9, R6.reuse, R69;
FFMA R70, R10, R6.reuse, R70;
FFMA R71, R11, R6, R71;
FFMA R72, R12, R7.reuse, R72;
FFMA R73, R13, R7, R73;
FFMA R74, R14, R7.reuse, R74;
FFMA R75, R15, R7.reuse, R75;
FFMA R76, R8, R7.reuse, R76;
FFMA R77, R9, R7.reuse, R77;
FFMA R78, R10, R7.reuse, R78;
FFMA R79, R11, R7, R79;
"""

IMPROVED_LISTING = """
FFMA R17, R12.reuse, R80.reuse, R17;
FFMA R16, R12, R81.reuse, R16;
FFMA R25, R13.reuse, R80.reuse, R25;
FFMA R24, R13, R81.reuse, R24;
FFMA R33, R14.reuse, R80.reuse, R33;
FFMA R32, R14, R81.reuse, R32;
FFMA R41, R15.reuse, R80.reuse, R41;
FFMA R40, R15, R81.reuse, R40;
FFMA R49, R8.reuse, R80.reuse, R49;
FFMA R48, R8, R81.reuse, R48;
FFMA R57, R9.reuse, R80.reuse, R57;
FFMA R56, R9, R81.reuse, R56;
FFMA R65, R10.reuse, R80.reuse, R65;
FFMA R64, R10.reuse, R81.reuse, R64;
FFMA R73, R11.reuse, R80, R73;
FFMA R72, R11.reuse, R81, R72;
FFMA R75, R11.reuse, R82.reuse, R75;
FFMA R74, R11, R83.reuse, R74;
FFMA R67, R10.reuse, R82.reuse, R67;
FFMA R66, R10, R83.reuse, R66;
FFMA R59, R9.reuse, R82.reuse, R59;
FFMA R58, R9, R83.reuse, R58;
FFMA R51, R8.reuse, R82.reuse, R51;
FFMA R50, R8, R83.reuse, R50;
FFMA R43, R15.reuse, R82.reuse, R43;
FFMA R42, R15, R83.reuse, R42;
FFMA R35, R14.reuse, R82.reuse, R35;
FFMA R34, R14, R83.reuse, R34;
FFMA R27, R13.reuse, R82.reuse, R27;
FFMA R26, R13.reuse, R83.reuse, R26;
FFMA R19, R12.reuse, R82, R19;
FFMA R18, R12.reuse, R83, R18;
FFMA R21, R12.reuse, R4.reuse, R21;
FFMA R20, R12, R5.reuse, R20;
FFMA R29, R13.reuse, R4.reuse, R29;
FFMA R28, R13, R5.reuse, R28;
FFMA R37, R14.reuse, R4.reuse, R37;
FFMA R36, R14, R5.reuse, R36;
FFMA R45, R15.reuse, R4.reuse, R45;
FFMA R44, R15, R5.reuse, R44;
FFMA R53, R8.reuse, R4.reuse, R53;
FFMA R52, R8, R5.reuse, R52;
FFMA R61, R9.reuse, R4.reuse, R61;
FFMA R60, R9, R5.reuse, R60;
FFMA R69, R10.reuse, R4.reuse, R69;
FFMA R68, R10.reuse, R5.reuse, R68;
FFMA R77, R11.reuse, R4, R77;
FFMA R76, R11.reuse, R5, R76;
FFMA R79, R11.reuse, R6.reuse, R79;
FFMA R78, R11, R7.reuse, R78;
FFMA R71, R10.reuse, R6.reuse, R71;
FFMA R70, R10, R7.reuse, R70;
FFMA R63, R9.reuse, R6.reuse, R63;
FFMA R62, R9, R7.reuse, R62;
FFMA R55, R8.reuse, R6.reuse, R55;
FFMA R54, R8, R7.reuse, R54;
FFMA R47, R15.reuse, R6.reuse, R47;
FFMA R46, R15, R7.reuse, R46;
FFMA R39, R14.reuse, R6.reuse, R39;
FFMA R38, R14, R7.reuse, R38;
FFMA R31, R13.reuse, R6.reuse, R31;
FFMA R30, R13.reuse, R7.reuse, R30;
FFMA R23, R12.reuse, R6, R23;
FFMA R22, R12.reuse, R7, R22;
"""

A_REGS = (12, 13, 14, 15, 8, 9, 10, 11)     # row slice of matrix A
B_REGS = (80, 81, 82, 83, 4, 5, 6, 7)       # column slice of matrix B

PAPER_GFLOPS_NVCC = 132.05
PAPER_GFLOPS_IMPROVED = 152.43


def tile_coverage(instrs: Sequence[FFMA]) -> bool:
    """Check an FFMA stream computes every (a, b) product of the 8x8 tile
    exactly once, with a consistent accumulator per product."""
    seen = {}
    for ins in instrs:
        operands = set(ins.srcs) - {ins.dst}
        a = operands & set(A_REGS)
        b = operands & set(B_REGS)
        if len(a) != 1 or len(b) != 1:
            return False
        key = (a.pop(), b.pop())
        if key in seen:
            return False
        seen[key] = ins.dst
    return len(seen) == 64 and len(set(seen.values())) == 64
