"""Microbenchmark-informed kernel/sharding tuning (the paper's Ch.1 thesis,
TPU-idiomatic).

The paper's demonstration is that measured microarchitectural parameters
(register banks, reuse caches) let a human beat the compiler's schedule.
The TPU transfer is mechanical rather than manual: the dissected hardware
model (VMEM capacity, MXU tile, HBM/ICI bandwidths — the quantities probed
by ``benchmarks/tpu_*.py``) drives an analytical search over Pallas
BlockSpec shapes and over sharding layouts.

The GEMM cost model uses the classic blocked-matmul traffic formula: with
C-stationary accumulation and (bm, bk, bn) tiles, A is streamed N/bn times,
B M/bm times and C once, so tile choice trades VMEM footprint against HBM
traffic — exactly the working-set-vs-capacity trade the paper's ch.3
geometry tables exist to inform.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple

from repro.core import hwmodel


@dataclasses.dataclass(frozen=True)
class GemmProblem:
    m: int
    k: int
    n: int
    in_bytes: int = 2          # bf16
    acc_bytes: int = 4         # fp32 accumulator


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    bm: int
    bk: int
    bn: int

    def vmem_bytes(self, p: GemmProblem) -> int:
        # Double-buffered input tiles + resident fp32 accumulator tile.
        return (2 * (self.bm * self.bk + self.bk * self.bn) * p.in_bytes
                + self.bm * self.bn * p.acc_bytes)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def mxu_efficiency(dim_m: int, dim_k: int, dim_n: int,
                   tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> float:
    """Fraction of MXU work that is useful for a (m,k,n) matmul tile — the
    padding-cliff law that ``benchmarks/tpu_mxu.py`` dissects: each dim pads
    to the systolic edge (lanes) or the sublane pack."""
    d = tpu.mxu_dim
    pad_m = _ceil_div(dim_m, 8) * 8          # sublane granularity
    pad_k = _ceil_div(dim_k, d) * d
    pad_n = _ceil_div(dim_n, d) * d
    useful = dim_m * dim_k * dim_n
    padded = pad_m * pad_k * pad_n
    return useful / padded


def gemm_cost(p: GemmProblem, c: GemmConfig,
              tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> Tuple[float, dict]:
    """Modeled execution time (seconds) of the blocked GEMM, plus terms."""
    flops = 2.0 * p.m * p.k * p.n
    eff = mxu_efficiency(min(c.bm, p.m), min(c.bk, p.k), min(c.bn, p.n), tpu)
    compute_s = flops / (tpu.peak_bf16_flops * eff)
    # HBM traffic in bytes (C-stationary): A x (N/bn), B x (M/bm), C once.
    a_reads = _ceil_div(p.n, c.bn)
    b_reads = _ceil_div(p.m, c.bm)
    traffic = (p.m * p.k * a_reads + p.k * p.n * b_reads) * p.in_bytes \
        + p.m * p.n * p.in_bytes
    memory_s = traffic / tpu.hbm_bandwidth
    t = max(compute_s, memory_s)
    return t, {"compute_s": compute_s, "memory_s": memory_s,
               "traffic_bytes": traffic, "mxu_efficiency": eff}


def candidate_blocks(p: GemmProblem,
                     tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU,
                     vmem_fraction: float = 0.5) -> List[GemmConfig]:
    """Hardware-aligned candidate tiles that fit the VMEM budget."""
    budget = int(tpu.vmem_bytes * vmem_fraction)
    dims = [128, 256, 512, 1024, 2048]
    out = []
    for bm in dims:
        if bm > max(p.m, 128):
            continue
        for bk in dims:
            if bk > max(p.k, 128):
                continue
            for bn in dims:
                if bn > max(p.n, 128):
                    continue
                c = GemmConfig(bm, bk, bn)
                if c.vmem_bytes(p) <= budget:
                    out.append(c)
    return out or [GemmConfig(128, 128, 128)]


def choose_gemm_block(p: GemmProblem,
                      tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU
                      ) -> Tuple[GemmConfig, dict]:
    """Pick the minimum-modeled-time tile (the autotuner's decision)."""
    best, best_t, best_terms = None, float("inf"), None
    for c in candidate_blocks(p, tpu):
        t, terms = gemm_cost(p, c, tpu)
        if t < best_t:
            best, best_t, best_terms = c, t, terms
    return best, dict(best_terms, time_s=best_t)


NAIVE_BLOCK = GemmConfig(128, 128, 128)


def tuning_gain(p: GemmProblem,
                tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> dict:
    """Naive-vs-tuned comparison — the Ch.1 '+15.4%' analogue, reported by
    ``benchmarks/fig_4_8.py`` and exercised e2e in examples/autotune_gemm.py."""
    t_naive, naive_terms = gemm_cost(p, NAIVE_BLOCK, tpu)
    cfg, terms = choose_gemm_block(p, tpu)
    return {
        "naive": {"config": dataclasses.astuple(NAIVE_BLOCK), **naive_terms,
                  "time_s": t_naive},
        "tuned": {"config": dataclasses.astuple(cfg), **terms},
        "speedup": t_naive / terms["time_s"],
    }


# ----------------------------------------------------------------------------
# Sharding selection for one weight-stationary matmul layer.
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingChoice:
    name: str                   # "dp", "tp_col", "tp_row", "dp+tp"
    time_s: float
    compute_s: float
    collective_s: float


def choose_layer_sharding(batch_tokens: int, d_in: int, d_out: int,
                          data_axis: int, model_axis: int,
                          in_bytes: int = 2,
                          tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU
                          ) -> List[ShardingChoice]:
    """Rank standard layouts for out = x @ W by modeled step time.

    dp: batch sharded, W replicated (grad all-reduce amortized elsewhere).
    tp_col: W column-sharded -> output sharded, no comm until next layer.
    tp_row: W row-sharded -> partial sums all-reduced.
    """
    from repro.core import interconnect

    chips = data_axis * model_axis
    flops = 2.0 * batch_tokens * d_in * d_out
    out: List[ShardingChoice] = []

    def add(name, shard_factor, coll_kind, coll_payload, axis):
        comp = flops / (chips * tpu.peak_bf16_flops) \
            if shard_factor == chips else flops / (shard_factor * tpu.peak_bf16_flops)
        coll = interconnect.collective_time(coll_kind, coll_payload, axis,
                                            tpu).time_s if coll_payload else 0.0
        out.append(ShardingChoice(name, comp + coll, comp, coll))

    tokens_local = batch_tokens / data_axis
    # dp only: compute split over data axis, none over model.
    add("dp", data_axis, None, 0, 1)
    # tp_col: activations all-gathered next layer; charge the gather here.
    add("tp_col", chips, "all_gather",
        tokens_local * d_out * in_bytes, model_axis)
    # tp_row: partial-sum all-reduce of the output activations.
    add("tp_row", chips, "all_reduce",
        tokens_local * d_out * in_bytes, model_axis)
    out.sort(key=lambda s: s.time_s)
    return out
