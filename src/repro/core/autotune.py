"""Microbenchmark-informed kernel/sharding tuning (the paper's Ch.1 thesis,
TPU-idiomatic).

The paper's demonstration is that measured microarchitectural parameters
(register banks, reuse caches) let a human beat the compiler's schedule.
The TPU transfer is mechanical rather than manual: the dissected hardware
model (VMEM capacity, MXU tile, HBM/ICI bandwidths — the quantities probed
by ``benchmarks/tpu_*.py``) drives an analytical search over Pallas
BlockSpec shapes and over sharding layouts.

The GEMM cost model uses the classic blocked-matmul traffic formula: with
C-stationary accumulation and (bm, bk, bn) tiles, A is streamed N/bn times,
B M/bm times and C once, so tile choice trades VMEM footprint against HBM
traffic — exactly the working-set-vs-capacity trade the paper's ch.3
geometry tables exist to inform.

Serving-path cost constants
---------------------------

The serving cost models price fixed per-step costs with the constants
below. Each has a documented hand-set default (the reproducible
fallback) and — since the calibration pass (``core.calibrate``, run via
``python -m repro.launch.calibrate``) — a *measured* value probed on the
actual backend, persisted in the tuning cache under the ``calibrated:``
namespace and preferred by ``resolve_constants``:

===================  ========  ========================================
constant             default   measured by (``core.calibrate`` probe)
===================  ========  ========================================
``PAGE_LOOKUP_S``    5e-8 s    page-walk slope: ``flash_decode_paged``
                               vs contiguous ``flash_decode`` across a
                               page-table-size sweep, regressed per
                               visited K/V block
``CHUNK_DISPATCH_S`` 5e-6 s    per-chunk execute span of the chunked
                               prefill executable (telemetry spans,
                               compile-separated)
``PREFIX_HASH_S``    2e-6 s    timed blake2b digest + index probe per
                               page of tokens (``serve.paged``)
``NGRAM_DRAFT_S``    2e-6 s    timed ``NgramDraft.propose`` per drafted
                               token
``dispatch_s``       (none)    best-of-N tiny-kernel dispatch latency
                               (no default term — reporting baseline is
                               ``CHUNK_DISPATCH_S``)
``hbm_bandwidth``    TPUSpec   timed device copies per dtype at
                               serving-relevant sizes (stream rate)
===================  ========  ========================================

Every model/``choose_*`` entry point takes ``constants=`` (a
``ServeConstants``); None means the hand-set defaults, so existing
callers and committed bench cells are bit-for-bit unchanged. The
serving engine resolves once per construction via
``resolve_constants()``; ``REPRO_DEFAULT_CONSTANTS=1`` forces the
defaults for reproducibility.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
from typing import Iterable, List, Optional, Tuple

from repro.core import hwmodel


@dataclasses.dataclass(frozen=True)
class GemmProblem:
    m: int
    k: int
    n: int
    in_bytes: int = 2          # bf16
    acc_bytes: int = 4         # fp32 accumulator


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    bm: int
    bk: int
    bn: int

    def vmem_bytes(self, p: GemmProblem) -> int:
        # Double-buffered input tiles + resident fp32 accumulator tile.
        return (2 * (self.bm * self.bk + self.bk * self.bn) * p.in_bytes
                + self.bm * self.bn * p.acc_bytes)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def mxu_efficiency(dim_m: int, dim_k: int, dim_n: int,
                   tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> float:
    """Fraction of MXU work that is useful for a (m,k,n) matmul tile — the
    padding-cliff law that ``benchmarks/tpu_mxu.py`` dissects: each dim pads
    to the systolic edge (lanes) or the sublane pack."""
    d = tpu.mxu_dim
    pad_m = _ceil_div(dim_m, 8) * 8          # sublane granularity
    pad_k = _ceil_div(dim_k, d) * d
    pad_n = _ceil_div(dim_n, d) * d
    useful = dim_m * dim_k * dim_n
    padded = pad_m * pad_k * pad_n
    return useful / padded


def gemm_cost(p: GemmProblem, c: GemmConfig,
              tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> Tuple[float, dict]:
    """Modeled execution time (seconds) of the blocked GEMM, plus terms."""
    flops = 2.0 * p.m * p.k * p.n
    eff = mxu_efficiency(min(c.bm, p.m), min(c.bk, p.k), min(c.bn, p.n), tpu)
    compute_s = flops / (tpu.peak_bf16_flops * eff)
    # HBM traffic in bytes (C-stationary): A x (N/bn), B x (M/bm), C once.
    a_reads = _ceil_div(p.n, c.bn)
    b_reads = _ceil_div(p.m, c.bm)
    traffic = (p.m * p.k * a_reads + p.k * p.n * b_reads) * p.in_bytes \
        + p.m * p.n * p.in_bytes
    memory_s = traffic / tpu.hbm_bandwidth
    t = max(compute_s, memory_s)
    return t, {"compute_s": compute_s, "memory_s": memory_s,
               "traffic_bytes": traffic, "mxu_efficiency": eff}


def candidate_blocks(p: GemmProblem,
                     tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU,
                     vmem_fraction: float = 0.5) -> List[GemmConfig]:
    """Hardware-aligned candidate tiles that fit the VMEM budget."""
    budget = int(tpu.vmem_bytes * vmem_fraction)
    dims = [128, 256, 512, 1024, 2048]
    out = []
    for bm in dims:
        if bm > max(p.m, 128):
            continue
        for bk in dims:
            if bk > max(p.k, 128):
                continue
            for bn in dims:
                if bn > max(p.n, 128):
                    continue
                c = GemmConfig(bm, bk, bn)
                if c.vmem_bytes(p) <= budget:
                    out.append(c)
    return out or [GemmConfig(128, 128, 128)]


def choose_gemm_block(p: GemmProblem,
                      tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU
                      ) -> Tuple[GemmConfig, dict]:
    """Pick the minimum-modeled-time tile (the autotuner's decision)."""
    best, best_t, best_terms = None, float("inf"), None
    for c in candidate_blocks(p, tpu):
        t, terms = gemm_cost(p, c, tpu)
        if t < best_t:
            best, best_t, best_terms = c, t, terms
    return best, dict(best_terms, time_s=best_t)


NAIVE_BLOCK = GemmConfig(128, 128, 128)


def tuning_gain(p: GemmProblem,
                tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> dict:
    """Naive-vs-tuned comparison — the Ch.1 '+15.4%' analogue, reported by
    ``benchmarks/fig_4_8.py`` and exercised e2e in examples/autotune_gemm.py."""
    t_naive, naive_terms = gemm_cost(p, NAIVE_BLOCK, tpu)
    cfg, terms = choose_gemm_block(p, tpu)
    return {
        "naive": {"config": dataclasses.astuple(NAIVE_BLOCK), **naive_terms,
                  "time_s": t_naive},
        "tuned": {"config": dataclasses.astuple(cfg), **terms},
        "speedup": t_naive / terms["time_s"],
    }


# ----------------------------------------------------------------------------
# Attention block selection (flash prefill + flash decode).
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnProblem:
    """One flash-attention launch: ``batch * n_heads`` independent rows of a
    (sq x skv x head_dim) attention, causally masked or not.

    For flash *decode* set ``sq`` to the GQA group size (queries per KV head)
    and ``n_heads`` to ``n_kv_heads`` — that is exactly the row shape the
    decode kernel runs per (slot, kv head) grid step.
    """

    sq: int
    skv: int
    n_heads: int
    head_dim: int
    batch: int = 1
    causal: bool = True
    in_bytes: int = 2          # bf16


@dataclasses.dataclass(frozen=True)
class AttnBlock:
    block_q: int
    block_k: int

    def vmem_bytes(self, p: AttnProblem) -> int:
        # Double-buffered q/k/v input tiles + fp32 scores tile + the
        # m/l/acc online-softmax scratch that persists across K steps.
        d = p.head_dim
        return (2 * (self.block_q + 2 * self.block_k) * d * p.in_bytes
                + self.block_q * self.block_k * 4
                + self.block_q * (d + 2) * 4)


def _attn_visited_blocks(p: AttnProblem, c: AttnBlock) -> int:
    """Number of (q-block, k-block) grid steps the skipped-load causal grid
    actually visits — the quantity the scalar-prefetch map shrinks."""
    nq = _ceil_div(p.sq, c.block_q)
    nk = _ceil_div(p.skv, c.block_k)
    if not p.causal:
        return nq * nk
    off = p.skv - p.sq          # query i attends keys <= i + off
    total = 0
    for qi in range(nq):
        last_row = min(qi * c.block_q + c.block_q - 1, p.sq - 1)
        total += min(_ceil_div(last_row + off + 1, c.block_k), nk)
    return total


def attn_cost(p: AttnProblem, c: AttnBlock,
              tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU
              ) -> Tuple[float, dict]:
    """Modeled execution time (seconds) of the flash kernel, plus terms.

    Same three prices as ``gemm_cost``: MXU compute at the padded-tile
    efficiency, HBM streaming traffic, and the VMEM footprint acting as a
    hard feasibility constraint (handled by ``candidate_attn_blocks``).
    K/V re-stream once per *visited* q-block — the skipped-load causal grid
    (and the per-slot length clamp in flash decode) shows up as fewer
    visited blocks, hence less traffic and fewer MXU steps.
    """
    rows = p.batch * p.n_heads
    visited = _attn_visited_blocks(p, c)
    bq = min(c.block_q, p.sq)
    bk = min(c.block_k, p.skv)
    # Two matmuls per visited block: QK^T (bq,d)x(d,bk) and PV (bq,bk)x(bk,d).
    flops = rows * visited * 4.0 * bq * bk * p.head_dim
    eff = min(mxu_efficiency(bq, p.head_dim, bk, tpu),
              mxu_efficiency(bq, bk, p.head_dim, tpu))
    compute_s = flops / (tpu.peak_bf16_flops * eff)
    # HBM traffic: Q and O touched once per row; K/V streamed per visit.
    qo_bytes = rows * 2 * p.sq * p.head_dim * p.in_bytes
    kv_bytes = rows * visited * 2 * bk * p.head_dim * p.in_bytes
    memory_s = (qo_bytes + kv_bytes) / tpu.hbm_bandwidth
    t = max(compute_s, memory_s)
    return t, {"compute_s": compute_s, "memory_s": memory_s,
               "traffic_bytes": qo_bytes + kv_bytes,
               "visited_blocks": visited, "mxu_efficiency": eff}


def candidate_attn_blocks(p: AttnProblem,
                          tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU,
                          vmem_fraction: float = 0.5) -> List[AttnBlock]:
    budget = int(tpu.vmem_bytes * vmem_fraction)
    dims = [128, 256, 512, 1024]
    out = []
    for bq in dims:
        if bq > max(p.sq, 128):
            continue
        for bk in dims:
            if bk > max(p.skv, 128):
                continue
            c = AttnBlock(bq, bk)
            if c.vmem_bytes(p) <= budget:
                out.append(c)
    return out or [AttnBlock(128, 128)]


NAIVE_ATTN_BLOCK = AttnBlock(128, 128)

# Persistent tuning cache: problem -> chosen block, refreshed write-through.
# Lives next to the benchmark artifacts so TPU-measured entries and modeled
# entries share one file; all IO is best-effort (read-only images just
# re-derive the analytical choice).
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
TUNING_CACHE_PATH = os.environ.get(
    "REPRO_ATTN_TUNING_CACHE",
    os.path.join(_REPO_ROOT, "benchmarks", "artifacts",
                 "attn_tuning_cache.json"))
_tuning_cache: Optional[dict] = None


def _mesh_key(mesh_shape=None) -> str:
    """Normalize a mesh/device-count descriptor into a cache-key token.

    Accepts a ``{axis: size}`` mapping, an object with a ``.shape``
    mapping (a jax Mesh), a string, or None — None keys by the process's
    visible device count. Tuned entries are only portable across runs
    that *partition identically*: a block shape measured fastest on one
    chip can lose once per-device operand slices shrink 8x, so single-
    and multi-device runs must not clobber each other's entries.
    """
    if mesh_shape is None:
        try:
            import jax
            return f"dev{jax.device_count()}"
        except Exception:            # jax-less analytical use
            return "dev1"
    if isinstance(mesh_shape, str):
        return mesh_shape
    shape = getattr(mesh_shape, "shape", mesh_shape)
    if hasattr(shape, "items"):
        return "mesh(" + ",".join(
            f"{a}={int(n)}" for a, n in sorted(dict(shape).items())) + ")"
    return "mesh(" + ",".join(str(int(n)) for n in tuple(shape)) + ")"


def _cache_key(p: AttnProblem, tpu: hwmodel.TPUSpec,
               mesh_shape=None) -> str:
    return (f"{tpu.name}:{_mesh_key(mesh_shape)}:sq={p.sq}:skv={p.skv}"
            f":h={p.n_heads}:d={p.head_dim}:b={p.batch}"
            f":causal={int(p.causal)}:bytes={p.in_bytes}")


def _load_tuning_cache() -> dict:
    global _tuning_cache
    if _tuning_cache is None:
        try:
            with open(TUNING_CACHE_PATH) as f:
                loaded = json.load(f)
            if not isinstance(loaded, dict):
                raise ValueError(
                    f"cache root is {type(loaded).__name__}, not object")
            _tuning_cache = loaded
        except OSError:
            # Missing or unreadable (permissions, transient IO): the file
            # may still hold good TPU-measured entries — leave it alone.
            _tuning_cache = {}
        except ValueError:
            # Torn concurrent write / truncated file / non-object root:
            # discard the bad file (so the next write-through rebuilds it
            # from scratch) and fall back to re-deriving analytically.
            _tuning_cache = {}
            try:
                os.remove(TUNING_CACHE_PATH)
            except OSError:
                pass
    return _tuning_cache


def _store_tuning_cache(key: str, entry: dict) -> None:
    cache = _load_tuning_cache()
    cache[key] = entry
    try:
        os.makedirs(os.path.dirname(TUNING_CACHE_PATH), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(TUNING_CACHE_PATH),
                                   suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, TUNING_CACHE_PATH)
    except OSError:
        pass                       # read-only image: in-memory cache only


# Measured serving-path timings (``serve.telemetry.drift_report``) share
# the persistent tuning cache under their own key namespace, so the
# calibration pass the ROADMAP names reads model-vs-measured evidence
# from the same file the block-shape tuner already maintains. Entries:
# {"time_s": measured mean span, "modeled_s", "ratio", "n", "source"}.
SERVE_MEASURED_PREFIX = "serve_measured:"


def record_serve_measurement(name: str, entry: dict) -> None:
    """Persist one measured serving-span entry (keyed by component and
    engine geometry) into the tuning cache."""
    assert isinstance(entry.get("time_s"), float) and entry["time_s"] > 0, \
        entry
    _store_tuning_cache(SERVE_MEASURED_PREFIX + name, dict(entry))


def load_serve_measurement(name: str) -> Optional[dict]:
    return _load_tuning_cache().get(SERVE_MEASURED_PREFIX + name)


def drift_ratio(measured_s: float, modeled_s: float) -> float:
    """measured/modeled with a 0.0 sentinel for missing or degenerate
    inputs — downstream gates require the ratio finite and > 0, so a
    run that never measured (or a model that priced 0) fails the gate
    instead of sneaking through as inf/nan."""
    if not (math.isfinite(measured_s) and math.isfinite(modeled_s)):
        return 0.0
    if measured_s <= 0.0 or modeled_s <= 0.0:
        return 0.0
    return measured_s / modeled_s


def choose_attn_block(p: AttnProblem,
                      tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU,
                      use_cache: bool = True,
                      mesh_shape=None) -> Tuple[AttnBlock, dict]:
    """Minimum-modeled-time (block_q, block_k), persisted across processes.

    The cache key includes backend *and* mesh shape/device count
    (``mesh_shape``; None -> the process's device count), so single- and
    multi-device runs keep separate entries instead of clobbering each
    other — the per-device problem a kernel sees under SPMD is a
    different problem."""
    key = _cache_key(p, tpu, mesh_shape)
    if use_cache:
        hit = _load_tuning_cache().get(key)
        if hit is not None:
            # A torn write can leave a structurally-broken entry even when
            # the file parses; treat any malformed hit as a miss (the
            # write-through below overwrites it with a good one).
            try:
                blk = AttnBlock(int(hit["block_q"]), int(hit["block_k"]))
                terms, time_s = dict(hit["terms"]), float(hit["time_s"])
            except (KeyError, TypeError, ValueError):
                hit = None
            # Entries persist across cost-model/hardware-spec changes (and
            # may be TPU-measured or hand-edited): only trust ones still in
            # the feasible candidate set, else re-derive.
            if hit is not None and blk in candidate_attn_blocks(p, tpu):
                return blk, dict(terms, time_s=time_s, cached=True)
    best, best_t, best_terms = None, float("inf"), None
    for c in candidate_attn_blocks(p, tpu):
        t, terms = attn_cost(p, c, tpu)
        if t < best_t:
            best, best_t, best_terms = c, t, terms
    if use_cache:
        _store_tuning_cache(key, {"block_q": best.block_q,
                                  "block_k": best.block_k,
                                  "time_s": best_t, "terms": best_terms})
    return best, dict(best_terms, time_s=best_t)


def decode_attn_speedup(max_len: int, lengths: Iterable[int], n_heads: int,
                        n_kv_heads: int, head_dim: int,
                        tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> dict:
    """Modeled naive-vs-fast decode attention cost for one engine tick.

    Naive: every slot attends over the full ``max_len`` cache (the seed
    engine's behavior). Fast: flash decode clamps each slot's K/V stream to
    its actual length. Reported by ``benchmarks/tpu_serving.py``.
    """
    group = max(1, n_heads // n_kv_heads)

    def tick_cost(ls):
        t = 0.0
        for length in ls:
            p = AttnProblem(sq=group, skv=max(int(length), 1),
                            n_heads=n_kv_heads, head_dim=head_dim,
                            causal=False)
            c, _ = choose_attn_block(p, tpu, use_cache=False)
            t += attn_cost(p, c, tpu)[0]
        return t

    lengths = list(lengths)
    naive = tick_cost([max_len] * len(lengths))
    fast = tick_cost(lengths)
    return {"naive_s": naive, "fast_s": fast,
            "speedup": naive / fast if fast else float("inf")}


# ----------------------------------------------------------------------------
# Serving-path cost constants: hand-set defaults + measured calibration.
# ----------------------------------------------------------------------------

# Per-visited-block cost of resolving the page table: one dependent scalar
# load off the prefetched table before the K/V DMA can issue — the roofline
# analogue of the paper's TLB-miss penalty (ch. 3: address translation sits
# on the load's critical path; here it is one SMEM lookup deep).
PAGE_LOOKUP_S = 5e-8

# Per-chunk dispatch overhead of the chunked-prefill executable: one host
# enqueue + kernel launch per chunk (the fixed cost small chunks pay more
# often — the MXU-efficiency side of the chunk-size trade).
CHUNK_DISPATCH_S = 5e-6

# Host-side cost of one prefix-index level: a blake2b digest over one
# page of tokens plus a dict probe (``serve.paged.PrefixIndex``).
PREFIX_HASH_S = 2e-6

# Host-side cost of one n-gram-lookup drafted token (a numpy scan of the
# slot's history — no model, no HBM).
NGRAM_DRAFT_S = 2e-6

# Calibrated constants persist in the tuning cache under their own
# schema-versioned namespace, one entry per (backend, mesh, constant):
#
#   calibrated:cpu:dev1:page_lookup_s ->
#     {"schema_version": 1, "value": 3.1e-8, "n_trials": 5,
#      "spread": 0.12, "backend": "cpu", "mesh": "dev1",
#      "timestamp": ..., ...probe metadata}
#
# ``resolve_constants`` reads them back per constant: a torn or
# mis-versioned entry falls back to that constant's hand-set default
# without failing the others.
CALIBRATED_PREFIX = "calibrated:"
CALIBRATION_SCHEMA_VERSION = 1

# Env switch forcing the documented defaults (skip every ``calibrated:``
# entry) — the reproducibility escape hatch; launch CLIs expose it as
# ``--default-constants``.
DEFAULT_CONSTANTS_ENV = "REPRO_DEFAULT_CONSTANTS"


@dataclasses.dataclass(frozen=True)
class ServeConstants:
    """One resolved set of serving-path cost constants.

    ``source`` says where the numbers came from: ``"default"`` (the
    hand-set module constants — the documented fallback) or
    ``"calibrated"`` (``core.calibrate`` probes read back from the
    tuning cache for this backend+mesh). ``hbm_bandwidth`` and
    ``dispatch_s`` are None in the default set: the models then price
    HBM streams straight from the ``TPUSpec`` and carry no separate
    dispatch term — exactly the pre-calibration arithmetic, so forcing
    defaults reproduces the old decisions bit-for-bit.
    """

    page_lookup_s: float = PAGE_LOOKUP_S
    chunk_dispatch_s: float = CHUNK_DISPATCH_S
    prefix_hash_s: float = PREFIX_HASH_S
    draft_token_s: float = NGRAM_DRAFT_S
    dispatch_s: Optional[float] = None     # measured executable dispatch
    hbm_bandwidth: Optional[float] = None  # None -> the TPUSpec's rate
    source: str = "default"                # "default" | "calibrated"
    backend: str = ""
    mesh: str = ""
    timestamp: float = 0.0

    def apply_tpu(self, tpu: hwmodel.TPUSpec) -> hwmodel.TPUSpec:
        """The spec the models should price HBM streams with: the
        measured stream rate when calibrated, the assumed spec itself
        otherwise (same object -> identical default math)."""
        if self.hbm_bandwidth is None:
            return tpu
        return dataclasses.replace(tpu, hbm_bandwidth=self.hbm_bandwidth)


DEFAULT_CONSTANTS = ServeConstants()

# Probe targets, in report order. ``assumed_constants()`` maps each to
# the hand-set value the drift ratio is taken against.
CALIBRATED_NAMES = ("dispatch_s", "page_lookup_s", "hbm_bandwidth",
                    "chunk_dispatch_s", "draft_token_s", "prefix_hash_s")


def assumed_constants(tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> dict:
    """Hand-set value per calibrated constant (the drift baseline).
    ``dispatch_s`` has no model term of its own; its baseline is the
    chunk-dispatch constant, which prices the same enqueue+launch."""
    return {"dispatch_s": CHUNK_DISPATCH_S,
            "page_lookup_s": PAGE_LOOKUP_S,
            "hbm_bandwidth": tpu.hbm_bandwidth,
            "chunk_dispatch_s": CHUNK_DISPATCH_S,
            "draft_token_s": NGRAM_DRAFT_S,
            "prefix_hash_s": PREFIX_HASH_S}


def _backend_key(backend: Optional[str] = None) -> str:
    if backend is not None:
        return backend
    try:
        import jax
        return jax.default_backend()
    except Exception:              # jax-less analytical use
        return "cpu"


def calibration_key(name: str, mesh_shape=None,
                    backend: Optional[str] = None) -> str:
    return (f"{CALIBRATED_PREFIX}{_backend_key(backend)}"
            f":{_mesh_key(mesh_shape)}:{name}")


def record_calibration(name: str, value: float, mesh_shape=None,
                       backend: Optional[str] = None, **meta) -> None:
    """Persist one probed constant under the ``calibrated:`` namespace."""
    assert name in CALIBRATED_NAMES, name
    value = float(value)
    assert math.isfinite(value) and value > 0, (name, value)
    entry = {"schema_version": CALIBRATION_SCHEMA_VERSION,
             "value": value,
             "backend": _backend_key(backend),
             "mesh": _mesh_key(mesh_shape)}
    entry.update(meta)
    _store_tuning_cache(calibration_key(name, mesh_shape, backend), entry)


def load_calibration(name: str, mesh_shape=None,
                     backend: Optional[str] = None) -> Optional[dict]:
    """One constant's validated cache entry, or None. A torn write, a
    schema-version mismatch, or a non-finite value reads as None (that
    constant falls back to its default), never an exception."""
    hit = _load_tuning_cache().get(
        calibration_key(name, mesh_shape, backend))
    if not isinstance(hit, dict):
        return None
    try:
        if int(hit["schema_version"]) != CALIBRATION_SCHEMA_VERSION:
            return None
        v = float(hit["value"])
    except (KeyError, TypeError, ValueError):
        return None
    if not (math.isfinite(v) and v > 0):
        return None
    return hit


def resolve_constants(mesh_shape=None,
                      backend: Optional[str] = None) -> ServeConstants:
    """The constants the serving engine prices its decisions with.

    Prefers calibrated entries (``core.calibrate`` probes for this
    backend+mesh) constant by constant; any constant without a valid
    entry keeps its hand-set default. With ``REPRO_DEFAULT_CONSTANTS``
    set — or no valid entries at all — this is exactly
    ``DEFAULT_CONSTANTS``, the documented reproducible fallback.
    """
    if os.environ.get(DEFAULT_CONSTANTS_ENV, "").strip() not in ("", "0"):
        return DEFAULT_CONSTANTS
    found, ts = {}, 0.0
    for name in CALIBRATED_NAMES:
        hit = load_calibration(name, mesh_shape, backend)
        if hit is not None:
            found[name] = float(hit["value"])
            try:
                ts = max(ts, float(hit.get("timestamp", 0.0)))
            except (TypeError, ValueError):
                pass
    if not found:
        return DEFAULT_CONSTANTS
    return dataclasses.replace(DEFAULT_CONSTANTS, source="calibrated",
                               backend=_backend_key(backend),
                               mesh=_mesh_key(mesh_shape),
                               timestamp=ts, **found)


def calibration_report(mesh_shape=None, backend: Optional[str] = None,
                       tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> dict:
    """Per-constant measured-vs-assumed rollup (the calibration half of
    the observability gate): for every probe target, the measured value
    (None when never calibrated), the hand-set assumed value, the drift
    ratio measured/assumed (0.0 sentinel when unmeasured), and the probe
    metadata the entry carried (n_trials, spread, timestamp)."""
    resolved = resolve_constants(mesh_shape, backend)
    assumed = assumed_constants(tpu)
    rows = {}
    for name in CALIBRATED_NAMES:
        hit = load_calibration(name, mesh_shape, backend)
        measured = float(hit["value"]) if hit is not None else None
        rows[name] = {
            "assumed": assumed[name],
            "measured": measured,
            "drift_ratio": drift_ratio(measured, assumed[name])
            if measured is not None else 0.0,
            "n_trials": hit.get("n_trials") if hit else None,
            "spread": hit.get("spread") if hit else None,
            "timestamp": hit.get("timestamp") if hit else None,
        }
    return {"schema_version": CALIBRATION_SCHEMA_VERSION,
            "source": resolved.source,
            "backend": _backend_key(backend),
            "mesh": _mesh_key(mesh_shape),
            "timestamp": resolved.timestamp,
            "constants": rows}


@dataclasses.dataclass(frozen=True)
class TPServe:
    """Tensor-parallel serving geometry for the analytical cost models.

    ``n_devices`` shards the weight stream, the dense FLOPs, and (when the
    relevant head count divides) the attention work; each transformer
    layer pays two activation all-reduces (attn out-proj + MLP down-proj,
    the classic Megatron row-parallel cut) and the forward ends with one
    all-gather assembling the unembed ring's sharded logits GEMM.
    """
    n_devices: int
    d_model: int
    n_layers: int


def _tp_collective_s(tokens: float, tp: Optional["TPServe"],
                     in_bytes: int,
                     tpu: hwmodel.TPUSpec) -> float:
    """Per-forward collective seconds at ``tokens`` total query tokens
    under ``tp``; 0 when unsharded (the single-device models stay exact)."""
    if tp is None or tp.n_devices <= 1:
        return 0.0
    from repro.core import interconnect
    payload = float(tokens) * tp.d_model * in_bytes
    ar = interconnect.collective_time("all_reduce", payload,
                                      tp.n_devices, tpu).time_s
    ag = interconnect.collective_time("all_gather", payload,
                                      tp.n_devices, tpu).time_s
    return 2.0 * tp.n_layers * ar + ag


def _tp_shard(tp: Optional["TPServe"], heads: int) -> Tuple[int, int]:
    """(dense shard factor, attention shard factor) under ``tp`` — the
    attention factor falls back to 1 when ``heads`` doesn't divide, the
    same divisibility rule the runtime sharding ruleset applies."""
    if tp is None or tp.n_devices <= 1:
        return 1, 1
    d = tp.n_devices
    return d, (d if heads % d == 0 else 1)


def paged_decode_model(max_len: int, lengths: Iterable[int], n_heads: int,
                       n_kv_heads: int, head_dim: int, page_size: int,
                       in_bytes: int = 2,
                       page_lookup_s: Optional[float] = None,
                       tp: Optional[TPServe] = None,
                       constants: Optional[ServeConstants] = None,
                       tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> dict:
    """Paged vs contiguous decode for one engine tick: same FLOPs, a
    page-table-lookup overhead term per visited K/V block, and an HBM
    *reservation* that drops from ``slots * max_len`` rows to the pages
    the live contexts actually touch (plus the null page).

    This is the trade the paper's paging chapter prices for the hardware:
    finer pages waste less capacity (internal fragmentation shrinks) but
    pay more translation work; the engine's ``page_size`` knob sits on the
    same curve.

    Under ``tp`` the attention work shards over kv heads (when they
    divide the mesh) and both variants pay the per-tick activation
    collectives — paging and tensor parallelism compose, they don't
    interact, so the contig-vs-paged delta is unchanged.

    ``constants`` (a ``ServeConstants``) supplies the lookup cost and —
    when calibrated — the measured HBM stream rate; None is the
    hand-set default set. An explicit ``page_lookup_s`` overrides.
    """
    # Deferred: keeps core free of a module-level serve/kernels dependency
    # (kernels.ops imports this module at its top level).
    from repro.kernels.flash_attention import _largest_divisor
    from repro.serve.paged import reservation

    const = constants if constants is not None else DEFAULT_CONSTANTS
    tpu = const.apply_tpu(tpu)
    if page_lookup_s is None:
        page_lookup_s = const.page_lookup_s

    group = max(1, n_heads // n_kv_heads)
    lengths = [int(l) for l in lengths]
    slots = len(lengths)
    _, attn_shard = _tp_shard(tp, n_kv_heads)
    collective_s = _tp_collective_s(slots, tp, in_bytes, tpu)

    contig_s, paged_s, visited_total = 0.0, 0.0, 0
    for length in lengths:
        p = AttnProblem(sq=group, skv=max(length, 1), n_heads=n_kv_heads,
                        head_dim=head_dim, causal=False, in_bytes=in_bytes)
        c, _ = choose_attn_block(p, tpu, use_cache=False)
        block_k = _largest_divisor(page_size, c.block_k)
        t, terms = attn_cost(p, AttnBlock(c.block_q, block_k), tpu)
        contig_s += t / attn_shard
        visited = terms["visited_blocks"]
        visited_total += visited
        paged_s += (t + visited * page_lookup_s) / attn_shard
    contig_s += collective_s
    paged_s += collective_s

    out = reservation(lengths, max_len, page_size)   # the one accounting
    bytes_per_row = 2 * n_kv_heads * head_dim * in_bytes     # K + V
    out.update({
        "collective_s": collective_s,
        "contig_s": contig_s,
        "paged_s": paged_s,
        "lookup_overhead_frac": (paged_s - contig_s) / contig_s
        if contig_s else 0.0,
        "visited_blocks": visited_total,
        "tokens_per_s_contig": slots / contig_s if contig_s else 0.0,
        "tokens_per_s_paged": slots / paged_s if paged_s else 0.0,
        "hbm_paged_bytes_per_layer": out["rows_resident"] * bytes_per_row,
        "hbm_contig_bytes_per_layer":
            out["rows_reserved_contig"] * bytes_per_row,
    })
    return out


def prefill_chunk_model(prompt_len: int, chunk: int, n_heads: int,
                        n_kv_heads: int, head_dim: int, page_size: int,
                        in_bytes: int = 2,
                        page_lookup_s: Optional[float] = None,
                        cached_rows: int = 0,
                        tp: Optional[TPServe] = None,
                        constants: Optional[ServeConstants] = None,
                        tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> dict:
    """Price chunked paged prefill of one ``prompt_len`` prompt at one
    chunk size: per-chunk causal attention over the previously-written
    pages plus the chunk itself, a page-table-lookup term per visited K/V
    block (the software-TLB walk), and a per-chunk dispatch cost.

    The chunk-size trade this exposes is the paper's TLB-reach argument at
    serving granularity: big chunks amortize dispatch and run the MXU at
    full tiles but stall interleaved decode ticks for the whole chunk
    (``interleave_latency_s`` = the longest single chunk); small chunks
    keep decode latency tight but pay the fixed costs per chunk and pad
    the q tile below the MXU edge.

    ``cached_rows`` prices a prefix-cache hit (``ServeConfig.
    prefix_cache``): prefill starts at the cached cursor — chunks below
    it never run — while every remaining chunk still attends the full
    cached prefix (its K/V pages are resident, mapped by refcount), and
    a per-level hash-probe term charges the index walk. The shared-
    prefix TTFT collapse this models is the headline win: suffix-only
    compute, zero data movement for the hit.

    ``n_kv_heads`` is accepted for signature symmetry with
    ``paged_decode_model`` but does not change the traffic: the prefill
    grid (``flash_attention_paged``) is flattened over *q* heads, so K/V
    blocks re-stream once per q head even under GQA — pricing per q head
    is faithful to the kernel's actual DMA (the decode kernel's
    b*kvh-flattened layout is what lets ``paged_decode_model`` price per
    kv head instead). Under ``tp`` the attention shards over q heads when
    they divide the mesh and every chunk pays the activation collectives
    (a per-chunk fixed cost — one more term small chunks amortize badly).
    """
    const = constants if constants is not None else DEFAULT_CONSTANTS
    tpu = const.apply_tpu(tpu)
    if page_lookup_s is None:
        page_lookup_s = const.page_lookup_s
    dispatch_s = const.chunk_dispatch_s
    _, attn_shard = _tp_shard(tp, n_heads)
    del n_kv_heads
    coll_per_chunk = _tp_collective_s(chunk, tp, in_bytes, tpu)
    # A full-coverage hit still re-prefills the last row (the first
    # token's logit must be sampled) — same clamp the engine applies.
    cached_rows = max(0, min(int(cached_rows), prompt_len - 1))
    probe_s = _ceil_div(cached_rows, page_size) * const.prefix_hash_s
    n_chunks = _ceil_div(prompt_len - cached_rows, chunk)
    attn_s, lookup_s, visited_total, worst_chunk_s = 0.0, 0.0, 0, 0.0
    for i in range(n_chunks):
        # live rows after chunk i (cached prefix included: its pages are
        # resident and every suffix chunk attends them)
        skv = min(cached_rows + (i + 1) * chunk, prompt_len)
        p = AttnProblem(sq=chunk, skv=max(skv, chunk), n_heads=n_heads,
                        head_dim=head_dim, causal=True, in_bytes=in_bytes)
        c, _ = choose_attn_block(p, tpu, use_cache=False)
        from repro.kernels.flash_attention import _largest_divisor
        blk = AttnBlock(min(c.block_q, chunk),
                        _largest_divisor(page_size, c.block_k))
        t, terms = attn_cost(p, blk, tpu)
        t /= attn_shard
        visited = terms["visited_blocks"]
        chunk_s = t + visited * page_lookup_s + dispatch_s \
            + coll_per_chunk
        attn_s += t
        lookup_s += visited * page_lookup_s
        visited_total += visited
        worst_chunk_s = max(worst_chunk_s, chunk_s)
    collective_s = n_chunks * coll_per_chunk
    total_s = attn_s + lookup_s + n_chunks * dispatch_s \
        + collective_s + probe_s
    return {
        "chunk": chunk,
        "n_chunks": n_chunks,
        "cached_rows": cached_rows,
        "probe_s": probe_s,
        "prefill_s": total_s,
        "attn_s": attn_s,
        "lookup_s": lookup_s,
        "dispatch_s": n_chunks * dispatch_s,
        "collective_s": collective_s,
        "visited_blocks": visited_total,
        "interleave_latency_s": worst_chunk_s,
        "lookup_overhead_frac": lookup_s / attn_s if attn_s else 0.0,
    }


def choose_prefill_chunk(max_len: int, n_heads: int, n_kv_heads: int,
                         head_dim: int, page_size: int,
                         latency_weight: float = 4.0,
                         in_bytes: int = 2,
                         constants: Optional[ServeConstants] = None,
                         tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU
                         ) -> Tuple[int, dict]:
    """Pick the chunk size the serving engine prefills with.

    Candidates are page-aligned powers-of-two multiples of ``page_size``
    up to ``max_len``; the score charges the full-prompt prefill time plus
    ``latency_weight`` times the interleave latency (every decode slot
    waits out one chunk between its tokens while a prompt streams — the
    weight is roughly how many stalled slots a chunk delay costs). The
    engine consults this when ``ServeConfig.chunk_size`` is None.
    """
    assert 0 < page_size <= max_len, \
        ("chunked prefill needs at least one page per chunk",
         page_size, max_len)
    cands = []
    c = page_size
    while c <= max_len:
        cands.append(c)
        c *= 2
    if cands[-1] != max_len and max_len % page_size == 0:
        cands.append(max_len)
    best, best_score, best_terms = None, float("inf"), None
    for cand in cands:
        terms = prefill_chunk_model(max_len, cand, n_heads, n_kv_heads,
                                    head_dim, page_size, in_bytes=in_bytes,
                                    constants=constants, tpu=tpu)
        score = terms["prefill_s"] \
            + latency_weight * terms["interleave_latency_s"]
        if score < best_score:
            best, best_score, best_terms = cand, score, terms
    return best, dict(best_terms, score_s=best_score,
                      candidates=len(cands))


def choose_prefix_cache(prompt_len: int, prefix_rows: int, hit_rate: float,
                        n_heads: int, n_kv_heads: int, head_dim: int,
                        page_size: int, chunk: Optional[int] = None,
                        in_bytes: int = 2,
                        constants: Optional[ServeConstants] = None,
                        tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU
                        ) -> Tuple[bool, dict]:
    """On/off policy for ``ServeConfig.prefix_cache``, priced by hit rate.

    Expected per-request prefill cost with the cache on is a mixture:
    ``hit_rate`` of admissions prefill only the suffix past
    ``prefix_rows`` (plus the hash-probe walk and one copy-on-write page
    split amortized per hit — the full-coverage clamp's eager split is
    the worst case, so charging it on every hit is conservative);
    misses pay the full prefill *plus* the probe that found nothing.
    The cache wins when the mixture beats the uncached cost — at
    ``hit_rate`` 0 the probe tax makes "off" the choice, which is the
    policy's real content: everything else is monotone in the hit rate.
    """
    assert 0.0 <= hit_rate <= 1.0, hit_rate
    const = constants if constants is not None else DEFAULT_CONSTANTS
    tpu = const.apply_tpu(tpu)
    prefix_rows = max(0, min(int(prefix_rows), int(prompt_len)))
    if chunk is None:
        chunk, _ = choose_prefill_chunk(prompt_len, n_heads, n_kv_heads,
                                        head_dim, page_size,
                                        in_bytes=in_bytes,
                                        constants=const, tpu=tpu)
    full = prefill_chunk_model(prompt_len, chunk, n_heads, n_kv_heads,
                               head_dim, page_size, in_bytes=in_bytes,
                               constants=const, tpu=tpu)
    hit = prefill_chunk_model(prompt_len, chunk, n_heads, n_kv_heads,
                              head_dim, page_size, in_bytes=in_bytes,
                              cached_rows=prefix_rows, constants=const,
                              tpu=tpu)
    # One COW page split: read + write one page of K and V rows.
    cow_s = 4 * page_size * n_kv_heads * head_dim * in_bytes \
        / tpu.hbm_bandwidth
    probe_s = _ceil_div(prompt_len, page_size) * const.prefix_hash_s
    on_s = hit_rate * (hit["prefill_s"] + cow_s) \
        + (1.0 - hit_rate) * (full["prefill_s"] + probe_s)
    off_s = full["prefill_s"]
    return on_s < off_s, {
        "hit_rate": hit_rate,
        "prefix_rows": prefix_rows,
        "chunk": chunk,
        "prefill_s_off": off_s,
        "prefill_s_on": on_s,
        "prefill_s_hit": hit["prefill_s"],
        "cow_s": cow_s,
        "probe_s": probe_s,
        "speedup": off_s / on_s if on_s else float("inf"),
        "ttft_frac_hit": hit["prefill_s"] / off_s if off_s else 0.0,
    }


def expected_spec_tokens(k: int, accept_rate: float) -> float:
    """E[tokens emitted per verify tick] with per-draft accept probability
    ``accept_rate``: the accepted prefix length plus the always-emitted
    bonus/correction token, sum_{i=0..k} a^i. k=0 gives 1 (plain decode)."""
    return sum(accept_rate ** i for i in range(k + 1))


def spec_decode_model(lengths: Iterable[int], n_heads: int,
                      n_kv_heads: int, head_dim: int, page_size: int,
                      k: int, accept_rate: float, param_bytes: float,
                      draft_bytes: float = 0.0,
                      draft_token_s: Optional[float] = None,
                      in_bytes: int = 2,
                      page_lookup_s: Optional[float] = None,
                      plain_tick_s: Optional[float] = None,
                      tp: Optional[TPServe] = None,
                      constants: Optional[ServeConstants] = None,
                      tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> dict:
    """Price one speculative verify tick against ``k + 1`` plain decode
    ticks — the serving-side instance of the paper's latency-hiding
    pricing: how much parallel work (k drafted tokens scored in one pass)
    amortizes the fixed-cost serial step (per-tick dispatch + streaming
    every weight byte from HBM once, which dominates small-batch decode).

    Per-tick terms, batch-wide:

    * ``weight_stream_s`` — ``param_bytes / hbm_bw``, paid once per tick
      no matter the verify width: the cost speculation amortizes.
    * paged attention per slot at query width ``group * (k+1)`` over the
      slot's live context (+ the drafted rows), with the page-walk term
      per visited block — the part that *grows* with width.
    * dense FLOPs for ``slots * (k+1)`` tokens — wasted on rejected rows.
    * draft cost: ``slots * k`` draft-model weight streams per tick
      (``draft_bytes``; 0 for the n-gram drafter) — the engine's
      ``ModelDraft`` rolls out per slot, serially; a batched draft would
      amortize to ``k`` streams (divide ``draft_bytes`` by the batch) —
      plus ``slots * k`` host lookups (``draft_token_s``).

    Emitted tokens per tick follow ``expected_spec_tokens(k,
    accept_rate)``; the headline is ``speedup`` = spec tokens/s over plain
    tokens/s. ``verify_overhead_frac`` is the widened tick's extra cost —
    the overhead an accept rate must beat.
    """
    from repro.kernels.flash_attention import _largest_divisor

    const = constants if constants is not None else DEFAULT_CONSTANTS
    tpu = const.apply_tpu(tpu)
    if page_lookup_s is None:
        page_lookup_s = const.page_lookup_s
    if draft_token_s is None:
        draft_token_s = const.draft_token_s
    group = max(1, n_heads // n_kv_heads)
    lengths = [int(l) for l in lengths]
    slots = len(lengths)
    dense_shard, attn_shard = _tp_shard(tp, n_kv_heads)
    # TP shards the weight stream too — each device streams its slice of
    # every matrix; the price is the per-tick activation collectives.
    weight_stream_s = param_bytes / tpu.hbm_bandwidth / dense_shard
    n_params = param_bytes / in_bytes

    def tick_s(width: int) -> float:
        attn = 0.0
        for length in lengths:
            p = AttnProblem(sq=group * width,
                            skv=max(length + width - 1, 1),
                            n_heads=n_kv_heads, head_dim=head_dim,
                            causal=False, in_bytes=in_bytes)
            c, _ = choose_attn_block(p, tpu, use_cache=False)
            blk = AttnBlock(c.block_q, _largest_divisor(page_size,
                                                        c.block_k))
            t, terms = attn_cost(p, blk, tpu)
            attn += (t + terms["visited_blocks"] * page_lookup_s) \
                / attn_shard
        dense = 2.0 * n_params * slots * width \
            / (dense_shard * tpu.peak_bf16_flops)
        return weight_stream_s + attn + dense + const.chunk_dispatch_s \
            + _tp_collective_s(slots * width, tp, in_bytes, tpu)

    # The width-1 tick is k-independent; choose_spec_k precomputes it
    # once and threads it through its candidate loop.
    plain_tick = plain_tick_s if plain_tick_s is not None else tick_s(1)
    spec_tick = tick_s(k + 1) if k else plain_tick
    draft_s = slots * k * (draft_bytes / tpu.hbm_bandwidth
                           + draft_token_s)
    spec_tick += draft_s
    e_tokens = expected_spec_tokens(k, accept_rate)
    tok_plain = slots / plain_tick
    tok_spec = slots * e_tokens / spec_tick
    return {
        "k": k,
        "accept_rate": accept_rate,
        "expected_tokens_per_tick": e_tokens,
        "weight_stream_s": weight_stream_s,
        "plain_tick_s": plain_tick,
        "spec_tick_s": spec_tick,
        "draft_s": draft_s,
        "verify_overhead_frac": spec_tick / plain_tick - 1.0,
        "tokens_per_s_plain": tok_plain,
        "tokens_per_s_spec": tok_spec,
        "speedup": tok_spec / tok_plain,
    }


def choose_spec_k(lengths: Iterable[int], n_heads: int,
                  n_kv_heads: int, head_dim: int, page_size: int,
                  accept_rate: float, param_bytes: float,
                  draft_bytes: float = 0.0,
                  draft_token_s: Optional[float] = None,
                  ks: Tuple[int, ...] = (1, 2, 3, 4, 6, 8),
                  in_bytes: int = 2,
                  tp: Optional[TPServe] = None,
                  constants: Optional[ServeConstants] = None,
                  tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU
                  ) -> Tuple[int, dict]:
    """Pick the verify width the serving engine speculates with.

    Maximizes modeled tokens/sec over candidate ``k``; returns ``k = 0``
    (speculation disabled — run plain decode ticks) when no candidate
    beats the plain engine, which happens exactly when the accept rate is
    too low to pay the verify-width + draft overhead (e.g. a model draft
    whose serial weight streams cost more than the tokens they land).
    The returned terms are the best candidate's either way, so the caller
    can see how close the call was.
    """
    lengths = list(lengths)
    best_k, best_terms, plain_tick_s = 0, None, None
    for k in ks:
        terms = spec_decode_model(lengths, n_heads, n_kv_heads,
                                  head_dim, page_size, k, accept_rate,
                                  param_bytes, draft_bytes=draft_bytes,
                                  draft_token_s=draft_token_s,
                                  in_bytes=in_bytes,
                                  plain_tick_s=plain_tick_s, tp=tp,
                                  constants=constants, tpu=tpu)
        plain_tick_s = terms["plain_tick_s"]
        if best_terms is None or \
                terms["tokens_per_s_spec"] > best_terms["tokens_per_s_spec"]:
            best_k, best_terms = k, terms
    if best_terms["speedup"] <= 1.0:
        best_k = 0
    return best_k, dict(best_terms, chosen_k=best_k,
                        candidates=len(list(ks)))


# -- serving overload pressure -------------------------------------------------

DEGRADE_HIGH = 0.85   # default enter-degraded threshold (ServeConfig)
DEGRADE_LOW = 0.60    # default leave-degraded threshold (hysteresis)


def serve_pressure(pool_occupancy: float, queue_depth: int,
                   batch: int) -> float:
    """Scalar load-pressure signal in [0, 1] for the serving engine's
    degradation ladder.

    Two independent saturation signals, take the worse: the KV page
    pool's occupancy fraction (pages in use / capacity — HBM pressure:
    near 1.0 the next decode page comes from a preemption), and the
    queue depth normalized by the decode batch (admission pressure: a
    queue deeper than the batch means arrivals outrun service even if
    every slot turned over each tick). ``max`` rather than a weighted
    sum — either resource saturating alone is an overload, and a bounded
    signal composes with fixed thresholds."""
    q = min(1.0, float(queue_depth) / max(1.0, float(batch)))
    return max(min(1.0, float(pool_occupancy)), q)


def choose_degradation(pressure: float, degraded: bool,
                       high: float = DEGRADE_HIGH,
                       low: float = DEGRADE_LOW) -> bool:
    """Hysteresis band for the load-shedding latch: enter degraded mode
    at/above ``high``, leave at/below ``low``. The dead band between
    them is what prevents flapping — a downshift frees resources (spec
    width, prefill budget), which *reduces* pressure; a single threshold
    would re-upshift immediately and oscillate every tick."""
    assert 0.0 <= low <= high <= 1.0, (low, high)
    if degraded:
        return pressure > low
    return pressure >= high


def tp_decode_model(lengths: Iterable[int], n_heads: int,
                    n_kv_heads: int, head_dim: int, page_size: int,
                    param_bytes: float, d_model: int, n_layers: int,
                    n_devices: int, in_bytes: int = 2,
                    page_lookup_s: Optional[float] = None,
                    constants: Optional[ServeConstants] = None,
                    tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU) -> dict:
    """Price one paged decode tick single-device vs tensor-parallel over
    ``n_devices`` — the serving-side instance of the paper's NVLink-era
    scaling question: decode is weight-stream bound, so sharding every
    matrix cuts the dominant HBM term by the mesh degree, and what's left
    to beat is the per-layer activation all-reduces plus the unembed
    ring's gather (``collective_s``), tiny at decode widths because the
    payload is activations (slots x d_model) rather than weights.

    The other headline is capacity, not speed: the KV page pool is
    device-sharded with pages as the shard unit, so the same per-device
    HBM budget holds ``n_devices`` times the pages globally
    (``pool_capacity_ratio``) — a slot's context can span devices.
    """
    lengths = [int(l) for l in lengths]
    slots = len(lengths)
    tp = TPServe(n_devices=n_devices, d_model=d_model, n_layers=n_layers)
    common = dict(n_heads=n_heads, n_kv_heads=n_kv_heads,
                  head_dim=head_dim, page_size=page_size,
                  k=0, accept_rate=0.0, param_bytes=param_bytes,
                  in_bytes=in_bytes, page_lookup_s=page_lookup_s,
                  constants=constants, tpu=tpu)
    base = spec_decode_model(lengths, **common)
    shard = spec_decode_model(lengths, tp=tp, **common)
    tick_1, tick_tp = base["plain_tick_s"], shard["plain_tick_s"]
    collective_s = _tp_collective_s(slots, tp, in_bytes, tpu)
    return {
        "n_devices": n_devices,
        "slots": slots,
        "tick_1dev_s": tick_1,
        "tick_tp_s": tick_tp,
        "weight_stream_1dev_s": base["weight_stream_s"],
        "weight_stream_tp_s": shard["weight_stream_s"],
        "collective_s": collective_s,
        "collective_frac": collective_s / tick_tp if tick_tp else 0.0,
        "attn_sharded": n_kv_heads % max(1, n_devices) == 0,
        "tokens_per_s_1dev": slots / tick_1 if tick_1 else 0.0,
        "tokens_per_s_tp": slots / tick_tp if tick_tp else 0.0,
        "speedup": tick_1 / tick_tp if tick_tp else float("inf"),
        "pool_capacity_ratio": float(n_devices),
    }


# ----------------------------------------------------------------------------
# Sharding selection for one weight-stationary matmul layer.
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingChoice:
    name: str                   # "dp", "tp_col", "tp_row", "dp+tp"
    time_s: float
    compute_s: float
    collective_s: float


def choose_layer_sharding(batch_tokens: int, d_in: int, d_out: int,
                          data_axis: int, model_axis: int,
                          in_bytes: int = 2,
                          tpu: hwmodel.TPUSpec = hwmodel.DEFAULT_TPU
                          ) -> List[ShardingChoice]:
    """Rank standard layouts for out = x @ W by modeled step time.

    dp: batch sharded, W replicated (grad all-reduce amortized elsewhere).
    tp_col: W column-sharded -> output sharded, no comm until next layer.
    tp_row: W row-sharded -> partial sums all-reduced.
    """
    from repro.core import interconnect

    chips = data_axis * model_axis
    flops = 2.0 * batch_tokens * d_in * d_out
    out: List[ShardingChoice] = []

    def add(name, shard_factor, coll_kind, coll_payload, axis):
        comp = flops / (chips * tpu.peak_bf16_flops) \
            if shard_factor == chips else flops / (shard_factor * tpu.peak_bf16_flops)
        coll = interconnect.collective_time(coll_kind, coll_payload, axis,
                                            tpu).time_s if coll_payload else 0.0
        out.append(ShardingChoice(name, comp + coll, comp, coll))

    tokens_local = batch_tokens / data_axis
    # dp only: compute split over data axis, none over model.
    add("dp", data_axis, None, 0, 1)
    # tp_col: activations all-gathered next layer; charge the gather here.
    add("tp_col", chips, "all_gather",
        tokens_local * d_out * in_bytes, model_axis)
    # tp_row: partial-sum all-reduce of the output activations.
    add("tp_row", chips, "all_reduce",
        tokens_local * d_out * in_bytes, model_axis)
    out.sort(key=lambda s: s.time_s)
    return out
