"""Microbenchmark calibration for the serving-path cost constants.

The paper's method is to discover the constants the vendor won't
disclose by probing: pointer-chase ladders for latency, streamed copies
for bandwidth, per-instruction timing for CPI. This module turns the
same idiom on our own serving hot path — the hand-set constants in
``core/autotune`` (``PAGE_LOOKUP_S``, ``CHUNK_DISPATCH_S``,
``NGRAM_DRAFT_S``, ``PREFIX_HASH_S``, the assumed ``hbm_bandwidth``)
become *measured* per backend+mesh:

  dispatch_s        best-of-N wall time of a tiny jitted kernel — the
                    floor every executable launch pays on this runtime.
  page_lookup_s     sweep page-table sizes through the real
                    ``flash_decode_paged`` executable at fixed context,
                    time the contiguous ``flash_decode`` at the same
                    lengths, and regress both against visited K blocks:
                    the *difference of slopes* is the per-block cost of
                    walking the table (the pchase trick — vary one knob,
                    read the marginal cost off the line, subtract the
                    part a contiguous layout also pays).
  hbm_bandwidth     timed device round-trips of an ``a + 1`` stream at
                    serving-relevant sizes, per dtype; the best observed
                    rate (2 x nbytes per call: read + write).
  chunk_dispatch_s  steady-state ``prefill_chunk`` execute span from a
                    tiny real engine run (telemetry's compile/execute
                    separation is the warm-up boundary).
  draft_token_s     best-of-N host n-gram draft proposal over a
                    motif-rich history, per proposed token.
  prefix_hash_s     best-of-N chained page-digest walk (hash + table
                    probe) per page — what the prefix cache pays to
                    recognize a shared prompt.

Results persist in the tuning cache under the schema-versioned
``calibrated:{backend}:{mesh}:{name}`` namespace with probe metadata
(n_trials, spread, unit, timestamp); ``autotune.resolve_constants``
reads them back and the serving engine prices every ``choose_*``
decision from the measured set. ``REPRO_DEFAULT_CONSTANTS=1`` forces
the documented defaults for reproducibility.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import autotune


@dataclasses.dataclass(frozen=True)
class ProbeResult:
    """One measured constant plus the evidence behind it."""

    name: str
    value: float
    unit: str
    n_trials: int
    spread: float            # (max - min) / min over kept trials
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        assert self.name in autotune.CALIBRATED_NAMES, self.name
        assert np.isfinite(self.value) and self.value > 0, \
            (self.name, self.value)


def _best_of(fn: Callable[[], Any], n: int,
             warmup: int = 2) -> Tuple[float, float, int]:
    """Best-of-N wall timing: min is the signal (one clean run with no
    interference), (max-min)/min is the spread the cache entry records
    so a noisy probe is visible downstream."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    best = min(times)
    spread = (max(times) - best) / best if best > 0 else 0.0
    return best, spread, n


# -- probes -------------------------------------------------------------------


def probe_dispatch(fast: bool = False) -> ProbeResult:
    """Executable dispatch floor: a jitted kernel too small to compute
    anything measurable, so its round-trip *is* the launch overhead."""
    import jax
    import jax.numpy as jnp

    x = jnp.zeros((8,), jnp.float32)
    f = jax.jit(lambda a: a + 1.0)
    n = 10 if fast else 30
    best, spread, n = _best_of(lambda: f(x).block_until_ready(), n)
    return ProbeResult("dispatch_s", best, "s/dispatch", n, spread,
                       {"probe": "tiny_kernel_best_of_n"})


def probe_page_lookup(fast: bool = False) -> ProbeResult:
    """Page-walk slope: time ``flash_decode_paged`` across page-table
    sizes and subtract the contiguous ``flash_decode`` slope at the same
    context lengths — the residual marginal cost per visited K block is
    the table lookup itself."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    batch, kvh, heads, d = 2, 1, 2, 32
    page_size = block_k = 8
    tables = (2, 4, 8) if fast else (2, 4, 8, 16)
    n = 3 if fast else 7
    key = jax.random.PRNGKey(0)
    visited, t_paged, t_contig = [], [], []
    for n_tables in tables:
        max_len = n_tables * page_size
        n_pages = batch * n_tables + 1          # page 0 is the null page
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (batch, heads, d), jnp.float32)
        k_pages = jax.random.normal(
            kk, (n_pages, page_size, kvh, d), jnp.float32)
        v_pages = jax.random.normal(
            kv, (n_pages, page_size, kvh, d), jnp.float32)
        page_table = np.arange(
            1, batch * n_tables + 1, dtype=np.int32).reshape(batch, n_tables)
        lengths = np.full((batch,), max_len, np.int32)
        k_flat = k_pages[page_table.reshape(-1)].reshape(
            batch, max_len, kvh, d)
        v_flat = v_pages[page_table.reshape(-1)].reshape(
            batch, max_len, kvh, d)
        tp, _, _ = _best_of(
            lambda: ops.flash_decode_paged(
                q, k_pages, v_pages, page_table, lengths,
                block_k=block_k).block_until_ready(), n)
        tc, _, _ = _best_of(
            lambda: ops.flash_decode(
                q, k_flat, v_flat, lengths,
                block_k=block_k).block_until_ready(), n)
        visited.append(batch * kvh * n_tables)   # K blocks touched/call
        t_paged.append(tp)
        t_contig.append(tc)
    slope_paged = float(np.polyfit(visited, t_paged, 1)[0])
    slope_contig = float(np.polyfit(visited, t_contig, 1)[0])
    # Interpret-mode noise can push the difference negative; clamp to a
    # positive floor so the constant stays priceable.
    value = max(slope_paged - slope_contig, 1e-10)
    spread = (max(t_paged) - min(t_paged)) / max(min(t_paged), 1e-12)
    return ProbeResult(
        "page_lookup_s", value, "s/block", n * len(tables), spread,
        {"probe": "table_sweep_slope", "tables": list(tables),
         "slope_paged_s": slope_paged, "slope_contig_s": slope_contig})


def probe_hbm_stream(fast: bool = False) -> ProbeResult:
    """Device stream rate: jitted ``a + 1`` moves 2 x nbytes (read +
    write); the best observed rate across dtypes is what the serving
    models should price weight and KV streams with."""
    import jax
    import jax.numpy as jnp

    elems = (1 << 18) if fast else (1 << 21)     # 1 MiB / 8 MiB at f32
    n = 5 if fast else 15
    rates = {}
    for dtype in (jnp.float32, jnp.bfloat16):
        a = jnp.ones((elems,), dtype)
        f = jax.jit(lambda x: x + 1)
        best, _, _ = _best_of(lambda: f(a).block_until_ready(), n)
        nbytes = elems * a.dtype.itemsize
        rates[np.dtype(dtype).name] = 2.0 * nbytes / best
    value = max(rates.values())
    spread = (max(rates.values()) - min(rates.values())) \
        / max(min(rates.values()), 1e-12)
    return ProbeResult(
        "hbm_bandwidth", value, "bytes/s", n * len(rates), spread,
        {"probe": "stream_copy", "rates_by_dtype": rates,
         "elems": elems})


def probe_chunk_dispatch(fast: bool = False) -> ProbeResult:
    """Steady-state chunked-prefill step cost from a real tiny engine:
    warm one drained run (compiles), reset telemetry, drain a second —
    the ``prefill_chunk`` execute-span mean is the measured per-chunk
    dispatch+step cost the prefill model's ``dispatch_s`` term prices."""
    import jax
    from repro import configs
    from repro.models import transformer as T
    from repro.serve.engine import Request, ServeConfig, ServingEngine

    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(
        max_len=32, batch=2, eos_id=-1, paged=True, page_size=8,
        chunk_size=8))
    rng = np.random.default_rng(0)

    def drain(rid0: int):
        for i in range(2):
            prompt = rng.integers(0, 64, size=24).astype(np.int32)
            eng.submit(Request(rid=rid0 + i, prompt=prompt, max_new=2))
        eng.run_until_drained()

    drain(0)                       # warm: compile every chunk bucket
    eng.telemetry.reset()
    drain(100)
    st = eng.telemetry.span_stats()["prefill_chunk"]
    assert st["execute_n"] > 0, st
    return ProbeResult(
        "chunk_dispatch_s", st["execute_mean_s"], "s/chunk",
        int(st["execute_n"]),
        (st["max_s"] - st["execute_mean_s"]) / max(st["execute_mean_s"],
                                                   1e-12),
        {"probe": "engine_chunk_span", "chunk": eng.chunk})


def probe_draft_token(fast: bool = False) -> ProbeResult:
    """Host n-gram draft cost per proposed token over a motif-rich
    history (every suffix has a continuation, so the scan always pays
    its full lookup)."""
    from repro.serve.spec import NgramDraft

    draft = NgramDraft()
    history = np.tile(np.arange(16, dtype=np.int32), 64)
    k = 4
    n = 10 if fast else 30
    best, spread, n = _best_of(lambda: draft.propose(history, k), n)
    return ProbeResult(
        "draft_token_s", max(best / k, 1e-12), "s/token", n, spread,
        {"probe": "ngram_propose", "k": k, "history": len(history)})


def probe_prefix_hash(fast: bool = False) -> ProbeResult:
    """Prefix-cache recognition cost per page: the chained page-digest
    walk (hash the page's tokens into the parent digest, probe the
    digest table) that admission pays per prompt page."""
    from repro.serve import paged

    n_pages = 16 if fast else 64
    page_size = 8
    rng = np.random.default_rng(0)
    chunks = [paged.token_bytes(
        rng.integers(0, 1 << 15, size=page_size).astype(np.int32))
        for _ in range(n_pages)]
    table: Dict[bytes, int] = {}

    def walk():
        parent = paged.ROOT_DIGEST
        for chunk in chunks:
            parent = paged._page_digest(parent, chunk)
            table.get(parent)
        return parent

    n = 5 if fast else 15
    best, spread, n = _best_of(walk, n)
    return ProbeResult(
        "prefix_hash_s", max(best / n_pages, 1e-12), "s/page", n, spread,
        {"probe": "digest_chain", "pages": n_pages})


# -- the pass -----------------------------------------------------------------

PROBES: Dict[str, Callable[[bool], ProbeResult]] = {
    "dispatch_s": probe_dispatch,
    "page_lookup_s": probe_page_lookup,
    "hbm_bandwidth": probe_hbm_stream,
    "chunk_dispatch_s": probe_chunk_dispatch,
    "draft_token_s": probe_draft_token,
    "prefix_hash_s": probe_prefix_hash,
}
assert tuple(PROBES) == autotune.CALIBRATED_NAMES


def run_calibration(fast: bool = False, persist: bool = True,
                    mesh_shape=None,
                    backend: Optional[str] = None
                    ) -> Dict[str, ProbeResult]:
    """Run every probe; with ``persist`` write each result into the
    tuning cache's ``calibrated:`` namespace (schema-versioned, with
    n_trials/spread/unit/timestamp metadata) so ``resolve_constants``
    prefers it from the next engine construction on."""
    results: Dict[str, ProbeResult] = {}
    for name, probe in PROBES.items():
        res = probe(fast)
        results[name] = res
        if persist:
            autotune.record_calibration(
                name, res.value, mesh_shape=mesh_shape, backend=backend,
                n_trials=res.n_trials, spread=res.spread, unit=res.unit,
                timestamp=time.time(), fast=bool(fast))
    return results
