"""Compiled-HLO dissection: collective bytes, op census, remat detection.

This is the TPU-side "disassembly" analogue of the paper's SASS dissection:
``lowered.as_text()`` is our nvdisasm. The roofline engine's collective term
is *not* available from ``cost_analysis()``, so we parse the HLO text and sum
operand bytes of every communication op, exactly as mandated by the task.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# e.g. "bf16[16,128,1024]{2,1,0}" or "f32[]"; layout suffix optional.
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# "%name = bf16[...] all-reduce(...)" — also matches tuple-shaped ops.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"([a-z0-9\-]+)\(", re.M)


def shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[dims]' shape string."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def result_bytes(result_str: str) -> int:
    """Bytes of an op result: a shape or a tuple of shapes."""
    return sum(shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(result_str))


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of every collective op in an HLO module.

    Result size equals operand size for all-reduce/all-to-all/permute and is
    the *gathered* size for all-gather (resp. pre-reduce for reduce-scatter's
    operand); we use result bytes consistently — it upper-bounds the logical
    payload that the alpha-beta model (``core/interconnect``) distributes
    over the ring.
    """
    bytes_by: Counter = Counter()
    count_by: Counter = Counter()
    for m in _OP_RE.finditer(hlo_text):
        result_str, opname = m.groups()
        base = opname.rstrip("0123456789.")  # all-reduce-start.1 etc.
        base = base.replace("-start", "").replace("-done", "")
        for kind in COLLECTIVE_OPS:
            if base == kind or base == kind + "-start":
                if opname.endswith("-done"):
                    continue                   # avoid double count async pairs
                bytes_by[kind] += result_bytes(result_str)
                count_by[kind] += 1
                break
    return CollectiveStats(dict(bytes_by), dict(count_by))


def op_census(hlo_text: str) -> Dict[str, int]:
    """Instruction census of an HLO module — the paper's opcode-frequency
    analysis applied to our 'ISA'."""
    census: Counter = Counter()
    for m in _OP_RE.finditer(hlo_text):
        census[m.group(2)] += 1
    return dict(census)


def fusion_count(hlo_text: str) -> int:
    return op_census(hlo_text).get("fusion", 0)


def dot_flops_census(hlo_text: str) -> int:
    """Count dot/convolution ops (the MXU instructions of the module)."""
    c = op_census(hlo_text)
    return c.get("dot", 0) + c.get("convolution", 0)


def collective_bytes(hlo_text: str) -> int:
    return collective_stats(hlo_text).total_bytes


def while_trip_counts(hlo_text: str) -> List[int]:
    """Trip counts of while loops (layer scans) when XLA annotates them."""
    return [int(x) for x in
            re.findall(r'trip_count[="]+(\d+)', hlo_text)]


# ----------------------------------------------------------------------------
# Independent dot-level FLOP accounting (auditable, loop-aware).
#
# XLA's aggregate cost analysis has murky semantics on SPMD-partitioned
# modules with nested while loops, so the roofline's compute term is derived
# here by parsing every dot/convolution in every computation, resolving
# operand shapes, and scaling loop bodies by their trip counts.
# ----------------------------------------------------------------------------

# Computation headers look like "%name (params...) -> type {"; parameter
# lists may contain nested parens (tuple types), so match loosely on the
# arrow + opening brace and the absence of an assignment.
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([a-z0-9]+\[[0-9,]*\])[^\s]*\s+"
    r"([a-z0-9\-]+)\(([^\n]*)$", re.M)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALL_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")


def _split_computations(hlo_text: str):
    """Split module text into {computation_name: [lines]} blocks."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m and " = " not in line:
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class ModuleGraph:
    """Parsed HLO module: per-computation op lines, shapes, call edges."""

    def __init__(self, hlo_text: str, default_trip: int = 1):
        self.comps = _split_computations(hlo_text)
        self.default_trip = default_trip
        em = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
        self.entry = em.group(1) if em else next(iter(self.comps), None)
        self.shapes: Dict[str, Dict[str, str]] = {}
        self.calls: Dict[str, List[Tuple[str, str]]] = {}
        self.param_hints: Dict[str, Dict[int, int]] = {}
        self.root_inplace: Dict[str, Optional[int]] = {}
        call_attr = re.compile(
            r"(?:body|condition|to_apply|calls|branch_computations)="
            r"\{?%?([\w.\-]+)")
        shape_def = re.compile(
            r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([a-z0-9]+\[[0-9,]*\])")
        for cname, lines in self.comps.items():
            table = {}
            edges = []
            for line in lines:
                pm = shape_def.match(line)
                if pm:
                    table[pm.group(1)] = pm.group(2)
                kind = "while" if " while(" in line else "call"
                for sub in call_attr.findall(line):
                    edges.append((kind, sub))
            self.shapes[cname] = table
            self.calls[cname] = edges
        _graph_access_hints(self)

    def scaled_sum(self, per_comp: Dict[str, float],
                   follow_calls: bool = True) -> float:
        """Sum per-computation values over the call graph; while bodies
        multiply by the default trip count."""
        seen = set()

        def total(cname: str) -> float:
            if cname in seen or cname not in self.comps:
                return 0.0
            seen.add(cname)
            t = per_comp.get(cname, 0.0)
            for kind, sub in self.calls.get(cname, []):
                if kind == "while":
                    t += total(sub) * self.default_trip
                elif follow_calls:
                    t += total(sub)
            seen.discard(cname)
            return t

        return total(self.entry) if self.entry else 0.0


# Ops whose operands/results are not real data movement.
_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota"}

_PARAM_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*[^=]*parameter\((\d+)\)")
_DS_RE = re.compile(r"dynamic-slice\(%([\w.\-]+)[,)]")
_DUS_RE = re.compile(r"dynamic-update-slice\(%([\w.\-]+),\s*%([\w.\-]+)[,)]")


def _graph_access_hints(graph):
    """Per computation: param index -> bytes actually touched, for fused
    dynamic-(update-)slice access into big operands (stacked scan weights,
    KV caches). Also records whether the ROOT is an in-place update."""
    for cname, lines in graph.comps.items():
        params = {}
        for line in lines:
            pm = _PARAM_RE.match(line)
            if pm:
                params[pm.group(1)] = int(pm.group(2))
        hints = {}
        root_inplace = None
        for line in lines:
            m = _DEF_RE.match(line)
            sliced = _DS_RE.search(line)
            if sliced and sliced.group(1) in params and m:
                if m.group(3) == "dynamic-slice":
                    hints[params[sliced.group(1)]] = shape_bytes(m.group(2))
            dm = _DUS_RE.search(line)
            if dm and dm.group(1) in params:
                upd = graph.shapes[cname].get(dm.group(2), "")
                hints[params[dm.group(1)]] = shape_bytes(upd)
                if "ROOT" in line:
                    root_inplace = shape_bytes(upd)
        graph.param_hints[cname] = hints
        graph.root_inplace[cname] = root_inplace


def _dot_flops_line(line: str, shape_table: Dict[str, str]) -> float:
    m = _DEF_RE.match(line)
    if not m or m.group(3) != "dot":
        return 0.0
    _, result_shape, _, rest = m.groups()
    out_elems = 1
    for d in _shape_dims(result_shape):
        out_elems *= d
    k = 1
    cm = _CONTRACT_RE.search(line)
    ops = _OPERAND_RE.findall(rest.split(")")[0])
    if cm and ops:
        lhs_dims = _shape_dims(shape_table.get(ops[0], ""))
        for ci in (int(x) for x in cm.group(1).split(",") if x):
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
    return 2.0 * out_elems * k


def _op_bytes_line(line: str, shape_table: Dict[str, str]) -> float:
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    _, result_shape, op, rest = m.groups()
    if op in _FREE_OPS:
        return 0.0
    total = float(shape_bytes(result_shape))
    for name in _OPERAND_RE.findall(rest.split(")")[0]):
        total += shape_bytes(shape_table.get(name, ""))
    return total


def _collective_bytes_line(line: str) -> float:
    m = _OP_RE.match(line)
    if not m:
        return 0.0
    result_str, opname = m.groups()
    base = opname.rstrip("0123456789.")
    base = base.replace("-start", "").replace("-done", "")
    if base in COLLECTIVE_OPS and not opname.endswith("-done"):
        return float(result_bytes(result_str))
    return 0.0


def _per_comp(graph: ModuleGraph, line_fn) -> Dict[str, float]:
    return {cname: sum(line_fn(l, graph.shapes[cname]) for l in lines)
            for cname, lines in graph.comps.items()}


def _comp_bytes(graph: ModuleGraph, cname: str) -> float:
    """Post-fusion bytes of one computation, slice-access aware."""
    total = 0.0
    shape_table = graph.shapes[cname]
    call_attr = re.compile(r"calls=\{?%?([\w.\-]+)")
    for line in graph.comps[cname]:
        m = _DEF_RE.match(line)
        if not m:
            continue
        _, result_shape, op, rest = m.groups()
        if op in _FREE_OPS:
            continue
        if op == "dynamic-slice":
            total += 2.0 * shape_bytes(result_shape)
            continue
        if op == "dynamic-update-slice":
            dm = _DUS_RE.search(line)
            upd = shape_bytes(shape_table.get(dm.group(2), "")) if dm else 0
            total += 2.0 * upd
            continue
        callee = None
        cm = call_attr.search(line)
        if cm:
            callee = cm.group(1)
        hints = graph.param_hints.get(callee, {}) if callee else {}
        # Result: in-place-update fusions write only the update bytes.
        inplace = graph.root_inplace.get(callee) if callee else None
        total += float(inplace if inplace is not None
                       else result_bytes(result_shape))
        for i, opnd in enumerate(_OPERAND_RE.findall(rest.split(")")[0])):
            b = float(shape_bytes(shape_table.get(opnd, "")))
            if i in hints:
                b = min(b, float(hints[i]))
            total += b
    return total


def parsed_flops(hlo_text: str, default_trip: int = 1) -> float:
    """Total dot FLOPs: per-computation dot flops resolved from operand
    shapes, with while-loop bodies multiplied by ``default_trip`` (XLA does
    not annotate CPU trip counts; callers pass the scan length). This is the
    auditable compute source for the roofline — XLA's aggregate
    ``cost_analysis`` has inconsistent loop semantics on partitioned
    modules (see EXPERIMENTS.md §Roofline notes)."""
    graph = ModuleGraph(hlo_text, default_trip)
    return graph.scaled_sum(_per_comp(graph, _dot_flops_line))


def parsed_bytes(hlo_text: str, default_trip: int = 1) -> float:
    """HLO bytes-accessed: operands + results of every top-level op (post
    fusion: a fusion op counts only its external inputs/outputs), loop
    bodies scaled by trip count. Dynamic-(update-)slice access — including
    fused slices of stacked scan weights and KV caches — is charged at the
    touched-slice size, matching in-place TPU semantics. Fusion internals
    are excluded: this is the fused-traffic model for the roofline memory
    term."""
    graph = ModuleGraph(hlo_text, default_trip)
    per = {cname: _comp_bytes(graph, cname) for cname in graph.comps}
    return graph.scaled_sum(per, follow_calls=False)


def parsed_collective_bytes(hlo_text: str, default_trip: int = 1) -> float:
    """Collective payload bytes with correct loop scaling (collectives
    inside a layer scan fire once per trip)."""
    graph = ModuleGraph(hlo_text, default_trip)
    return graph.scaled_sum(
        _per_comp(graph, lambda l, _t: _collective_bytes_line(l)))


def cost_analysis_terms(compiled) -> Dict[str, float]:
    """Extract flops/bytes from a compiled executable's cost analysis."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    # 'bytes accessed' totals all operand+output traffic.
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    if bytes_accessed == 0.0:
        bytes_accessed = sum(v for k, v in ca.items()
                             if k.startswith("bytes accessed"))
    transcendentals = float(ca.get("transcendentals", 0.0))
    return {"flops": flops, "bytes": bytes_accessed,
            "transcendentals": transcendentals}


def memory_analysis_bytes(compiled) -> Optional[Dict[str, float]]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    return {
        "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
        "code_bytes": float(getattr(ma, "generated_code_size_in_bytes", 0)),
    }
