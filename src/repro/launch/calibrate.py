"""Calibration launcher: probe the serving-path cost constants on this
backend and persist them for the engine's ``choose_*`` decisions.

  PYTHONPATH=src python -m repro.launch.calibrate            # full pass
  PYTHONPATH=src python -m repro.launch.calibrate --fast     # CI smoke
  PYTHONPATH=src python -m repro.launch.calibrate --no-persist --json

Each probe prints its measured value next to the hand-set assumption it
replaces and the drift ratio between them; the final line says which
constant set ``resolve_constants`` now returns. Undo with
``REPRO_DEFAULT_CONSTANTS=1`` (or ``--default-constants`` on the serve
launcher) — the defaults stay the documented, reproducible fallback.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core import autotune, calibrate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer trials / smaller sweeps (CI smoke)")
    ap.add_argument("--no-persist", action="store_true",
                    help="measure and report without writing the cache")
    ap.add_argument("--json", action="store_true",
                    help="emit the calibration report as JSON")
    args = ap.parse_args(argv)

    backend = autotune._backend_key()
    persist = not args.no_persist
    t0 = time.time()
    results = calibrate.run_calibration(fast=args.fast, persist=persist)
    elapsed = time.time() - t0
    assumed = autotune.assumed_constants()

    if args.json:
        report = autotune.calibration_report()
        report["probe_details"] = {
            n: dict(value=r.value, unit=r.unit, n_trials=r.n_trials,
                    spread=r.spread, **r.detail)
            for n, r in results.items()}
        print(json.dumps(report, indent=2, sort_keys=True))
    else:
        print(f"== calibration [{backend}:{autotune._mesh_key(None)}] "
              f"{elapsed:.1f}s ==")
        print(f"{'constant':18s} {'measured':>12s} {'assumed':>12s} "
              f"{'drift':>8s} {'unit':>10s} {'n':>4s} {'spread':>7s}")
        for name, r in results.items():
            drift = autotune.drift_ratio(r.value, assumed[name])
            print(f"{name:18s} {r.value:12.4e} {assumed[name]:12.4e} "
                  f"{drift:8.2f} {r.unit:>10s} {r.n_trials:4d} "
                  f"{r.spread:7.2f}")

    resolved = autotune.resolve_constants()
    if persist:
        assert resolved.source == "calibrated", resolved
        assert len(results) >= 5, sorted(results)
    if not args.json:
        verb = "persisted; engine decisions now price from" \
            if persist else "not persisted; engines keep"
        print(f"constants {verb} the "
              f"'{resolved.source}' set "
              f"(backend={resolved.backend or backend}, "
              f"ts={resolved.timestamp:.0f})")
    return results


if __name__ == "__main__":
    main()
