import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with NO device allocation (ShapeDtypeStruct inputs).

For each cell this prints/records:
  * ``compiled.memory_analysis()``  — proves the per-device footprint fits;
  * ``compiled.cost_analysis()``    — HLO FLOPs/bytes for §Roofline;
  * collective bytes parsed from the HLO text — the roofline's third term.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out artifacts/
Perf-iteration knobs (EXPERIMENTS.md §Perf): --kv-dtype, --moe-impl,
--no-remat, --no-fsdp, --flash.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import shapes as shapes_mod
from repro.core import hlo_analysis, roofline
from repro.dist import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.models import transformer as T
from repro.optim import adamw
from repro.serve import engine as serve_engine
from repro.train import steps as train_steps


# ----------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input.
# ----------------------------------------------------------------------------

def input_specs(cfg: T.ModelConfig, shape: shapes_mod.ShapeSpec,
                kv_dtype=None) -> Dict[str, Any]:
    """ShapeDtypeStructs for one cell (weak-type-correct, shardable, no
    allocation)."""
    b = shape.global_batch
    kv_dtype = kv_dtype or cfg.dtype
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
        }
        if cfg.n_frontend_tokens:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype)
        return specs
    if shape.kind == "prefill":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            "caches": jax.eval_shape(
                lambda: T.init_caches(cfg, b, shape.seq_len, dtype=kv_dtype)),
        }
        if cfg.n_frontend_tokens:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype)
        return specs
    # decode: one new token against a seq_len cache.
    specs = {
        "last_tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "caches": jax.eval_shape(
            lambda: T.init_caches(cfg, b, shape.seq_len, dtype=kv_dtype)),
    }
    if cfg.n_frontend_tokens:
        specs["cross_kv"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype)
    return specs


# ----------------------------------------------------------------------------
# Sharding construction
# ----------------------------------------------------------------------------

def state_shardings(cfg: T.ModelConfig, ruleset: shd.Ruleset):
    shapes = jax.eval_shape(
        lambda k: train_steps.init_state(k, cfg).tree(),
        jax.random.PRNGKey(0))
    mesh = ruleset.mesh

    def leaf_spec(path, leaf):
        names = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
        return jax.sharding.NamedSharding(
            mesh, shd.param_spec(names, leaf.shape, ruleset))

    return jax.tree_util.tree_map_with_path(leaf_spec, shapes), shapes


def batch_shardings(specs, ruleset: shd.Ruleset, shape_kind: str):
    mesh = ruleset.mesh

    def spec_for(path, leaf):
        names = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
        key = names[0] if names else ""
        dims: list = [None] * len(leaf.shape)
        if key in ("tokens", "labels", "last_tokens", "frontend", "cross_kv"):
            dims[0] = "batch"
        elif key == "caches":
            leafname = names[-1]
            if leafname in ("k", "v"):
                # (periods, b, cache_len, kvh, hd)
                dims = [None, "batch", "cache_seq", "kv_heads", None]
            elif leafname == "conv":
                dims = [None, "batch", None, "ssm_heads", None]
            elif leafname == "ssm":
                dims = [None, "batch", "ssm_heads", None, None]
            else:                       # index
                dims = [None] * len(leaf.shape)
        return jax.sharding.NamedSharding(
            mesh, ruleset.spec(dims, leaf.shape))

    return jax.tree_util.tree_map_with_path(spec_for, specs)


# ----------------------------------------------------------------------------
# Cell lowering
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    compile_s: float = 0.0
    memory: Optional[Dict[str, float]] = None
    cost: Optional[Dict[str, float]] = None
    collective_bytes: float = 0.0
    collective_detail: Optional[Dict[str, int]] = None
    roofline: Optional[Dict[str, Any]] = None


def prepare_cfg(cfg: T.ModelConfig, args) -> T.ModelConfig:
    upd: Dict[str, Any] = {"compute_dtype": "bfloat16",
                           "scan_layers": True}
    upd["remat"] = not args.no_remat
    if args.moe_impl:
        upd["moe_impl"] = args.moe_impl
    if args.flash:
        upd["use_flash"] = True
    if args.expand_kv:
        upd["expand_kv"] = True
    if args.bf16_probs:
        upd["attn_probs_fp32"] = False
    if args.remat_policy:
        upd["remat_policy"] = args.remat_policy
    if args.capacity_factor:
        upd["moe_capacity_factor"] = args.capacity_factor
    return dataclasses.replace(cfg, **upd)


def lower_cell(arch_id: str, shape_name: str, mesh, args) -> CellResult:
    mesh_name = mesh_mod.describe(mesh)
    ok, why = shapes_mod.runnable(arch_id, shape_name)
    if not ok:
        return CellResult(arch_id, shape_name, mesh_name, ok=True,
                          skipped=True, reason=why)
    cfg = prepare_cfg(configs.get_config(arch_id), args)
    shape = shapes_mod.SHAPES[shape_name]
    rules = {}
    if shape.name == "long_500k":
        # Sequence parallelism: the 500k cache shards over the data axis.
        rules["cache_seq"] = "data"
    if args.replicate_experts:
        # EP-off: expert weights replicate; MoE dispatch goes chip-local
        # (trades HBM for the all-to-all/all-reduce dispatch traffic).
        rules["experts"] = None
    if args.shard_cache_seq:
        # Sequence-parallel KV cache over the model axis: the fix for GQA
        # archs whose kv_heads don't divide the axis (attention runs with
        # partial-softmax collectives instead of a replicated cache).
        rules["cache_seq"] = args.shard_cache_seq
    ruleset = shd.Ruleset(rules=rules, mesh=mesh, fsdp=not args.no_fsdp
                          and shape.kind == "train")
    kv_dtype = jnp.int8 if args.kv_dtype == "int8" else cfg.dtype
    specs = input_specs(cfg, shape, kv_dtype=kv_dtype)
    chips = int(np.prod(list(mesh.shape.values())))

    t0 = time.time()
    with mesh, shd.use_ruleset(ruleset):
        if shape.kind == "train":
            step = train_steps.make_train_step(cfg,
                                               accum_steps=args.accum)
            state_sh, state_shapes = state_shardings(cfg, ruleset)
            batch_sh = batch_shardings(specs, ruleset, shape.kind)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,)).lower(state_shapes, specs)
            mode = "train"
            cache_len = 0
        elif shape.kind == "prefill":
            def prefill_fn(params, tokens, caches, frontend=None):
                return serve_engine.prefill(params, cfg, tokens, caches,
                                            frontend_embeds=frontend)

            serve_dtype = jnp.bfloat16 if args.serve_params_bf16 else None
            param_sh, param_shapes = _param_only_shardings(cfg, ruleset,
                                                           dtype=serve_dtype)
            batch_sh = batch_shardings(specs, ruleset, shape.kind)
            in_sh = (param_sh, batch_sh["tokens"], batch_sh["caches"])
            lower_args = [param_shapes, specs["tokens"], specs["caches"]]
            if "frontend" in specs:
                in_sh = in_sh + (batch_sh["frontend"],)
                lower_args.append(specs["frontend"])
            lowered = jax.jit(
                prefill_fn, in_shardings=in_sh,
                out_shardings=None).lower(*lower_args)
            mode = "prefill"
            cache_len = 0
        else:
            def serve_fn(params, last_tokens, caches, cross_kv=None):
                logits, new_caches, _ = T.forward(
                    params, cfg, last_tokens[:, None], caches=caches,
                    cross_kv=cross_kv)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, new_caches

            serve_dtype = jnp.bfloat16 if args.serve_params_bf16 else None
            param_sh, param_shapes = _param_only_shardings(cfg, ruleset,
                                                           dtype=serve_dtype)
            batch_sh = batch_shardings(specs, ruleset, shape.kind)
            in_sh = (param_sh, batch_sh["last_tokens"], batch_sh["caches"])
            lower_args = [param_shapes, specs["last_tokens"], specs["caches"]]
            if "cross_kv" in specs:
                in_sh = in_sh + (batch_sh["cross_kv"],)
                lower_args.append(specs["cross_kv"])
            lowered = jax.jit(
                serve_fn, in_shardings=in_sh, out_shardings=None,
                donate_argnums=(2,)).lower(*lower_args)
            mode = "decode"
            cache_len = shape.seq_len

        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = hlo_analysis.memory_analysis_bytes(compiled)
    cost = hlo_analysis.cost_analysis_terms(compiled)
    text = compiled.as_text()
    stats = hlo_analysis.collective_stats(text)
    seq_for_flops = 1 if shape.kind == "decode" else shape.seq_len
    mf = T.model_flops(cfg, shape.global_batch, seq_for_flops,
                       mode="train" if mode == "train" else "inference",
                       cache_len=cache_len)
    terms = roofline.terms_from_compiled(
        arch_id, shape_name, mesh_name, chips, compiled, mf,
        hlo_text=text, scan_trips=cfg.periods)
    return CellResult(
        arch=arch_id, shape=shape_name, mesh=mesh_name, ok=True,
        compile_s=compile_s, memory=mem, cost=cost,
        collective_bytes=float(stats.total_bytes),
        collective_detail=stats.bytes_by_kind,
        roofline=terms.to_dict())


def _param_only_shardings(cfg: T.ModelConfig, ruleset: shd.Ruleset,
                          dtype=None):
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, dtype if l.dtype == jnp.float32 else l.dtype),
            shapes)
    mesh = ruleset.mesh

    def leaf_spec(path, leaf):
        names = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
        # Serving keeps params TP-sharded only (no FSDP gather per token).
        serve_rules = shd.Ruleset(rules=ruleset.rules, mesh=mesh, fsdp=False)
        return jax.sharding.NamedSharding(
            mesh, shd.param_spec(names, leaf.shape, serve_rules))

    return jax.tree_util.tree_map_with_path(leaf_spec, shapes), shapes


# ----------------------------------------------------------------------------
# Driver
# ----------------------------------------------------------------------------

def run(args) -> int:
    mesh_kinds = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
    arch_ids = ([configs.canonical_id(a) for a in configs.list_archs()]
                if args.arch == "all" else [args.arch])
    shape_names = (list(shapes_mod.SHAPES) if args.shape == "all"
                   else [args.shape])
    results = []
    failures = 0
    for mesh_kind in mesh_kinds:
        mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
        for arch_id in arch_ids:
            for shape_name in shape_names:
                tag = f"{arch_id} x {shape_name} @ {mesh_mod.describe(mesh)}"
                try:
                    res = lower_cell(arch_id, shape_name, mesh, args)
                except Exception as e:  # noqa: BLE001 — report and continue
                    traceback.print_exc()
                    res = CellResult(arch_id, shape_name,
                                     mesh_mod.describe(mesh), ok=False,
                                     reason=f"{type(e).__name__}: {e}")
                    failures += 1
                results.append(res)
                if res.skipped:
                    print(f"[skip] {tag}: {res.reason}", flush=True)
                elif res.ok:
                    r = res.roofline
                    print(f"[ok]   {tag}: compile={res.compile_s:.1f}s "
                          f"flops/chip={res.cost['flops']:.3e} "
                          f"bytes/chip={res.cost['bytes']:.3e} "
                          f"coll={res.collective_bytes:.3e} "
                          f"dominant={r['dominant']} "
                          f"frac={r['roofline_fraction']:.3f}", flush=True)
                    if args.verbose:
                        print(f"       memory_analysis: {res.memory}")
                        print(f"       collectives: {res.collective_detail}")
                else:
                    print(f"[FAIL] {tag}: {res.reason}", flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump([dataclasses.asdict(r) for r in results], f, indent=1)
        print(f"wrote {args.out}")
    print(f"{sum(1 for r in results if r.ok and not r.skipped)} ok, "
          f"{sum(1 for r in results if r.skipped)} skipped, "
          f"{failures} failed")
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="")
    ap.add_argument("--verbose", action="store_true")
    # Perf-iteration knobs (§Perf)
    ap.add_argument("--kv-dtype", default="", choices=["", "int8"])
    ap.add_argument("--moe-impl", default="",
                    choices=["", "capacity", "dense_mask"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--expand-kv", action="store_true")
    ap.add_argument("--bf16-probs", action="store_true")
    ap.add_argument("--remat-policy", default="", choices=["", "full", "dots"])
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches")
    ap.add_argument("--replicate-experts", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--shard-cache-seq", default="",
                    choices=["", "model", "data"])
    ap.add_argument("--serve-params-bf16", action="store_true")
    sys.exit(run(ap.parse_args()))


if __name__ == "__main__":
    main()
