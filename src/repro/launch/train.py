"""Training launcher.

Single-process entry point that scales: on a real multi-host TPU deployment
``jax.distributed.initialize()`` is called (guarded), the same mesh/ruleset
code paths drive 8 or 8192 chips, and the Trainer provides checkpoints,
crash recovery and the straggler watchdog.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \\
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/run1
Overrides: --key=value pairs map onto ModelConfig fields
(e.g. --moe_impl=dense_mask --compute_dtype=float32).
"""

from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import DataConfig, SyntheticLMData
from repro.dist import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.models import transformer as T
from repro.optim import schedule
from repro.train import steps as steps_mod
from repro.train.trainer import Trainer, TrainerConfig


def maybe_init_distributed():
    if os.environ.get("REPRO_MULTIHOST") == "1":     # pragma: no cover
        jax.distributed.initialize()


def build(cfg: T.ModelConfig, args, mesh=None):
    ruleset = shd.Ruleset(mesh=mesh, fsdp=args.fsdp) if mesh else None
    sched = schedule.ScheduleConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                                    total_steps=args.steps)
    step = steps_mod.make_train_step(cfg, sched=sched,
                                     accum_steps=args.accum,
                                     compress_grads=args.compress_grads,
                                     error_feedback=args.error_feedback)
    step = jax.jit(step, donate_argnums=(0,))

    def init_fn():
        with shd.use_ruleset(ruleset):
            return steps_mod.init_state(
                jax.random.PRNGKey(args.seed), cfg,
                error_feedback=args.error_feedback).tree()

    def wrapped_step(state, batch):
        with shd.use_ruleset(ruleset):
            return step(state, batch)

    return wrapped_step, init_fn


def frontend_stub(cfg: T.ModelConfig):
    if not cfg.n_frontend_tokens:
        return None

    def make(batch):
        return jnp.zeros((batch, cfg.n_frontend_tokens, cfg.d_model),
                         cfg.dtype)

    return make


def apply_overrides(cfg: T.ModelConfig, overrides: Dict[str, Any]):
    fields = {f.name: f for f in dataclasses.fields(cfg)}
    typed = {}
    for k, v in overrides.items():
        assert k in fields, f"unknown config field {k}"
        t = type(getattr(cfg, k))
        typed[k] = t(v) if t is not type(None) and not isinstance(v, t) else v
    return dataclasses.replace(cfg, **typed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry the int8 quantization residual in "
                         "TrainState (EF-SGD: bias-free compression); "
                         "implies --compress-grads")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    args, extra = ap.parse_known_args(argv)
    if args.error_feedback:
        args.compress_grads = True

    maybe_init_distributed()
    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    overrides = dict(kv.lstrip("-").split("=", 1) for kv in extra if "=" in kv)
    if overrides:
        cfg = apply_overrides(cfg, overrides)

    mesh = None
    if args.mesh != "none":
        mesh = mesh_mod.make_production_mesh(multi_pod=args.mesh == "multi")

    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch,
                                      seed=args.seed))
    step_fn, init_fn = build(cfg, args, mesh)
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every,
                      checkpoint_dir=args.ckpt),
        cfg, data, step_fn, init_fn, frontend_fn=frontend_stub(cfg))
    result = trainer.run()
    for m in result["metrics"]:
        print(f"step {m['step']:5d} loss={m['loss']:.4f} "
              f"nll={m['nll']:.4f} lr={m['lr']:.2e} dt={m['dt']:.3f}s")
    print(f"done: {len(result['metrics'])} logs, "
          f"{result['recoveries']} recoveries, "
          f"{len(result['stragglers'])} stragglers")
    return result


if __name__ == "__main__":
    main()
