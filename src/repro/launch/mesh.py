"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required for the smoke
tests (1 device) and the dry-run (512 placeholder devices) to coexist.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

try:  # jax >= 0.5: meshes carry explicit axis types.
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: no axis_types kwarg; Auto is the default.
    AxisType = None


def _make(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests, pipeline demos, elastic restore targets)."""
    return _make(shape, axes)


def single_device_mesh():
    return make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(tp: Optional[int] = None):
    """1-D tensor-parallel mesh for the serving engine: ``("model",)``
    over ``tp`` devices (default: all visible). Serving has no data axis
    — every device holds the same slots and a shard of every weight and
    of the KV page pool; ``tp=1`` returns None so the engine takes its
    unsharded (mesh-blind) path rather than a degenerate shard_map."""
    tp = tp if tp is not None else jax.device_count()
    if tp <= 1:
        return None
    assert tp <= jax.device_count(), (tp, jax.device_count())
    return make_mesh((tp,), ("model",))


def describe(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())
