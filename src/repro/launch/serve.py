"""Serving launcher: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
      --requests 8 --max-new 16

Distributed serving shards the same engine over a 1-D mesh (weights
tensor-parallel, KV page pool device-sharded — see serve/README.md):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
      --paged --tp 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.serve.engine import Request, ServeConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="KV rows from a shared page pool (serve/paged.py)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="prefill chunk rows (paged; page-size multiple); "
                         "default: the autotune chunk cost model's choice")
    ap.add_argument("--pool-frac", type=float, default=1.0,
                    help="pool size as a fraction of the contiguous "
                         "batch*max_len reservation (>= 1.0 keeps the "
                         "full, exhaustion-free equivalent)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: drafted tokens per verify "
                         "tick (paged only; 0 disables — see "
                         "core.autotune.choose_spec_k for when that wins)")
    ap.add_argument("--draft", default="ngram",
                    help="draft source for --spec-k: 'ngram' (prompt "
                         "lookup, no second model), 'self' (sliding-window "
                         "self-speculation), or a configs/ arch name")
    ap.add_argument("--tp", type=int, default=None,
                    help="shard the engine tensor-parallel over this many "
                         "devices (paged only; weights TP, KV page pool "
                         "device-sharded). 1 = unsharded")
    ap.add_argument("--mesh", default=None,
                    help="explicit serving mesh as AXIS=N (e.g. model=8); "
                         "alternative spelling of --tp")
    args = ap.parse_args(argv)

    if args.spec_k and not args.paged:
        raise SystemExit("--spec-k needs --paged (verify runs the paged "
                         "s>1 attention path)")
    if args.tp is not None and args.mesh is not None:
        raise SystemExit("--tp and --mesh are alternative spellings; "
                         "pass one")
    mesh = None
    if args.mesh is not None:
        axis, _, size = args.mesh.partition("=")
        if axis != "model" or not size.isdigit():
            raise SystemExit(f"--mesh wants model=N, got {args.mesh!r}")
        mesh = mesh_lib.make_serving_mesh(int(size))
    elif args.tp is not None:
        mesh = mesh_lib.make_serving_mesh(args.tp)
    if mesh is not None and not args.paged:
        raise SystemExit("--tp/--mesh need --paged (the shard unit of the "
                         "distributed engine is the KV page)")

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    if cfg.encoder is not None or cfg.n_frontend_tokens:
        raise SystemExit("serve launcher demo supports decoder-only archs")
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    n_pages = None
    if args.paged and args.pool_frac < 1.0:
        # At least 2 (null page + one real page): a tiny fraction should
        # degrade to a tiny-but-usable pool, not an assert.
        n_pages = max(2, 1 + int(args.batch * args.max_len
                                 // args.page_size * args.pool_frac))
    engine = ServingEngine(params, cfg,
                           ServeConfig(max_len=args.max_len,
                                       batch=args.batch, paged=args.paged,
                                       page_size=args.page_size,
                                       n_pages=n_pages,
                                       chunk_size=args.chunk_size,
                                       spec_k=args.spec_k,
                                       draft=args.draft),
                           mesh=mesh)
    rng = np.random.RandomState(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.randint(2, cfg.vocab, size=rng.randint(4, 12))
        engine.submit(Request(rid=rid, prompt=prompt.astype(np.int32),
                              max_new=args.max_new))
    finished = engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(v) for v in finished.values())
    print(f"served {len(finished)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    if engine.pool is not None:
        occ = engine.pool.occupancy()
        mesh_note = (f" over {occ['n_devices']} devices"
                     if occ["n_devices"] > 1 else "")
        print(f"  paged: {occ['high_water']}/{occ['capacity']} pages "
              f"high-water ({args.page_size} rows each){mesh_note}, "
              f"chunk={engine.chunk}, "
              f"{engine.admission_rejections} admission holds, "
              f"{engine.preemptions} preemptions")
    if engine.spec_k:
        ticks = max(1, engine.spec_ticks)
        print(f"  spec: k={engine.spec_k} draft={args.draft} "
              f"accepted/tick={engine.spec_accepted / ticks:.2f} "
              f"emitted/tick={engine.spec_emitted / ticks:.2f} "
              f"({engine.verify_traces} verify executable)")
    for rid in sorted(finished):
        print(f"  req {rid}: {finished[rid][:10]}...")
    return finished


if __name__ == "__main__":
    main()
