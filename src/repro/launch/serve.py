"""Serving launcher: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
      --requests 8 --max-new 16

Distributed serving shards the same engine over a 1-D mesh (weights
tensor-parallel, KV page pool device-sharded — see serve/README.md):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
      --paged --tp 8

Open-loop traffic mode (--rate) replaces the batch submit with the
seeded arrival generator, SLO-aware admission, and the operator report
(TTFT/TPOT percentiles, goodput, shed rate); --faults adds the canonical
fault schedule on top:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
      --paged --rate 2.0 --process bursty --max-queue 8 \\
      --max-preemptions 3 --degrade --tenant \\
      "name=paid,priority=2,weight=1" --tenant \\
      "name=free,weight=3,rate=2,burst=16,ttft=32"
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import configs
from repro.core import autotune
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.serve import traffic
from repro.serve.engine import Request, ServeConfig, ServingEngine, SLOClass
from repro.serve.faults import FaultInjector, canonical_schedule


def _parse_tenant(spec: str):
    """``name=paid,priority=2,rate=1.5,burst=8,ttft=16,tpot=4,weight=1``
    -> (SLOClass, TrafficClass) with unset fields at their defaults."""
    kv = {}
    for part in spec.split(","):
        k, _, v = part.partition("=")
        if not _ or not k:
            raise SystemExit(f"--tenant wants k=v pairs, got {part!r}")
        kv[k.strip()] = v.strip()
    name = kv.pop("name", None)
    if not name:
        raise SystemExit(f"--tenant needs name=..., got {spec!r}")
    num = lambda k, d=None: float(kv[k]) if k in kv else d  # noqa: E731
    slo = SLOClass(name, priority=int(num("priority", 0)),
                   ttft_slo=num("ttft"), tpot_slo=num("tpot"),
                   rate=num("rate"), burst=num("burst"))
    tcls = traffic.TrafficClass(
        name, weight=num("weight", 1.0),
        prompt_lo=int(num("prompt-lo", 4)),
        prompt_hi=int(num("prompt-hi", 12)),
        out_lo=int(num("out-lo", 2)), out_hi=int(num("out-hi", 8)),
        ttft_ms=num("ttft-ms"), tpot_ms=num("tpot-ms"),
        sessions=int(num("sessions", 0)),
        prefix_len=int(num("prefix-len", 0)))
    known = {"priority", "ttft", "tpot", "rate", "burst", "weight",
             "prompt-lo", "prompt-hi", "out-lo", "out-hi",
             "ttft-ms", "tpot-ms", "sessions", "prefix-len"}
    if set(kv) - known:
        raise SystemExit(f"--tenant unknown keys {sorted(set(kv) - known)}")
    return slo, tcls


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="KV rows from a shared page pool (serve/paged.py)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="prefill chunk rows (paged; page-size multiple); "
                         "default: the autotune chunk cost model's choice")
    ap.add_argument("--prefix-cache", default=False,
                    action=argparse.BooleanOptionalAction,
                    help="share full-page-aligned prompt prefixes across "
                         "requests through the page table (paged only; "
                         "refcounted pages + copy-on-write — admission "
                         "skips prefill for cached prefixes, streams stay "
                         "bit-identical)")
    ap.add_argument("--pool-frac", type=float, default=1.0,
                    help="pool size as a fraction of the contiguous "
                         "batch*max_len reservation (>= 1.0 keeps the "
                         "full, exhaustion-free equivalent)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: drafted tokens per verify "
                         "tick (paged only; 0 disables — see "
                         "core.autotune.choose_spec_k for when that wins)")
    ap.add_argument("--draft", default="ngram",
                    help="draft source for --spec-k: 'ngram' (prompt "
                         "lookup, no second model), 'self' (sliding-window "
                         "self-speculation), or a configs/ arch name")
    ap.add_argument("--tp", type=int, default=None,
                    help="shard the engine tensor-parallel over this many "
                         "devices (paged only; weights TP, KV page pool "
                         "device-sharded). 1 = unsharded")
    ap.add_argument("--mesh", default=None,
                    help="explicit serving mesh as AXIS=N (e.g. model=8); "
                         "alternative spelling of --tp")
    traf = ap.add_argument_group(
        "open-loop traffic / SLO admission",
        "--rate switches from the batch submit to the seeded open-loop "
        "generator (serve/traffic.py): requests arrive on a Poisson or "
        "bursty (MMPP) clock, admission is SLO-aware, and the run ends "
        "with the operator report.")
    traf.add_argument("--rate", type=float, default=None,
                      help="offered load in requests per engine tick "
                           "(enables traffic mode)")
    traf.add_argument("--process", choices=("poisson", "bursty"),
                      default="poisson",
                      help="arrival process; 'bursty' modulates the rate "
                           "by --burst-factor in burst state")
    traf.add_argument("--burst-factor", type=float, default=8.0,
                      help="bursty-state rate multiplier (MMPP)")
    traf.add_argument("--tenant", action="append", default=[],
                      help="repeatable tenant class: 'name=paid,priority=2,"
                           "rate=1.5,burst=8,ttft=16,tpot=4,weight=1,"
                           "prompt-lo=4,prompt-hi=12,out-lo=2,out-hi=8'. "
                           "priority orders admission and shedding; "
                           "rate/burst meter a token bucket; ttft/tpot set "
                           "the SLO targets the report scores (ticks); "
                           "ttft-ms/tpot-ms score the same wall-clock "
                           "against the measured tick time")
    traf.add_argument("--max-queue", type=int, default=None,
                      help="bounded admission queue: overflow sheds the "
                           "lowest-priority newest request (explicit "
                           "rejected: outcome, never a silent drop)")
    traf.add_argument("--max-preemptions", type=int, default=None,
                      help="fairness cap: a request preempted this many "
                           "times is force-completed or cleanly rejected "
                           "instead of being evicted again")
    traf.add_argument("--degrade", action="store_true",
                      help="automatic load-shedding downshifts under "
                           "pressure (spec off, prefill budget 1); "
                           "stream-transparent, recovers on its own")
    traf.add_argument("--spec-probe-every", type=int, default=None,
                      help="after an accept-rate collapse disables "
                           "speculation, run a k=1 trial tick this often "
                           "so it can re-open (needs --spec-k and the "
                           "adaptation clock)")
    traf.add_argument("--faults", action="store_true",
                      help="run the canonical seeded fault schedule (pool "
                           "squeeze -> accept collapse -> churn storm) "
                           "against the traffic")
    obs = ap.add_argument_group(
        "observability (serve/telemetry.py)",
        "Structured tick traces and wall-clock spans are on by default "
        "(ring-buffered, overhead-bounded, stream-transparent).")
    obs.add_argument("--trace-out", default=None, metavar="PATH",
                     help="write the Chrome-trace/Perfetto JSON timeline "
                          "here after the run (open at ui.perfetto.dev)")
    obs.add_argument("--no-telemetry", action="store_true",
                     help="disable the event ring and wall-clock spans "
                          "(decision counters stay exact either way)")
    obs.add_argument("--default-constants", action="store_true",
                     help="price choose_* decisions from the hand-set "
                          "default constants, skipping any calibrated: "
                          "cache entries (reproducibility escape hatch; "
                          "see repro.launch.calibrate)")
    args = ap.parse_args(argv)

    if args.default_constants:
        os.environ[autotune.DEFAULT_CONSTANTS_ENV] = "1"

    if args.spec_k and not args.paged:
        raise SystemExit("--spec-k needs --paged (verify runs the paged "
                         "s>1 attention path)")
    if args.tp is not None and args.mesh is not None:
        raise SystemExit("--tp and --mesh are alternative spellings; "
                         "pass one")
    mesh = None
    if args.mesh is not None:
        axis, _, size = args.mesh.partition("=")
        if axis != "model" or not size.isdigit():
            raise SystemExit(f"--mesh wants model=N, got {args.mesh!r}")
        mesh = mesh_lib.make_serving_mesh(int(size))
    elif args.tp is not None:
        mesh = mesh_lib.make_serving_mesh(args.tp)
    if mesh is not None and not args.paged:
        raise SystemExit("--tp/--mesh need --paged (the shard unit of the "
                         "distributed engine is the KV page)")
    if args.rate is None and (args.tenant or args.faults):
        raise SystemExit("--tenant/--faults need --rate (traffic mode)")
    if args.prefix_cache and not args.paged:
        raise SystemExit("--prefix-cache needs --paged (sharing happens "
                         "through the page table)")
    if args.spec_probe_every is not None and not args.spec_k:
        raise SystemExit("--spec-probe-every needs --spec-k")

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    if cfg.encoder is not None or cfg.n_frontend_tokens:
        raise SystemExit("serve launcher demo supports decoder-only archs")
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    n_pages = None
    if args.paged and args.pool_frac < 1.0:
        # At least 2 (null page + one real page): a tiny fraction should
        # degrade to a tiny-but-usable pool, not an assert.
        n_pages = max(2, 1 + int(args.batch * args.max_len
                                 // args.page_size * args.pool_frac))
    tenants = [_parse_tenant(s) for s in args.tenant]
    scfg = ServeConfig(
        max_len=args.max_len, batch=args.batch, paged=args.paged,
        page_size=args.page_size, n_pages=n_pages,
        chunk_size=args.chunk_size, prefix_cache=args.prefix_cache,
        spec_k=args.spec_k, draft=args.draft,
        classes=tuple(slo for slo, _ in tenants) or None,
        max_queue=args.max_queue, max_preemptions=args.max_preemptions,
        degrade=args.degrade,
        spec_adapt_every=(args.spec_probe_every
                          if args.spec_probe_every else None),
        spec_probe_every=args.spec_probe_every,
        telemetry=not args.no_telemetry)
    if args.trace_out and args.no_telemetry:
        raise SystemExit("--trace-out needs telemetry (drop --no-telemetry)")
    engine = ServingEngine(params, cfg, scfg, mesh=mesh)
    t0 = time.time()
    if args.rate is not None:
        tcfg = traffic.TrafficConfig(
            rate=args.rate, n_requests=args.requests, seed=args.seed,
            process=args.process, burst_factor=args.burst_factor,
            vocab=cfg.vocab, max_prompt=args.max_len - args.max_new,
            classes=tuple(t for _, t in tenants) or
            (traffic.TrafficClass("default", out_lo=2,
                                  out_hi=max(2, args.max_new)),))
        arrivals = traffic.TrafficGenerator(tcfg).arrivals()
        inj = FaultInjector(canonical_schedule()) if args.faults else None
        res = traffic.run_open_loop(engine, arrivals, injector=inj)
        if inj is not None:
            inj.finish(engine)
        dt = time.time() - t0
        s = traffic.summarize(engine, arrivals, classes=tcfg.classes)
        print(f"offered {s['offered']} requests at rate {args.rate} "
              f"({args.process}): {s['done']} done, {s['forced']} forced, "
              f"{s['rejected']} rejected, {len(res['unresolved'])} "
              f"unresolved in {s['ticks']} ticks / {dt:.2f}s")
        print(f"  ttft p50/p99 {s['ttft_p50']:.0f}/{s['ttft_p99']:.0f} "
              f"ticks, tpot p50/p99 {s['tpot_p50']:.2f}/{s['tpot_p99']:.2f}"
              f", goodput {s['goodput_tokens_per_tick']:.2f} tok/tick, "
              f"shed {s['shed_rate']:.2f}")
        print(f"  preemptions {s['preemptions']}, admission holds "
              f"{s['admission_holds']}, downshifts {s['downshifts']} "
              f"({s['degraded_ticks']} degraded ticks), spec probes "
              f"{engine.spec_probes}")
        if "tick_wall_s_mean" in s:
            print(f"  wall-clock: tick mean/p99 "
                  f"{s['tick_wall_s_mean'] * 1e3:.2f}/"
                  f"{s['tick_wall_s_p99'] * 1e3:.2f} ms, ttft p50 "
                  f"{s['ttft_ms_p50']:.0f} ms, tpot p50 "
                  f"{s['tpot_ms_p50']:.1f} ms/token")
        if inj is not None:
            print(f"  faults: {inj.injected} injected, {inj.cleared} "
                  f"cleared, {engine.pool.pages_in_use if engine.pool else 0}"
                  f" pages leaked")
        for name, c in sorted(s["by_class"].items()):
            slo = (f", ttft-slo {c['ttft_slo_attainment']:.0%}"
                   if "ttft_slo_attainment" in c else "")
            slo += (f", ttft-ms-slo {c['ttft_ms_slo_attainment']:.0%}"
                    if "ttft_ms_slo_attainment" in c else "")
            print(f"  class {name}: {c['done']}/{c['offered']} done, "
                  f"shed {engine.shed_by_class.get(name, 0)}{slo}")
        finished = engine.finished
    else:
        rng = np.random.RandomState(args.seed)
        for rid in range(args.requests):
            prompt = rng.randint(2, cfg.vocab, size=rng.randint(4, 12))
            engine.submit(Request(rid=rid, prompt=prompt.astype(np.int32),
                                  max_new=args.max_new))
        finished = engine.run_until_drained()
        dt = time.time() - t0
        toks = sum(len(v) for v in finished.values())
        print(f"served {len(finished)} requests, {toks} tokens "
              f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    # Which constant set priced this session's choose_* decisions —
    # operators need to tell a stale calibration from a fresh one.
    const = engine.constants
    if const.source == "calibrated":
        age_min = max(0.0, (time.time() - const.timestamp) / 60.0)
        print(f"  constants: calibrated [{const.backend}:{const.mesh}] "
              f"priced choose_* (measured {age_min:.0f} min ago, "
              f"ts={const.timestamp:.0f}; --default-constants forces "
              f"the hand-set defaults)")
    else:
        print("  constants: hand-set defaults priced choose_* (run "
              "python -m repro.launch.calibrate to measure this backend)")
    if engine.pool is not None:
        occ = engine.pool.occupancy()
        mesh_note = (f" over {occ['n_devices']} devices"
                     if occ["n_devices"] > 1 else "")
        print(f"  paged: {occ['high_water']}/{occ['capacity']} pages "
              f"high-water ({args.page_size} rows each){mesh_note}, "
              f"{occ['pages_allocated']} alloc / {occ['pages_freed']} "
              f"freed, chunk={engine.chunk}, "
              f"{engine.admission_rejections} admission holds, "
              f"{engine.preemptions} preemptions")
        if engine.prefix is not None:
            # Prefix-cache operator report: sharing state of the live
            # pool + cumulative hit/COW/eviction traffic. hit rate is
            # over admissions that probed (hits + misses).
            probes = engine.prefix_hits + engine.prefix_misses
            hit_rate = engine.prefix_hits / probes if probes else 0.0
            print(f"  prefix cache: {occ['pages_shared']} shared / "
                  f"{occ['pages_exclusive']} exclusive / "
                  f"{occ['pages_cached_idle']} cached-idle pages, "
                  f"index {len(engine.prefix)} entries, "
                  f"hit rate {hit_rate:.0%} ({engine.prefix_hits}/"
                  f"{probes} admissions, {engine.prefix_hit_pages} pages "
                  f"mapped), {occ['cow_count']} cow copies, "
                  f"{engine.prefix.evicted_pages} evicted")
    if engine.spec_k:
        ticks = max(1, engine.spec_ticks)
        print(f"  spec: k={engine.spec_k} draft={args.draft} "
              f"accepted/tick={engine.spec_accepted / ticks:.2f} "
              f"emitted/tick={engine.spec_emitted / ticks:.2f} "
              f"({engine.verify_traces} verify executable)")
    tel = engine.telemetry
    tstats = tel.tick_stats()
    if tstats["n"]:
        print(f"  telemetry: tick p50/p99 {tstats['p50_s'] * 1e3:.2f}/"
              f"{tstats['p99_s'] * 1e3:.2f} ms over {tstats['n']} ticks, "
              f"{len(tel.events)} events in ring "
              f"({tel.dropped_events} evicted)")
        for name, st in sorted(tel.span_stats().items()):
            print(f"    span {name}: n={st['n']} "
                  f"exec-mean={st['execute_mean_s'] * 1e3:.2f} ms "
                  f"(compile {st['compile_n']}x "
                  f"{st['compile_s'] * 1e3:.1f} ms)")
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            json.dump(tel.chrome_trace(), f)
        print(f"  wrote {args.trace_out} "
              f"(open at ui.perfetto.dev or chrome://tracing)")
    for rid in sorted(finished):
        print(f"  req {rid}: {finished[rid][:10]}...")
    return finished


if __name__ == "__main__":
    main()
