from repro.checkpoint.manager import (CheckpointManager, load_checkpoint,  # noqa
                                      save_checkpoint)
