"""Fault-tolerant checkpointing: atomic, async, elastic.

* **Atomic**: write to ``<dir>/tmp-<step>`` then ``os.replace`` — a crash
  mid-save never corrupts the latest checkpoint.
* **Async**: ``CheckpointManager(async_save=True)`` snapshots device arrays
  to host and writes on a background thread; training never blocks on disk.
* **Elastic**: arrays are stored mesh-agnostic (full host arrays + the
  pytree structure); ``load_checkpoint(..., ruleset=)`` re-device_puts onto
  whatever mesh is active, so a 16-chip checkpoint restores onto 512 chips
  (or back) — the elastic-scaling path, exercised by tests.
* **Retention**: keeps the last ``keep`` checkpoints, best-effort GC.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree, extra: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp-{step}")
    final = os.path.join(directory, f"step-{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "n_arrays": len(flat),
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(directory)
             if d.startswith("step-")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, like, step: Optional[int] = None,
                    ruleset=None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). With ``ruleset`` the arrays are placed sharded onto
    the active mesh (elastic re-shard)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step-{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kpath, leaf in paths:
        key = "/".join(_path_str(p) for p in kpath)
        arr = data[key]
        want = leaf.dtype if hasattr(leaf, "dtype") else None
        if want is not None and arr.dtype != want \
                and arr.dtype.itemsize == np.dtype(want).itemsize:
            # npz stores ml_dtypes (bfloat16 etc.) as raw void; view back.
            arr = arr.view(want)
        if ruleset is not None and ruleset.mesh is not None:
            from repro.dist import sharding as shd
            names = tuple(str(_path_str(p)) for p in kpath)
            spec = shd.param_spec(names, arr.shape, ruleset)
            arr = jax.device_put(
                arr, jax.sharding.NamedSharding(ruleset.mesh, spec))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, manifest


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra: Optional[dict] = None):
        # Snapshot to host first so training can proceed.
        host_tree = jax.tree.map(np.asarray, tree)
        if self.async_save:
            self.wait()

            def work():
                try:
                    save_checkpoint(self.directory, step, host_tree, extra)
                    self._gc()
                except BaseException as e:     # surfaced on next wait()
                    self._error = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, like, step: Optional[int] = None, ruleset=None):
        return load_checkpoint(self.directory, like, step=step,
                               ruleset=ruleset)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(int(d.split("-")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step-"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:08d}"),
                          ignore_errors=True)
