"""Paged KV cache: a virtual-memory view of serving HBM.

The serving engine's contiguous caches reserve ``batch * max_len`` KV rows
per layer no matter how long each slot's context actually is — the exact
flat-allocation waste the paper's memory-hierarchy chapter dissects at the
page-table level (and Mei & Chu's TLB/page-size geometry quantifies). This
module applies the same cure the hardware does: a fixed page size, a shared
physical pool, and per-slot page tables.

Pieces:

* ``PageAllocator`` — host-side free-list allocator over logical page ids.
  Page 0 is the **null page**: never allocated, it absorbs writes from
  freed/idle slots (whose page-table rows are zeroed) exactly like a
  faulting PTE redirected to a scratch frame. Allocation is LIFO so a
  freed slot's pages are the next ones handed out (warm-page reuse).
  With ``n_devices > 1`` the pool is striped over a device mesh axis in
  contiguous blocks of ``n_pages // n_devices`` pages: global page id
  ``p`` lives on device ``p // block`` at local slot ``p % block`` — the
  (device, local_page) pair the sharded pools resolve (``serve.dist``).
  Allocation picks the least-loaded device first, so a long slot's table
  naturally spans devices — the paper's NVLink remote-access story
  applied to KV: capacity scales with the mesh while the logical page
  table (and every admission/preemption decision priced against it)
  stays flat and global.
* ``gather_kv`` — pure-jnp page-table walk: materializes the contiguous
  (b, max_pages*page_size, kvh, d) view of a pool. Reference/parity path
  for the paged flash-decode kernel (and the non-flash engine path).
* Reservation accounting — ``rows_resident`` / ``reservation`` report the
  HBM the paged layout actually holds vs the contiguous ``slots*max_len``
  reservation, the headline number in ``benchmarks/tpu_serving.py``.
* ``chunk_page_need`` — the chunked-prefill allocation unit: how many
  pages a slot must add before streaming one prompt chunk through its
  table (admission headroom and the prefill scheduler share it).

The physical pools themselves live in the model caches (one pool per
pattern position, stacked over periods — see
``models.transformer.init_paged_caches``); every layer shares one logical
page table per slot, so the allocator needs no notion of layers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp

NULL_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """No free pages left in the shared KV pool."""


def pages_for(n_rows: int, page_size: int) -> int:
    """Pages needed to hold ``n_rows`` KV rows."""
    return -(-int(n_rows) // page_size)


def chunk_page_need(cursor: int, chunk_rows: int, pages_held: int,
                    page_size: int, max_rows: int) -> int:
    """Pages a slot must *add* before writing rows [cursor, cursor+chunk).

    The chunked-prefill allocation unit: a slot holding ``pages_held``
    pages about to stream one chunk through its table needs table entries
    through ``min(cursor + chunk_rows, max_rows)`` (rows past ``max_rows``
    spill to the null page and need no backing). ``_admit`` uses it with
    cursor=0/pages_held=0 to price a request's first chunk, and the
    prefill scheduler re-prices every subsequent chunk with the same
    function so admission headroom and mid-prefill growth can never
    disagree.
    """
    end = min(int(cursor) + int(chunk_rows), int(max_rows))
    return max(0, pages_for(end, page_size) - int(pages_held))


@dataclasses.dataclass
class PageAllocator:
    """Free-list allocator over the shared KV page pool.

    ``n_pages`` counts physical pages *including* the null page, so the
    allocatable ``capacity`` is ``n_pages - 1`` on *any* mesh: sharding
    the pool over ``n_devices`` changes where a page physically lives,
    never how many a request costs — admission and preemption stay priced
    against the global pool. Invariants (asserted):

    * a page is never handed out while still owned by a live slot,
    * the null page is never handed out,
    * every page is either free or owned by exactly one slot,
    * equivalently: no (device, local_page) pair is live twice.
    """

    n_pages: int
    page_size: int
    n_devices: int = 1

    def __post_init__(self):
        assert self.n_devices >= 1
        assert self.n_pages % self.n_devices == 0, \
            (self.n_pages, self.n_devices)
        self.block = self.n_pages // self.n_devices
        assert self.n_pages >= 2, "pool needs the null page + 1 real page"
        assert self.page_size >= 1
        # Per-device LIFO free lists: freshly freed pages are reused first.
        # The null page (global 0, device 0 local 0) never enters a list.
        self._free_by_dev: List[List[int]] = [
            list(range((d + 1) * self.block - 1, d * self.block - 1, -1))
            for d in range(self.n_devices)]
        self._free_by_dev[0] = list(range(self.block - 1, NULL_PAGE, -1))
        self.slot_pages: Dict[int, List[int]] = {}
        self._live: set = set()
        self.high_water = 0
        # Cumulative churn counters (never decremented): post-run pool
        # sizing audits need total traffic, not just the instantaneous
        # occupancy — conservation law: allocated - freed == in use.
        self.pages_allocated = 0
        self.pages_freed = 0

    # -- device geometry ------------------------------------------------------

    def device_of(self, page: int) -> int:
        """Mesh-axis index of the device holding global page id ``page``."""
        return int(page) // self.block

    def local_of(self, page: int) -> int:
        """Device-local physical page slot of global page id ``page``."""
        return int(page) % self.block

    @property
    def capacity(self) -> int:
        """Allocatable pages: the pool minus the null page."""
        return self.n_pages - 1

    # -- alloc/free -----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free_by_dev)

    @property
    def _free(self) -> List[int]:
        """Flat view of the free lists (introspection/tests only)."""
        return [p for f in self._free_by_dev for p in f]

    @property
    def pages_in_use(self) -> int:
        return len(self._live)

    def can_alloc(self, n: int) -> bool:
        return self.free_pages >= n

    def alloc(self, slot: int, n: int = 1) -> List[int]:
        """Take ``n`` pages for ``slot``; raises ``PagePoolExhausted``
        (allocating nothing) when the free lists are short.

        Pages are pulled from the least-loaded device first (ties go to
        the lowest device index), so slots stripe across the mesh and a
        single long context spans devices instead of exhausting one
        block — global capacity is the only admission constraint.
        """
        if self.free_pages < n:
            raise PagePoolExhausted(
                f"need {n} pages for slot {slot}, {self.free_pages} free "
                f"({self.pages_in_use}/{self.capacity} in use)")
        got = []
        for _ in range(n):
            dev = max(range(self.n_devices),
                      key=lambda d: (len(self._free_by_dev[d]), -d))
            got.append(self._free_by_dev[dev].pop())
        for p in got:
            assert p != NULL_PAGE and p not in self._live, p
            self._live.add(p)
        self.slot_pages.setdefault(slot, []).extend(got)
        self.pages_allocated += len(got)
        self.high_water = max(self.high_water, self.pages_in_use)
        return got

    def free_slot(self, slot: int) -> List[int]:
        """Return every page owned by ``slot`` to its device's free list."""
        pages = self.slot_pages.pop(slot, [])
        for p in pages:
            assert p in self._live, p
            self._live.discard(p)
        # Reversed: re-admission walks pages in allocation order again.
        for p in reversed(pages):
            self._free_by_dev[self.device_of(p)].append(p)
        self.pages_freed += len(pages)
        return pages

    def reset(self) -> None:
        """Free everything (engine restart)."""
        self.__post_init__()

    # -- accounting -----------------------------------------------------------

    def rows_resident(self) -> int:
        """KV rows the paged layout holds live right now (incl. the null
        page) — the paged analogue of the contiguous ``slots * max_len``."""
        return (self.pages_in_use + 1) * self.page_size

    def device_occupancy(self) -> List[int]:
        """Live pages per device — sums to ``pages_in_use`` (the property
        test's conservation law for the sharded pool)."""
        occ = [0] * self.n_devices
        for p in self._live:
            occ[self.device_of(p)] += 1
        return occ

    def occupancy(self, lengths: Optional[Dict[int, int]] = None) -> dict:
        """Pool utilization; with per-slot ``lengths`` also the internal
        fragmentation (allocated-but-unused rows — the page-granularity
        tax, the repo's analogue of the paper's page-size trade)."""
        out = {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "capacity": self.capacity,
            "n_devices": self.n_devices,
            "pages_in_use": self.pages_in_use,
            "pages_free": self.free_pages,
            "high_water": self.high_water,
            "pages_allocated": self.pages_allocated,
            "pages_freed": self.pages_freed,
            "utilization": self.pages_in_use / max(1, self.capacity),
            "rows_resident": self.rows_resident(),
        }
        if self.n_devices > 1:
            out["pages_in_use_by_device"] = self.device_occupancy()
        if lengths is not None:
            alloc_rows = sum(len(ps) * self.page_size
                             for ps in self.slot_pages.values())
            used_rows = sum(int(l) for l in lengths.values())
            out["fragmentation_rows"] = alloc_rows - used_rows
            out["fragmentation_frac"] = ((alloc_rows - used_rows)
                                         / max(1, alloc_rows))
        return out


# ----------------------------------------------------------------------------
# Pure-jnp page-table walk (reference path) + reservation model
# ----------------------------------------------------------------------------

def gather_kv(kp, vp, pages):
    """Materialize the contiguous view of a paged pool.

    kp/vp: (n_pages, page_size, kvh, d); pages: (b, max_pages) int32 with
    0 = null page. Returns (b, max_pages*page_size, kvh, d) — rows mapped
    through the null page are garbage and must be masked by ``kv_lengths``
    (the caller's lengths never reach into them).
    """
    b, max_pages = pages.shape
    ps = kp.shape[1]
    kc = jnp.take(kp, pages, axis=0).reshape(b, max_pages * ps, *kp.shape[2:])
    vc = jnp.take(vp, pages, axis=0).reshape(b, max_pages * ps, *vp.shape[2:])
    return kc, vc


def reservation(lengths, max_len: int, page_size: int) -> dict:
    """Modeled HBM reservation, paged vs contiguous, for one layer's KV.

    ``lengths`` are per-slot live context lengths. Contiguous reserves
    ``slots * max_len`` rows up front; paged holds only the pages the live
    contexts touch (plus the null page).
    """
    lengths = [int(l) for l in lengths]
    slots = len(lengths)
    rows_paged = (sum(pages_for(l, page_size) for l in lengths) + 1) \
        * page_size
    rows_contig = slots * max_len
    return {
        "page_size": page_size,
        "slots": slots,
        "rows_resident": rows_paged,
        "rows_reserved_contig": rows_contig,
        "reservation_ratio": rows_paged / max(1, rows_contig),
    }
