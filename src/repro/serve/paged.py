"""Paged KV cache: a virtual-memory view of serving HBM.

The serving engine's contiguous caches reserve ``batch * max_len`` KV rows
per layer no matter how long each slot's context actually is — the exact
flat-allocation waste the paper's memory-hierarchy chapter dissects at the
page-table level (and Mei & Chu's TLB/page-size geometry quantifies). This
module applies the same cure the hardware does: a fixed page size, a shared
physical pool, and per-slot page tables.

Pieces:

* ``PageAllocator`` — host-side free-list allocator over logical page ids.
  Page 0 is the **null page**: never allocated, it absorbs writes from
  freed/idle slots (whose page-table rows are zeroed) exactly like a
  faulting PTE redirected to a scratch frame. Allocation is LIFO so a
  freed slot's pages are the next ones handed out (warm-page reuse).
  With ``n_devices > 1`` the pool is striped over a device mesh axis in
  contiguous blocks of ``n_pages // n_devices`` pages: global page id
  ``p`` lives on device ``p // block`` at local slot ``p % block`` — the
  (device, local_page) pair the sharded pools resolve (``serve.dist``).
  Allocation picks the least-loaded device first, so a long slot's table
  naturally spans devices — the paper's NVLink remote-access story
  applied to KV: capacity scales with the mesh while the logical page
  table (and every admission/preemption decision priced against it)
  stays flat and global.
* ``gather_kv`` — pure-jnp page-table walk: materializes the contiguous
  (b, max_pages*page_size, kvh, d) view of a pool. Reference/parity path
  for the paged flash-decode kernel (and the non-flash engine path).
* Reservation accounting — ``rows_resident`` / ``reservation`` report the
  HBM the paged layout actually holds vs the contiguous ``slots*max_len``
  reservation, the headline number in ``benchmarks/tpu_serving.py``.
* ``chunk_page_need`` — the chunked-prefill allocation unit: how many
  pages a slot must add before streaming one prompt chunk through its
  table (admission headroom and the prefill scheduler share it).
* ``PrefixIndex`` — hash-keyed map from full-page-aligned token prefixes
  to resident page runs. Prefix caching is page-table sharing: the index
  holds a refcount on each published page, admission maps hit pages into
  a new slot's table by bumping refcounts (zero data movement — the page
  table IS the sharing mechanism), and the engine copy-on-writes before
  any write that would land in a shared page. The classic TLB/page-
  sharing trick the paper's memory-hierarchy chapters dissect, applied
  to our software TLB.

The physical pools themselves live in the model caches (one pool per
pattern position, stacked over periods — see
``models.transformer.init_paged_caches``); every layer shares one logical
page table per slot, so the allocator needs no notion of layers.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

NULL_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """No free pages left in the shared KV pool."""


def pages_for(n_rows: int, page_size: int) -> int:
    """Pages needed to hold ``n_rows`` KV rows."""
    return -(-int(n_rows) // page_size)


def chunk_page_need(cursor: int, chunk_rows: int, pages_held: int,
                    page_size: int, max_rows: int) -> int:
    """Pages a slot must *add* before writing rows [cursor, cursor+chunk).

    The chunked-prefill allocation unit: a slot holding ``pages_held``
    pages about to stream one chunk through its table needs table entries
    through ``min(cursor + chunk_rows, max_rows)`` (rows past ``max_rows``
    spill to the null page and need no backing). ``_admit`` uses it with
    cursor=0/pages_held=0 to price a request's first chunk, and the
    prefill scheduler re-prices every subsequent chunk with the same
    function so admission headroom and mid-prefill growth can never
    disagree.
    """
    end = min(int(cursor) + int(chunk_rows), int(max_rows))
    return max(0, pages_for(end, page_size) - int(pages_held))


@dataclasses.dataclass
class PageAllocator:
    """Free-list allocator over the shared KV page pool.

    ``n_pages`` counts physical pages *including* the null page, so the
    allocatable ``capacity`` is ``n_pages - 1`` on *any* mesh: sharding
    the pool over ``n_devices`` changes where a page physically lives,
    never how many a request costs — admission and preemption stay priced
    against the global pool.

    Pages are **refcounted**: a live page is held by one or more slots
    (``share``) and/or the prefix index (``retain``); it returns to the
    free list only when its count drops to zero. Refcounts are host-side
    bookkeeping only — the device pools never see them, so the sharded
    pool (``serve.dist``) composes unchanged and a shared page simply
    lives on whichever device first allocated it. Invariants (asserted):

    * a free page is never handed out while still live,
    * the null page is never handed out and never refcounted,
    * every live page has refcount >= 1; refcount 0 <=> free,
    * ``pages_allocated - pages_freed == pages_in_use`` (conservation:
      allocation counts free->live transitions, freeing counts
      live->free transitions — sharing bumps neither).
    """

    n_pages: int
    page_size: int
    n_devices: int = 1

    def __post_init__(self):
        assert self.n_devices >= 1
        assert self.n_pages % self.n_devices == 0, \
            (self.n_pages, self.n_devices)
        self.block = self.n_pages // self.n_devices
        assert self.n_pages >= 2, "pool needs the null page + 1 real page"
        assert self.page_size >= 1
        # Per-device LIFO free lists: freshly freed pages are reused first.
        # The null page (global 0, device 0 local 0) never enters a list.
        self._free_by_dev: List[List[int]] = [
            list(range((d + 1) * self.block - 1, d * self.block - 1, -1))
            for d in range(self.n_devices)]
        self._free_by_dev[0] = list(range(self.block - 1, NULL_PAGE, -1))
        self.slot_pages: Dict[int, List[int]] = {}
        self._live: set = set()
        # Per-page refcounts (slot holds + prefix-index holds). A page in
        # _index_held is retained by the prefix index; with refcount 1 it
        # is "cached idle" — resident but unreferenced by any slot, the
        # evictable class.
        self._ref: Dict[int, int] = {}
        self._index_held: set = set()
        self.high_water = 0
        # Cumulative churn counters (never decremented): post-run pool
        # sizing audits need total traffic, not just the instantaneous
        # occupancy — conservation law: allocated - freed == in use.
        self.pages_allocated = 0
        self.pages_freed = 0
        # Sharing churn (cumulative): share() page-mappings handed out,
        # prefix-index retains, and copy-on-write page splits.
        self.shared_mappings = 0
        self.index_retains = 0
        self.cow_count = 0

    # -- device geometry ------------------------------------------------------

    def device_of(self, page: int) -> int:
        """Mesh-axis index of the device holding global page id ``page``."""
        return int(page) // self.block

    def local_of(self, page: int) -> int:
        """Device-local physical page slot of global page id ``page``."""
        return int(page) % self.block

    @property
    def capacity(self) -> int:
        """Allocatable pages: the pool minus the null page."""
        return self.n_pages - 1

    # -- alloc/free -----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free_by_dev)

    @property
    def _free(self) -> List[int]:
        """Flat view of the free lists (introspection/tests only)."""
        return [p for f in self._free_by_dev for p in f]

    @property
    def pages_in_use(self) -> int:
        return len(self._live)

    def can_alloc(self, n: int) -> bool:
        return self.free_pages >= n

    def alloc(self, slot: int, n: int = 1) -> List[int]:
        """Take ``n`` pages for ``slot``; raises ``PagePoolExhausted``
        (allocating nothing) when the free lists are short.

        Pages are pulled from the least-loaded device first (ties go to
        the lowest device index), so slots stripe across the mesh and a
        single long context spans devices instead of exhausting one
        block — global capacity is the only admission constraint.
        """
        got = self._take(n, owner=f"slot {slot}")
        self.slot_pages.setdefault(slot, []).extend(got)
        return got

    def _take(self, n: int, owner: str = "?") -> List[int]:
        """Pull ``n`` fresh pages (refcount 1) off the free lists without
        assigning them to a slot — ``alloc`` and ``cow`` share it."""
        if self.free_pages < n:
            raise PagePoolExhausted(
                f"need {n} pages for {owner}, {self.free_pages} free "
                f"({self.pages_in_use}/{self.capacity} in use)")
        got = []
        for _ in range(n):
            dev = max(range(self.n_devices),
                      key=lambda d: (len(self._free_by_dev[d]), -d))
            got.append(self._free_by_dev[dev].pop())
        for p in got:
            assert p != NULL_PAGE and p not in self._live, p
            self._live.add(p)
            self._ref[p] = 1
        self.pages_allocated += len(got)
        self.high_water = max(self.high_water, self.pages_in_use)
        return got

    def share(self, slot: int, pages: Sequence[int]) -> None:
        """Map already-live ``pages`` into ``slot``'s table by bumping
        refcounts — the prefix-cache hit path. Zero data movement: the
        pages stay where they are, only the slot's page table (installed
        by the engine) and the host-side counts change."""
        pages = [int(p) for p in pages]
        for p in pages:
            assert p in self._live and self._ref.get(p, 0) >= 1, p
            self._ref[p] += 1
        self.slot_pages.setdefault(slot, []).extend(pages)
        self.shared_mappings += len(pages)

    def retain(self, page: int) -> None:
        """Prefix-index hold on a live page (at most one per page)."""
        page = int(page)
        assert page in self._live and page not in self._index_held, page
        self._ref[page] += 1
        self._index_held.add(page)
        self.index_retains += 1

    def release(self, page: int) -> bool:
        """Drop the prefix-index hold; frees the page if that was the
        last reference. Returns True when the page was freed."""
        page = int(page)
        assert page in self._index_held, page
        self._index_held.discard(page)
        return self._decref(page)

    def refcount(self, page: int) -> int:
        return self._ref.get(int(page), 0)

    def _decref(self, page: int) -> bool:
        """Drop one reference; on the live->free transition return the
        page to its device's free list. Returns True when freed."""
        assert page in self._live and self._ref[page] >= 1, page
        self._ref[page] -= 1
        if self._ref[page] > 0:
            return False
        del self._ref[page]
        self._live.discard(page)
        self._free_by_dev[self.device_of(page)].append(page)
        self.pages_freed += 1
        return True

    def cow(self, slot: int, pos: int) -> Tuple[int, int]:
        """Copy-on-write split: replace the shared page at table position
        ``pos`` of ``slot`` with a fresh exclusive page. Returns
        ``(old, new)`` global page ids — the *caller* copies the K/V rows
        on device and swaps the device-side table entry; the allocator
        only rewires ownership. Raises ``PagePoolExhausted`` (changing
        nothing) when no page is free."""
        old = self.slot_pages[slot][pos]
        assert self._ref.get(old, 0) >= 2, \
            f"COW of unshared page {old} (ref {self._ref.get(old, 0)})"
        new = self._take(1, owner=f"cow slot {slot}")[0]
        self.slot_pages[slot][pos] = new
        self._decref(old)            # ref >= 2, so never frees
        self.cow_count += 1
        return old, new

    def free_slot(self, slot: int) -> List[int]:
        """Drop ``slot``'s reference on every page it maps; pages whose
        count hits zero return to their device's free list. Returns the
        pages actually freed (shared pages survive their co-holders)."""
        pages = self.slot_pages.pop(slot, [])
        freed = []
        # Reversed: re-admission walks pages in allocation order again.
        for p in reversed(pages):
            if self._decref(p):
                freed.append(p)
        freed.reverse()
        return freed

    def reset(self) -> None:
        """Free everything (engine restart)."""
        self.__post_init__()

    # -- accounting -----------------------------------------------------------

    def rows_resident(self) -> int:
        """KV rows the paged layout holds live right now (incl. the null
        page) — the paged analogue of the contiguous ``slots * max_len``."""
        return (self.pages_in_use + 1) * self.page_size

    def device_occupancy(self) -> List[int]:
        """Live pages per device — sums to ``pages_in_use`` (the property
        test's conservation law for the sharded pool)."""
        occ = [0] * self.n_devices
        for p in self._live:
            occ[self.device_of(p)] += 1
        return occ

    def page_classes(self) -> Dict[str, int]:
        """Live pages split by sharing state: ``exclusive`` (one slot,
        no index hold), ``shared`` (refcount >= 2), ``cached_idle``
        (index hold only — the evictable class). Sums to
        ``pages_in_use``."""
        exclusive = shared = cached_idle = 0
        for p, r in self._ref.items():
            if r >= 2:
                shared += 1
            elif p in self._index_held:
                cached_idle += 1
            else:
                exclusive += 1
        return {"pages_exclusive": exclusive, "pages_shared": shared,
                "pages_cached_idle": cached_idle}

    def occupancy(self, lengths: Optional[Dict[int, int]] = None) -> dict:
        """Pool utilization; with per-slot ``lengths`` also the internal
        fragmentation (allocated-but-unused rows — the page-granularity
        tax, the repo's analogue of the paper's page-size trade)."""
        out = {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "capacity": self.capacity,
            "n_devices": self.n_devices,
            "pages_in_use": self.pages_in_use,
            "pages_free": self.free_pages,
            "high_water": self.high_water,
            "pages_allocated": self.pages_allocated,
            "pages_freed": self.pages_freed,
            "utilization": self.pages_in_use / max(1, self.capacity),
            "rows_resident": self.rows_resident(),
            "shared_mappings": self.shared_mappings,
            "index_retains": self.index_retains,
            "cow_count": self.cow_count,
        }
        out.update(self.page_classes())
        if self.n_devices > 1:
            out["pages_in_use_by_device"] = self.device_occupancy()
        if lengths is not None:
            alloc_rows = sum(len(ps) * self.page_size
                             for ps in self.slot_pages.values())
            used_rows = sum(int(l) for l in lengths.values())
            out["fragmentation_rows"] = alloc_rows - used_rows
            out["fragmentation_frac"] = ((alloc_rows - used_rows)
                                         / max(1, alloc_rows))
        return out


# ----------------------------------------------------------------------------
# Prefix index: hash-keyed map from token prefixes to resident page runs
# ----------------------------------------------------------------------------

ROOT_DIGEST = b""
_DIGEST_BYTES = 16


def _page_digest(parent: bytes, chunk: bytes) -> bytes:
    """Chained digest of one full page of tokens: hashing the parent
    digest in means a prefix's key depends on *every* token before it,
    so equal keys can only come from equal whole prefixes (plus the
    stored-token check below for collision paranoia)."""
    return hashlib.blake2b(parent + chunk, digest_size=_DIGEST_BYTES).digest()


def token_bytes(tokens) -> bytes:
    """Canonical byte serialization of a token run (int32 little-endian)."""
    return np.ascontiguousarray(np.asarray(tokens, np.int32)).tobytes()


@dataclasses.dataclass
class _PrefixEntry:
    page: int          # global page id the prefix's last page lives in
    parent: bytes      # digest of the prefix one page shorter (or ROOT)
    tokens: bytes      # this page's tokens — verified on probe (no
                       # stream may ever depend on a hash non-collision)
    children: int      # live entries extending this prefix by one page
    last_used: int     # engine tick of last probe hit / publish (LRU)


class PrefixIndex:
    """Full-page-aligned prefix -> resident page run, with LRU eviction.

    Granularity is a whole page because a page is the unit the kernel's
    scalar-prefetch table can remap: sharing a partial page would need
    row-level copy at admission, which is exactly the data movement the
    page table exists to avoid. Each entry holds one ``retain`` on its
    page, so a published page survives its writer (cached idle) until
    ``evict`` releases it; entries whose page is also mapped by a slot
    (refcount >= 2) are never evicted — the slot's stream depends on it.
    """

    def __init__(self, pool: PageAllocator):
        self.pool = pool
        self.page_size = pool.page_size
        self._entries: Dict[bytes, _PrefixEntry] = {}
        # Cumulative eviction traffic (pages released back to the pool).
        self.evicted_pages = 0

    def __len__(self) -> int:
        return len(self._entries)

    def probe(self, tokens, max_pages: int,
              now: int = 0) -> Tuple[List[int], bytes, int]:
        """Longest cached prefix of ``tokens``, capped at ``max_pages``
        full pages. Returns ``(pages, digest, n_hit)`` where ``digest``
        keys the deepest matched entry (parent for later publishes).
        Every level's stored tokens are compared byte-for-byte — a hash
        collision degrades to a miss, never to a wrong-stream share."""
        ps = self.page_size
        n_full = min(len(tokens) // ps, int(max_pages))
        pages: List[int] = []
        parent = ROOT_DIGEST
        for i in range(n_full):
            chunk = token_bytes(tokens[i * ps:(i + 1) * ps])
            digest = _page_digest(parent, chunk)
            e = self._entries.get(digest)
            if e is None or e.tokens != chunk:
                break
            e.last_used = now
            pages.append(e.page)
            parent = digest
        return pages, parent, len(pages)

    def publish(self, tokens, page: int, parent: bytes,
                now: int = 0) -> Optional[bytes]:
        """Register one full page of tokens extending ``parent``.

        An existing entry wins — the pool holds one copy per distinct
        prefix, so the caller's duplicate page stays its own exclusive
        copy and future admissions share the incumbent. A token mismatch
        at an existing digest (hash collision) refuses to publish and
        returns None, stopping the caller's chain. Otherwise returns the
        digest to parent the next page on."""
        chunk = token_bytes(tokens)
        assert len(chunk) == 4 * self.page_size, "publish needs a full page"
        digest = _page_digest(parent, chunk)
        e = self._entries.get(digest)
        if e is not None:
            if e.tokens != chunk:
                return None
            e.last_used = now
            return digest
        self.pool.retain(page)
        if parent != ROOT_DIGEST and parent in self._entries:
            self._entries[parent].children += 1
        self._entries[digest] = _PrefixEntry(
            page=int(page), parent=parent, tokens=chunk,
            children=0, last_used=now)
        return digest

    def evict(self, n_pages: int, now: int = 0) -> int:
        """Release up to ``n_pages`` cached-idle pages, LRU leaf first.

        Only leaves (``children == 0``) whose page has refcount 1 (the
        index's own hold) are candidates: interior entries back longer
        cached prefixes and slot-mapped pages back live streams. Freeing
        a leaf can turn its parent into a candidate, so long-dead chains
        unwind back-to-front across iterations. Returns pages freed."""
        freed = 0
        while freed < n_pages:
            best = None
            for digest, e in self._entries.items():
                if e.children != 0 or self.pool.refcount(e.page) != 1:
                    continue
                if best is None or e.last_used < best[1].last_used:
                    best = (digest, e)
            if best is None:
                break
            digest, e = best
            del self._entries[digest]
            if e.parent != ROOT_DIGEST and e.parent in self._entries:
                self._entries[e.parent].children -= 1
            self.pool.release(e.page)
            freed += 1
        self.evicted_pages += freed
        return freed

    def clear(self) -> int:
        """Drop every entry (engine reset); returns pages freed."""
        freed = 0
        for e in self._entries.values():
            if self.pool.release(e.page):
                freed += 1
        self._entries.clear()
        return freed


# ----------------------------------------------------------------------------
# Pure-jnp page-table walk (reference path) + reservation model
# ----------------------------------------------------------------------------

def gather_kv(kp, vp, pages):
    """Materialize the contiguous view of a paged pool.

    kp/vp: (n_pages, page_size, kvh, d); pages: (b, max_pages) int32 with
    0 = null page. Returns (b, max_pages*page_size, kvh, d) — rows mapped
    through the null page are garbage and must be masked by ``kv_lengths``
    (the caller's lengths never reach into them).
    """
    b, max_pages = pages.shape
    ps = kp.shape[1]
    kc = jnp.take(kp, pages, axis=0).reshape(b, max_pages * ps, *kp.shape[2:])
    vc = jnp.take(vp, pages, axis=0).reshape(b, max_pages * ps, *vp.shape[2:])
    return kc, vc


def reservation(lengths, max_len: int, page_size: int) -> dict:
    """Modeled HBM reservation, paged vs contiguous, for one layer's KV.

    ``lengths`` are per-slot live context lengths. Contiguous reserves
    ``slots * max_len`` rows up front; paged holds only the pages the live
    contexts touch (plus the null page).
    """
    lengths = [int(l) for l in lengths]
    slots = len(lengths)
    rows_paged = (sum(pages_for(l, page_size) for l in lengths) + 1) \
        * page_size
    rows_contig = slots * max_len
    return {
        "page_size": page_size,
        "slots": slots,
        "rows_resident": rows_paged,
        "rows_reserved_contig": rows_contig,
        "reservation_ratio": rows_paged / max(1, rows_contig),
    }
