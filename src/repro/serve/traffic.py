"""Open-loop traffic generation and SLO accounting for the serving engine.

Every benchmark before this module replayed a fixed request list — a
*closed loop*: the generator waits for the engine, so the engine can
never be overrun and its failure paths never fire. Production load is
open-loop: arrivals come on their own clock whether or not the server
keeps up, and the interesting regime is exactly the one closed-loop
replay can't reach — offered load past capacity, where queues grow,
admission sheds, and preemption churns. (Same method as the source
paper's microbenchmarks: drive the system past its comfortable point
and characterize *how* it breaks, not whether it works when idle.)

Everything here is deterministic from ``TrafficConfig.seed`` — arrivals,
prompt content, length mixes, class labels all come from one
``np.random.Generator``, so a traffic trace is reproducible bit-for-bit
and the breaking-point bench cells commit stable numbers.

Pieces:

  * ``TrafficClass`` — one tenant class's mix weight, length
    distributions, and the name of its engine-side ``SLOClass``.
  * ``TrafficGenerator`` — seeded arrival-time + request synthesis.
    ``process="poisson"`` draws i.i.d. exponential gaps at ``rate``
    requests/tick; ``process="bursty"`` is a 2-state Markov-modulated
    Poisson process (calm/burst states with different rates and seeded
    state flips) — the arrival shape that actually trips admission
    control, because a burst arrives faster than any steady rate.
  * ``run_open_loop`` — the open-loop driver: submit every request whose
    arrival time has passed, then tick once, repeat; the engine never
    gates the generator. ``record_to=`` writes the offered trace in the
    recorded-log format before driving it.
  * ``write_log`` / ``replay_log`` — the recorded production log format
    (JSONL, one line per request: ``arrival_s``, ``class``,
    ``prompt_len``, ``max_new``, ``session_id``) and its replayer, which
    re-synthesizes deterministic prompts at the recorded lengths —
    arrivals sharing a ``session_id`` share their opening tokens, so a
    replayed log exercises the same prefix-cache behavior the live
    traffic did.
  * ``summarize`` — the operator-facing rollup: TTFT/TPOT percentiles
    (tick domain), goodput, shed/preemption accounting, per-class SLO
    attainment.

Times are in *engine ticks*, not wall-clock: a tick is the engine's unit
of service (one decode step for every active slot), so tick-domain
latencies are deterministic, hardware-independent, and directly
convertible (multiply by the measured tick time) — which is what lets
the committed bench cells be schema-gated with hard inequalities.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve import engine as engine_mod


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One tenant class's share of the offered load.

    ``name`` should match an engine-side ``SLOClass`` name when the
    engine runs with admission classes (unknown names serve unmetered at
    priority 0 — the engine's explicit fallback). Lengths are drawn
    log-uniform in [lo, hi]: production prompt lengths are heavy-tailed,
    and a log draw exercises every bucket/chunk regime instead of
    clustering at the mean."""

    name: str
    weight: float = 1.0               # mix share (normalized over classes)
    prompt_lo: int = 8
    prompt_hi: int = 64
    out_lo: int = 4
    out_hi: int = 32
    # Wall-clock SLO targets (milliseconds), reported by ``summarize``
    # when the engine carries measured tick times (``serve.telemetry``).
    # Tick-domain targets (engine ``SLOClass``) remain the default: they
    # are deterministic and hardware-independent; these price the same
    # latencies on the machine actually serving.
    ttft_ms: Optional[float] = None
    tpot_ms: Optional[float] = None
    # Session mode (ROADMAP carry-over: multi-turn arrivals that share
    # prefixes). With ``sessions > 0`` the class keeps a pool of that
    # many distinct session prefixes, each ``prefix_len`` tokens; every
    # arrival picks a session (seeded uniform) and prepends its prefix
    # to a fresh log-uniform suffix — returning users re-offer the same
    # opening tokens, the workload shape prefix caching monetizes
    # (``ServeConfig.prefix_cache``; the ``prefix_cache_hit`` bench cell
    # drives exactly this traffic). The prefix pool draws from a
    # *separate* seeded RNG stream, so session-mode arrival times,
    # classes, and suffixes are bit-identical to the same config with
    # sessions off — only the prompt heads change.
    sessions: int = 0
    prefix_len: int = 0

    def __post_init__(self):
        assert self.weight > 0, self.weight
        assert 1 <= self.prompt_lo <= self.prompt_hi
        assert 1 <= self.out_lo <= self.out_hi
        assert self.ttft_ms is None or self.ttft_ms > 0
        assert self.tpot_ms is None or self.tpot_ms > 0
        assert self.sessions >= 0 and self.prefix_len >= 0
        assert (self.sessions > 0) == (self.prefix_len > 0), \
            "session mode needs both sessions and prefix_len"


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Seeded open-loop arrival process.

    ``rate`` is offered load in requests per engine tick. The bursty
    process alternates calm (``rate``) and burst (``rate * burst_factor``)
    states; state flips are Bernoulli per arrival with the given exit
    probabilities, giving geometric dwell times — the standard 2-state
    MMPP shape."""

    rate: float                       # mean arrivals per tick (calm state)
    n_requests: int                   # total requests to offer
    seed: int = 0
    process: str = "poisson"          # "poisson" | "bursty"
    burst_factor: float = 8.0         # burst-state rate multiplier
    p_enter_burst: float = 0.05       # calm -> burst flip per arrival
    p_exit_burst: float = 0.25        # burst -> calm flip per arrival
    classes: Tuple[TrafficClass, ...] = (TrafficClass("default"),)
    vocab: int = 128                  # prompt token id range [2, vocab)
    max_prompt: Optional[int] = None  # clamp (engine max_len guard)

    def __post_init__(self):
        assert self.rate > 0, self.rate
        assert self.n_requests >= 1
        assert self.process in ("poisson", "bursty"), self.process
        assert self.burst_factor >= 1.0
        assert 0.0 < self.p_enter_burst < 1.0
        assert 0.0 < self.p_exit_burst <= 1.0
        assert self.classes


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One offered request: what to submit and when. ``session_id``
    marks a returning user (session-mode classes): arrivals with the
    same id share their prompt head, and the recorded-log format
    carries the id so a replay regenerates the same sharing shape."""

    tick: int                         # arrival time (engine ticks)
    rid: int
    rclass: str
    prompt: np.ndarray
    max_new: int
    session_id: Optional[int] = None


class TrafficGenerator:
    """Deterministic open-loop arrival synthesis (one RNG, one seed)."""

    def __init__(self, cfg: TrafficConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        # Session prefixes come from a *separate* seeded stream: the
        # main stream draws exactly the same sequence with sessions on
        # or off, so flipping session mode changes prompt heads only —
        # arrival times, class picks, and suffixes stay bit-identical
        # (the prefix_cache_hit cell compares engines across that flip).
        self._session_rng = np.random.default_rng([cfg.seed, 0x5E55])
        self._session_prefixes: Dict[str, np.ndarray] = {}
        for c in cfg.classes:
            if c.sessions:
                self._session_prefixes[c.name] = self._session_rng.integers(
                    2, cfg.vocab, size=(c.sessions, c.prefix_len),
                    dtype=np.int64).astype(np.int32)

    def _log_uniform(self, lo: int, hi: int) -> int:
        if lo == hi:
            return lo
        return int(round(np.exp(self.rng.uniform(np.log(lo), np.log(hi)))))

    def arrivals(self, rid0: int = 0) -> List[Arrival]:
        """The full offered trace, arrival-time sorted."""
        cfg = self.cfg
        names = [c.name for c in cfg.classes]
        weights = np.asarray([c.weight for c in cfg.classes], np.float64)
        weights = weights / weights.sum()
        by_name = {c.name: c for c in cfg.classes}
        out: List[Arrival] = []
        t = 0.0
        burst = False
        for n in range(cfg.n_requests):
            rate = cfg.rate
            if cfg.process == "bursty":
                # Geometric dwell: flip with the state's exit probability
                # before each gap, then draw the gap at the state's rate.
                p = cfg.p_exit_burst if burst else cfg.p_enter_burst
                if self.rng.random() < p:
                    burst = not burst
                if burst:
                    rate = cfg.rate * cfg.burst_factor
            t += self.rng.exponential(1.0 / rate)
            cls = by_name[str(self.rng.choice(names, p=weights))]
            plen = self._log_uniform(cls.prompt_lo, cls.prompt_hi)
            if cfg.max_prompt is not None:
                plen = min(plen, cfg.max_prompt)
            prompt = self.rng.integers(2, cfg.vocab, size=(plen,),
                                       dtype=np.int64).astype(np.int32)
            sid: Optional[int] = None
            if cls.sessions:
                # A returning user: this session's shared opening tokens
                # ahead of the per-arrival suffix (clamped prefix-first —
                # the shared head is what the prefix cache can reuse).
                pool = self._session_prefixes[cls.name]
                sid = int(self._session_rng.integers(0, cls.sessions))
                prompt = np.concatenate([pool[sid], prompt])
                if cfg.max_prompt is not None:
                    prompt = prompt[:cfg.max_prompt]
            out.append(Arrival(
                tick=int(t), rid=rid0 + n, rclass=cls.name, prompt=prompt,
                max_new=self._log_uniform(cls.out_lo, cls.out_hi),
                session_id=sid))
        return out


# ----------------------------------------------------------------------------
# Recorded-log format: write a trace out, replay it back
# ----------------------------------------------------------------------------

LOG_SCHEMA_VERSION = 1


def write_log(path: str, arrivals: List[Arrival]) -> None:
    """Write the offered trace as a recorded production log: JSONL, one
    line per request with ``arrival_s`` (the tick-domain arrival time),
    ``class``, ``prompt_len``, ``max_new``, ``session_id``. Token
    *content* is deliberately not recorded — production logs don't ship
    user text; ``replay_log`` re-synthesizes deterministic tokens at the
    recorded lengths and session-sharing shape."""
    with open(path, "w") as f:
        for a in arrivals:
            f.write(json.dumps({
                "arrival_s": float(a.tick),
                "class": a.rclass,
                "prompt_len": int(len(a.prompt)),
                "max_new": int(a.max_new),
                "session_id": a.session_id,
            }) + "\n")


def replay_log(path: str, vocab: int = 128, seed: int = 0,
               rid0: int = 0, prefix_len: int = 0) -> List[Arrival]:
    """Rebuild a submittable arrival list from a recorded log.

    Prompts are synthesized deterministically from ``seed`` at each
    line's recorded length: lines carrying the same ``session_id`` get
    the same ``prefix_len``-token head (drawn from a per-session seeded
    stream, mirroring the generator's separate session stream), so a
    replayed log re-offers the prefix-sharing the live traffic had —
    the property prefix-cache and calibration runs care about. Replay
    of a replayed log's own recording is bit-identical (round-trip)."""
    rng = np.random.default_rng([seed, 0x10C])
    heads: Dict[int, np.ndarray] = {}
    out: List[Arrival] = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            plen = int(rec["prompt_len"])
            sid = rec.get("session_id")
            prompt = rng.integers(2, vocab, size=(plen,),
                                  dtype=np.int64).astype(np.int32)
            if sid is not None and prefix_len > 0:
                if sid not in heads:
                    heads[sid] = np.random.default_rng(
                        [seed, 0x5E55, int(sid)]).integers(
                        2, vocab, size=(prefix_len,),
                        dtype=np.int64).astype(np.int32)
                head = heads[sid][:plen]
                prompt = np.concatenate([head, prompt[len(head):]])
            out.append(Arrival(
                tick=int(rec["arrival_s"]), rid=rid0 + i,
                rclass=str(rec["class"]), prompt=prompt,
                max_new=int(rec["max_new"]),
                session_id=None if sid is None else int(sid)))
    return out


def run_open_loop(engine, arrivals: List[Arrival],
                  max_ticks: int = 20000,
                  injector=None,
                  record_to: Optional[str] = None) -> Dict[str, dict]:
    """Drive ``engine`` open-loop: each iteration submits every arrival
    whose time has passed (the generator's clock, not the engine's
    readiness), then ticks once. Runs until every offered request has a
    terminal outcome (finished or rejected) or ``max_ticks`` elapses —
    the caller asserts on the shortfall, because a request with no
    outcome after the drain window IS the hang the robustness invariant
    forbids. ``injector`` (``serve.faults.FaultInjector``) is stepped
    before each tick so fault schedules share the tick clock.
    ``record_to`` writes the *offered* trace (submission order) in the
    recorded-log format before driving it — what ``replay_log`` reads
    back."""
    pending = sorted(arrivals, key=lambda a: (a.tick, a.rid))
    if record_to is not None:
        write_log(record_to, pending)
    offered = {a.rid for a in pending}
    j = 0
    for _ in range(max_ticks):
        while j < len(pending) and pending[j].tick <= engine.ticks:
            a = pending[j]
            engine.submit(engine_mod.Request(
                rid=a.rid, prompt=a.prompt, max_new=a.max_new,
                rclass=a.rclass))
            j += 1
        if injector is not None:
            injector.step(engine)
        engine.tick()
        if j == len(pending):
            done = all(r in engine.finished or r in engine.rejected
                       for r in offered)
            if done and not engine.queue and \
                    all(s is None for s in engine.slots):
                break
    return {
        "finished": dict(engine.finished),
        "rejected": dict(engine.rejected),
        "unresolved": sorted(
            r for r in offered
            if r not in engine.finished and r not in engine.rejected),
    }


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) \
        if xs else float("nan")


def summarize(engine, arrivals: List[Arrival],
              classes: Optional[Tuple[TrafficClass, ...]] = None
              ) -> Dict[str, object]:
    """The operator-facing rollup, tick domain first, wall-clock second.

    * TTFT: first-token tick minus submit tick (queueing + prefill).
    * TPOT: inter-token interval over the decode phase,
      (finish - first) / (n_tokens - 1), per request with >= 2 tokens.
    * goodput: completed tokens per elapsed tick — tokens of *finished*
      requests only, so shed/preempted-to-death work doesn't count.
    * per class: the same plus SLO attainment against the engine's
      ``SLOClass`` targets when they are set.
    * wall-clock: when the engine's telemetry measured tick times
      (``serve.telemetry``, default-on), the summary adds the tick-time
      histogram (``tick_wall_s_*``) and millisecond latency percentiles
      (tick-domain latency x mean measured tick). Pass the traffic
      ``classes`` to also report attainment against any ``ttft_ms`` /
      ``tpot_ms`` targets they carry — the carried-over ROADMAP item:
      SLOs priced in milliseconds on the machine actually serving, not
      just in ticks.
    """
    by_class: Dict[str, List[Arrival]] = {}
    for a in arrivals:
        by_class.setdefault(a.rclass, []).append(a)
    elapsed = max(1, engine.ticks)
    done_tokens = sum(len(v) for r, v in engine.finished.items()
                      if engine.outcome.get(r) == "done")
    all_tokens = sum(len(v) for v in engine.finished.values())
    tel = getattr(engine, "telemetry", None)
    tstats = tel.tick_stats() if tel is not None else {"n": 0}
    # ticks -> milliseconds via the measured mean tick time. None when
    # nothing was measured (telemetry disabled): the ms fields are then
    # simply absent rather than fabricated.
    tick_ms = tstats["mean_s"] * 1e3 if tstats["n"] else None
    wall_cls = {c.name: c for c in (classes or ())}

    def roll(arrs: List[Arrival]) -> Dict[str, object]:
        ttfts, tpots = [], []
        n_done = n_forced = n_rejected = 0
        ttft_ok = tpot_ok = ttft_n = tpot_n = 0
        ttft_ms_ok = tpot_ms_ok = ttft_ms_n = tpot_ms_n = 0
        for a in arrs:
            cls = engine._classes.get(a.rclass)
            wcls = wall_cls.get(a.rclass)
            out = engine.outcome.get(a.rid, "")
            if out == "done":
                n_done += 1
            elif out.startswith("forced"):
                n_forced += 1
            elif out.startswith("rejected"):
                n_rejected += 1
            ft = engine.first_token_tick.get(a.rid)
            sub = engine.submit_tick.get(a.rid)
            if ft is not None and sub is not None:
                ttft = ft - sub
                ttfts.append(ttft)
                if cls is not None and cls.ttft_slo is not None:
                    ttft_n += 1
                    ttft_ok += ttft <= cls.ttft_slo
                if wcls is not None and wcls.ttft_ms is not None \
                        and tick_ms is not None:
                    ttft_ms_n += 1
                    ttft_ms_ok += ttft * tick_ms <= wcls.ttft_ms
            fin = engine.finish_tick.get(a.rid)
            n_tok = len(engine.finished.get(a.rid, ()))
            if ft is not None and fin is not None and n_tok >= 2:
                tpot = (fin - ft) / (n_tok - 1)
                tpots.append(tpot)
                if cls is not None and cls.tpot_slo is not None:
                    tpot_n += 1
                    tpot_ok += tpot <= cls.tpot_slo
                if wcls is not None and wcls.tpot_ms is not None \
                        and tick_ms is not None:
                    tpot_ms_n += 1
                    tpot_ms_ok += tpot * tick_ms <= wcls.tpot_ms
        out = {
            "offered": len(arrs),
            "done": n_done,
            "forced": n_forced,
            "rejected": n_rejected,
            "ttft_p50": _pct(ttfts, 50), "ttft_p99": _pct(ttfts, 99),
            "tpot_p50": _pct(tpots, 50), "tpot_p99": _pct(tpots, 99),
        }
        if ttft_n:
            out["ttft_slo_attainment"] = ttft_ok / ttft_n
        if tpot_n:
            out["tpot_slo_attainment"] = tpot_ok / tpot_n
        if tick_ms is not None:
            out["ttft_ms_p50"] = out["ttft_p50"] * tick_ms
            out["ttft_ms_p99"] = out["ttft_p99"] * tick_ms
            out["tpot_ms_p50"] = out["tpot_p50"] * tick_ms
            out["tpot_ms_p99"] = out["tpot_p99"] * tick_ms
        if ttft_ms_n:
            out["ttft_ms_slo_attainment"] = ttft_ms_ok / ttft_ms_n
        if tpot_ms_n:
            out["tpot_ms_slo_attainment"] = tpot_ms_ok / tpot_ms_n
        return out

    summary: Dict[str, object] = roll(arrivals)
    summary.update({
        "ticks": engine.ticks,
        "goodput_tokens_per_tick": done_tokens / elapsed,
        "total_tokens_per_tick": all_tokens / elapsed,
        "shed_rate": sum(engine.shed_by_class.values())
        / max(1, len(arrivals)),
        "preemptions": engine.preemptions,
        "admission_holds": engine.admission_rejections,
        "downshifts": engine.downshifts,
        "degraded_ticks": engine.degraded_ticks,
        "by_class": {name: roll(arrs)
                     for name, arrs in sorted(by_class.items())},
    })
    if tstats["n"]:
        summary.update({
            "wall_s": tstats["total_s"],
            "tick_wall_s_mean": tstats["mean_s"],
            "tick_wall_s_p50": tstats["p50_s"],
            "tick_wall_s_p99": tstats["p99_s"],
        })
    return summary
