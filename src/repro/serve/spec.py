"""Speculative decoding: draft sources + exact accept/reject bookkeeping.

The paper's recurring move is pricing a latency-hiding mechanism by how
much parallel work it stacks behind one fixed-cost serial step (dual-issue
behind a shared scheduler slot, cache-line geometry behind one tag lookup,
TLB reach behind one translation). Small-batch decode has exactly that
shape: every engine tick pays a fixed dispatch + full weight stream from
HBM to emit *one* token per slot. Speculative decoding widens the tick —
``k`` cheap drafted tokens are scored together with the pending token in a
single verify pass, so the fixed per-tick cost amortizes over every
accepted token (``core.autotune.spec_decode_model`` prices the trade; the
engine's ``_spec_tick`` executes it).

Pieces:

* **Draft sources** — anything with ``propose(history, k) -> <=k token
  ids``. ``NgramDraft`` needs no second model: it looks the trailing
  n-gram up in the slot's own history (prompt-lookup decoding) and
  proposes whatever followed it last time — free on repetitive spans.
  ``ModelDraft`` runs a small draft model greedily over a fixed sliding
  window (one jitted rollout executable, any ``configs/`` arch with a
  compatible vocab). ``ScriptedDraft`` forces an accept/reject pattern
  against a known reference stream — the oracle tests' instrument.
* **Acceptance** — ``longest_accept``: exact token-match acceptance.
  The verify pass picks a target token at every position; drafts are
  accepted up to the first mismatch and the target at that position is
  the corrected *bonus* token, so every verify tick emits at least one
  token (a zero-accept tick degrades to plain decode) and the emitted
  stream is the one the non-speculative engine would have produced —
  bit-identical under greedy, and under temperature sampling too because
  the engine keys every emitted position by (request, position), not by
  tick (``per_row_sampler`` consumes one key per position).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


def per_row_sampler(temperature: float) -> Callable:
    """logits (..., vocab) + keys (..., 2) -> ids; one PRNG key per row.

    The engine samples every emitted position under its own key (derived
    from the request id and the position index), so a speculative verify
    scoring positions t..t+k consumes exactly the keys the plain engine
    would have, one tick at a time — the parity that makes spec-vs-plain
    streams identical even at temperature > 0. Greedy ignores the keys.
    """
    if temperature == 0.0:
        return lambda logits, keys: jnp.argmax(logits, -1).astype(jnp.int32)

    def sample(logits, keys):
        lead = logits.shape[:-1]
        flat_l = logits.reshape((-1, logits.shape[-1]))
        flat_k = keys.reshape((-1, 2))
        toks = jax.vmap(lambda l, k: jax.random.categorical(
            k, l.astype(jnp.float32) / temperature))(flat_l, flat_k)
        return toks.reshape(lead).astype(jnp.int32)

    return sample


def fold_row_keys(base_key, rids, ts):
    """Per-row sampling keys derived *inside* a jitted step: (b,) request
    ids + (b,) emitted indices -> (b, 2) keys, fold_in(fold_in(base, rid),
    t) per row. Keeps the per-(request, position) key discipline without
    per-tick host-side fold_in dispatches on the hot decode path (the
    engine's no-per-tick-sync invariant)."""
    return jax.vmap(lambda r, t: jax.random.fold_in(
        jax.random.fold_in(base_key, r), t))(rids, ts)


def fold_span_keys(base_key, rids, t0s, width: int):
    """Verify-tick keys: (b,) request ids + (b,) first emitted indices ->
    (b, width, 2), position j of row i keyed by (rids[i], t0s[i] + j)."""
    def row(r, t0):
        kb = jax.random.fold_in(base_key, r)
        return jnp.stack([jax.random.fold_in(kb, t0 + j)
                          for j in range(width)])

    return jax.vmap(row)(rids, t0s)


def longest_accept(drafts: Sequence[int],
                   targets: Sequence[int]) -> Tuple[int, List[int]]:
    """Exact-match acceptance: longest accepted prefix + corrected bonus.

    ``drafts`` are the k proposed tokens; ``targets`` the k+1 verify picks
    (``targets[j]`` is the model's choice *after* context + drafts[:j]).
    Draft j is accepted iff it equals ``targets[j]``; the emitted tokens
    are the accepted prefix plus ``targets[a]`` — the token the plain
    engine would have produced at the first divergence (or the free bonus
    token when everything was accepted). Always emits >= 1 token.
    """
    a = 0
    while a < len(drafts) and int(drafts[a]) == int(targets[a]):
        a += 1
    return a, [int(t) for t in drafts[:a]] + [int(targets[a])]


def rechoose_k(cfg: T.ModelConfig, page_size: int, lengths, accept_rate: float,
               k_max: int, in_bytes: int = 4,
               constants=None) -> Tuple[int, dict]:
    """Feed a *measured* accept rate back into the spec cost model.

    ``choose_spec_k`` was built to be consulted offline with a guessed
    accept rate; the engine instead measures ``accepted / proposed`` over
    a window of verify ticks (its ``spec_accepted`` / ``spec_ticks``
    counters) and re-prices the draft width against the current slot
    lengths here — candidates capped at ``k_max``, the verify
    executable's traced width. Returns 0 when no width beats plain
    decode (the disable regime a collapsing accept rate lands in).
    """
    from repro.core import autotune

    param_bytes = float(T.active_param_count(cfg)) * in_bytes
    k, terms = autotune.choose_spec_k(
        [int(l) for l in lengths], cfg.n_heads, cfg.n_kv_heads, cfg.dhead,
        page_size, float(accept_rate), param_bytes,
        ks=tuple(range(1, k_max + 1)), in_bytes=in_bytes,
        constants=constants)
    return min(k, k_max), terms


# ----------------------------------------------------------------------------
# Draft sources
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class NgramDraft:
    """Prompt-lookup drafting: no second model, no extra HBM.

    Proposes the k tokens that followed the most recent *previous*
    occurrence of the history's trailing ``n``-gram, backing off to
    shorter n-grams down to ``min_n``; proposes nothing when the history
    never repeats (the verify tick then degrades to plain decode width).
    Accept rate is whatever the workload's self-similarity buys — high on
    code, quotes, and structured spans, ~zero on fresh prose.

    The lookup scans only the trailing ``window`` tokens: drafting sits
    on the host between device steps, so its cost must stay constant in
    context length — that bound is exactly what lets
    ``core.autotune.NGRAM_DRAFT_S`` price a draft token as a
    length-independent constant in ``choose_spec_k``.
    """

    n: int = 3
    min_n: int = 1
    window: int = 1024

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history, np.int32).ravel()[-self.window:]
        length = len(h)
        for n in range(min(self.n, length - 1), self.min_n - 1, -1):
            pat = h[length - n:]
            windows = np.lib.stride_tricks.sliding_window_view(h, n)
            hits = np.nonzero((windows == pat).all(axis=1))[0]
            hits = hits[hits < length - n]      # exclude the query itself
            if not hits.size:
                continue
            # Prefer the most recent occurrence with k whole continuation
            # tokens; a tail-touching match means the history ends in a
            # short cycle, so extend the proposal cyclically — a constant
            # or period-p tail then drafts k full tokens, not the one or
            # two left before the end.
            full = hits[hits + n + k <= length]
            start = int(full[-1] if full.size else hits[-1]) + n
            cont = h[start:start + k]
            if len(cont) < k:
                # Tail-touching match: every hit ends before the final
                # n-gram, so at least one continuation token exists.
                cycle = h[start:]
                cont = np.tile(cycle, -(-k // len(cycle)))[:k]
            return cont
        return np.zeros((0,), np.int32)


class ModelDraft:
    """Draft-model rollout: greedy k-token continuation from a (small)
    model over a fixed sliding window of the history.

    The window keeps every shape static — one jitted prefill-and-rollout
    executable per k, reused for every slot and every tick (its traces are
    the draft's own, not counted against the engine's verify gate).
    Positions are window-relative: for histories longer than ``window``
    the draft sees a shifted RoPE frame — fine for a *proposer* (the
    verify pass is what guarantees exactness), and what keeps the draft's
    cost O(window), not O(context).
    """

    def __init__(self, params, cfg: T.ModelConfig, window: int = 32):
        assert window >= 1
        self.params = params
        self.cfg = cfg
        self.window = window
        self._fns: Dict[int, Callable] = {}

    def _fn(self, k: int) -> Callable:
        fn = self._fns.get(k)
        if fn is not None:
            return fn
        cfg, window = self.cfg, self.window

        def rollout(params, tokens, true_len):
            # tokens: (1, window) right-padded history tail.
            caches = T.init_caches(cfg, 1, window + k, per_slot_index=True)
            logits, caches, _ = T.forward(params, cfg, tokens, caches=caches)
            last = jax.lax.dynamic_index_in_dim(logits[0], true_len - 1,
                                                axis=0, keepdims=False)
            # Padded rows sit at/past true_len; resetting the write
            # position masks them out of the rollout steps.
            caches = T.set_cache_lengths(caches, true_len)
            tok = jnp.argmax(last, -1).astype(jnp.int32)
            out = [tok]
            for _ in range(k - 1):
                logits, caches, _ = T.forward(params, cfg, tok[None, None],
                                              caches=caches)
                tok = jnp.argmax(logits[0, -1], -1).astype(jnp.int32)
                out.append(tok)
            return jnp.stack(out)

        fn = self._fns[k] = jax.jit(rollout)
        return fn

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history, np.int32).ravel()
        n = min(len(h), self.window)
        if n == 0 or k == 0:
            return np.zeros((0,), np.int32)
        tokens = np.zeros((1, self.window), np.int32)
        tokens[0, :n] = h[len(h) - n:]
        return np.asarray(self._fn(k)(self.params, jnp.asarray(tokens),
                                      jnp.int32(n)), np.int32)


class ScriptedDraft:
    """Forced accept/reject oracle (tests): proposes the *true* reference
    token at emitted position t when ``pattern[t % len]`` is truthy, a
    corrupted (guaranteed-rejected) token otherwise.

    ``stream`` is the reference generated stream for the single request
    this draft serves; position = len(history) - prompt_len. An all-zero
    pattern is the adversarial always-wrong draft (every verify tick then
    emits exactly one token — the plain-decode degradation the tests pin).
    """

    def __init__(self, prompt_len: int, stream: Sequence[int],
                 pattern: Sequence[int], vocab: int):
        assert len(pattern) >= 1
        self.prompt_len = prompt_len
        self.stream = np.asarray(stream, np.int32)
        self.pattern = [bool(p) for p in pattern]
        self.vocab = vocab

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        pos = len(np.asarray(history).ravel()) - self.prompt_len
        out = []
        for j in range(k):
            t = pos + j
            if t >= len(self.stream):
                break
            tok = int(self.stream[t])
            if not self.pattern[t % len(self.pattern)]:
                tok = (tok + 1) % self.vocab
            out.append(tok)
        return np.asarray(out, np.int32)


def resolve_draft(draft: Any, cfg: T.ModelConfig, params) -> Any:
    """ServeConfig.draft -> a DraftSource.

    Strings name built-ins: ``"ngram"`` (default), ``"self"``
    (self-speculation with the target model over a sliding window), or a
    ``configs/`` arch name whose smoke config becomes a freshly-initialized
    draft model (a demo stand-in for a trained draft checkpoint). Anything
    else must already quack like a DraftSource.
    """
    if draft is None:
        draft = "ngram"
    if not isinstance(draft, str):
        assert callable(getattr(draft, "propose", None)), draft
        return draft
    if draft == "ngram":
        return NgramDraft()
    if draft == "self":
        return ModelDraft(params, cfg)
    from repro import configs
    # Smoke drafts pair with smoke targets; a full-size target needs the
    # arch's full config (smoke vocabs are tiny and could never cover it).
    dcfg = configs.get_smoke(draft)
    if dcfg.vocab < cfg.vocab:
        dcfg = configs.get_config(draft)
    assert dcfg.vocab >= cfg.vocab, \
        ("draft vocab must cover the target's", dcfg.vocab, cfg.vocab)
    dparams = T.init_params(jax.random.PRNGKey(0), dcfg)
    return ModelDraft(dparams, dcfg)
