"""Serving: prefill + decode steps and a batched continuous-batching engine.

``make_serve_step`` builds the jitted one-token decode step the dry-run
lowers for the ``decode_32k`` / ``long_500k`` cells: one new token against a
KV/SSM cache of the cell's sequence length, caches donated in-place.

``ServingEngine`` is the decode fast path around it (see README.md here):

  * **Bucketed, jitted prefill** — prompts pad right to power-of-two
    buckets, so each bucket traces and compiles exactly once instead of
    once per distinct prompt length. The padded K/V rows are never
    attended (per-slot write positions are reset to the true length) and
    are overwritten as decode advances.
  * **Fused slot install** — the row caches produced by prefill scatter
    into the engine's batch caches inside the same jitted executable
    (one ``dynamic_update_slice`` per leaf, caches donated), not as a
    per-leaf host loop.
  * **Donated decode** — ``tick`` threads the engine caches through the
    decode step with buffer donation, so the cache never exists twice.
  * **Per-slot lengths** — caches carry one write position per slot;
    with ``use_flash`` the flash-decode kernel scalar-prefetches them and
    streams only each slot's live K/V blocks (O(context), not O(max_len)).
  * **Paged KV** (``ServeConfig.paged``) — slots stop reserving ``max_len``
    rows each: K/V rows live in a shared page pool (``serve.paged``) and
    each slot owns a page table. Admission allocates the first prompt
    chunk's pages (rejecting cleanly when the pool is short — the request
    stays queued), decode allocates lazily one page at a time as contexts
    grow, and freeing a slot returns its pages for immediate reuse.
  * **Chunked paged prefill** — prompts are written *in place* through the
    page table in fixed-size chunks (``ServeConfig.chunk_size``, default
    from the autotune chunk cost model): one jitted chunk executable total
    — not one per bucket — runs one chunk per mid-prefill slot per tick,
    so decode ticks keep making progress while a long prompt streams in.
    There is no contiguous row cache and no install scatter: the chunk's
    K/V rows land in their pages as they are computed, VMEM stays bounded
    at one chunk, and pages are pre-allocated per chunk right before the
    chunk that writes them.
  * **Preemption** — pool exhaustion mid-decode (or mid-prefill) preempts
    the youngest slot instead of raising: its pages return to the pool and
    its request re-queues at the head with generated tokens preserved
    (re-prefilled as prompt context on re-admission). Counted in
    ``engine.preemptions``; only a pool with nothing left to preempt still
    raises ``PagePoolExhausted``.
  * **Speculative decoding** (``ServeConfig.spec_k``, paged only) — each
    tick drafts ``k`` tokens per decode-active slot (``serve.spec`` draft
    sources: n-gram prompt lookup or a small draft model) and scores them
    together with the pending token in ONE batched verify executable over
    the paged ``s > 1`` attention path (``layers._paged_apply``,
    write-then-attend). The longest accepted prefix plus the corrected
    bonus token is emitted (>= 1 token per slot per tick; zero accepts
    degrade to plain decode), write positions roll back over rejected
    rows, and the emitted stream is exactly the plain engine's.
  * **Per-position sampling keys** — every emitted token is sampled under
    a key derived from (request id, emitted index), never from the tick
    count: preempted streams replay bit-identically on re-admission and
    the speculative verify consumes exactly the keys sequential decode
    would, so spec == plain holds at temperature > 0 too.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as sharding_mod
from repro.models import transformer as T
from repro.serve import paged as paged_mod
from repro.serve import spec as spec_mod
from repro.serve import telemetry as telemetry_mod


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One multi-tenant request class and its service-level objectives.

    ``priority`` orders both admission (higher classes admit first) and
    preemption (lower classes are evicted first). The TTFT/TPOT targets
    are accounting, not scheduling inputs — ``serve.traffic.summarize``
    reports attainment against them. ``rate``/``burst`` parameterize the
    class's admission token bucket (tokens per engine tick / bucket cap):
    a class can never occupy more sustained token throughput than its
    refill rate, so one tenant's burst cannot starve the others. A class
    with ``rate=None`` admits unmetered (subject only to pool headroom).
    """

    name: str
    priority: int = 0            # higher = more important
    ttft_slo: Optional[int] = None     # target ticks to first token
    tpot_slo: Optional[float] = None   # target ticks per output token
    rate: Optional[float] = None       # admission bucket refill, tokens/tick
    burst: Optional[float] = None      # bucket cap; None -> 8 * rate

    @property
    def bucket_cap(self) -> float:
        if self.burst is not None:
            return float(self.burst)
        return 8.0 * float(self.rate or 0.0)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    batch: int
    temperature: float = 0.0     # 0 -> greedy
    eos_id: int = 1
    seed: int = 0                # sampling PRNG (temperature > 0)
    min_bucket: int = 8          # smallest prefill bucket (power of two)
    paged: bool = False          # KV rows from a shared page pool
    page_size: int = 16          # KV rows per page (paged=True)
    n_pages: Optional[int] = None  # pool size incl. null page; None ->
    # the contiguous equivalent (batch * max_len / page_size + 1), i.e.
    # no savings but no exhaustion risk; size it down to reclaim HBM.
    chunk_size: Optional[int] = None  # prefill chunk rows (paged=True);
    # must be a page_size multiple; None -> the autotune chunk cost
    # model's choice (``core.autotune.choose_prefill_chunk``).
    spec_k: int = 0              # drafted tokens per verify tick (paged
    # only); 0 disables speculation — ``core.autotune.choose_spec_k``
    # prices when that is the right call.
    draft: Any = None            # spec_k > 0: a serve.spec DraftSource,
    # or "ngram" (default) / "self" / a configs/ arch name.
    spec_adapt_every: Optional[int] = None  # re-choose the live draft
    # width from the measured accept rate every N verify ticks
    # (``serve.spec.rechoose_k`` -> ``core.autotune.choose_spec_k``);
    # None keeps k fixed at spec_k. The verify executable's width stays
    # spec_k + 1 (one trace); only how many drafts are requested adapts,
    # and a collapsed accept rate drives ``k_live`` to 0 — plain decode
    # ticks — until the next window re-opens speculation.
    prefill_chunks_per_tick: Optional[int] = None  # per-tick prefill
    # chunk budget; None runs one chunk for *every* mid-prefill slot.
    # With a budget, the shortest-remaining-first order decides who runs.
    prefix_cache: bool = False   # paged only: share full-page-aligned
    # prompt prefixes across requests through the page table (refcounted
    # pages + hash-keyed ``paged.PrefixIndex``). Admission maps cached
    # pages into the new slot (zero data movement) and chunk-prefills
    # only the uncached suffix; copy-on-write splits any shared page
    # before a write could land in it; unreferenced cached prefixes are
    # reclaimed LRU before preemption fires. Token streams stay
    # bit-identical to an uncached engine on every path.
    # ``core.autotune.choose_prefix_cache`` prices when to enable it.
    # -- overload robustness (all default-off: legacy behavior unchanged) --
    classes: Optional[Tuple[SLOClass, ...]] = None  # multi-tenant request
    # classes: admission runs highest-priority-first with per-class
    # token-bucket metering; requests name their class via
    # ``Request.rclass`` (unknown names fall back to priority 0,
    # unmetered).
    max_queue: Optional[int] = None  # bounded queue: beyond this depth
    # the lowest-priority newest queued request is *shed* (cleanly
    # rejected, counted in ``engine.shed_by_class``/``rejected``) instead
    # of queueing unboundedly.
    max_preemptions: Optional[int] = None  # per-request preemption cap:
    # a request evicted this many times is next force-completed (partial
    # stream kept) or cleanly rejected instead of re-queued — bounds
    # preemption livelock. Also switches lone-slot pool exhaustion from
    # raising PagePoolExhausted to self-preemption (graceful ladder).
    preempt_cooldown: int = 2    # storm guard: a re-admitted slot is not
    # chosen as a preemption victim again for this many ticks while any
    # other victim exists (prevents admit/evict livelock under churn).
    degrade: bool = False        # automatic load-shedding downshifts:
    # under pressure (pool occupancy / queue depth, hysteresis via
    # ``core.autotune.choose_degradation``) the engine disables
    # speculation and tightens the prefill chunk budget for the tick,
    # recovering when pressure clears. Emitted tokens are unchanged —
    # every downshifted mode is bit-identical on the tokens it emits.
    pressure_high: float = 0.85  # enter degraded mode at/above this
    pressure_low: float = 0.60   # leave degraded mode at/below this
    spec_probe_every: Optional[int] = None  # adaptive spec-k probing:
    # while ``k_live == 0`` (the disable regime), run a k=1 trial verify
    # tick every N plain ticks; trial accept stats feed the normal
    # adaptation window, so speculation *recovers* when a collapsed
    # accept rate clears (requires spec_adapt_every). None keeps the
    # disable regime terminal (legacy).
    # -- observability (``serve.telemetry``) -------------------------------
    telemetry: bool = True       # event ring + wall-clock spans. Disabling
    # drops the ring buffers and every perf_counter read; the decision
    # *aggregates* (admission_rejections, shed_by_class, ...) stay exact
    # either way, and token streams are bit-identical traced or not.
    trace_capacity: int = 4096   # ring-buffer entries per stream (events,
    # spans, tick times); eviction never touches the aggregates.


def prefill(params, cfg: T.ModelConfig, tokens, caches,
            frontend_embeds=None):
    """Run the prompt through the model, filling the caches."""
    logits, caches, _ = T.forward(params, cfg, tokens, caches=caches,
                                  frontend_embeds=frontend_embeds)
    return logits[:, -1], caches


def decode_step(params, cfg: T.ModelConfig, last_tokens, caches,
                frontend_embeds=None, unembed_fn=None):
    """One decode step: (b,) token ids -> (b,) next ids + new caches."""
    logits, caches, _ = T.forward(params, cfg, last_tokens[:, None],
                                  caches=caches,
                                  frontend_embeds=frontend_embeds,
                                  unembed_fn=unembed_fn)
    return logits[:, -1], caches


def sampler(temperature: float) -> Callable:
    """logits (..., vocab) -> token ids; greedy at temperature 0."""
    if temperature == 0.0:
        return lambda logits, key: jnp.argmax(logits, -1).astype(jnp.int32)

    def sample(logits, key):
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)

    return sample


def make_serve_step(cfg: T.ModelConfig, donate: bool = True,
                    temperature: float = 0.0) -> Callable:
    """Jitted decode step (the dry-run's serve_step), caches donated."""
    pick = sampler(temperature)

    def step(params, last_tokens, caches, frontend_embeds=None, key=None):
        logits, caches = decode_step(params, cfg, last_tokens, caches,
                                     frontend_embeds=frontend_embeds)
        return pick(logits, key), caches

    return jax.jit(step, donate_argnums=(2,) if donate else ())


def greedy_generate(params, cfg: T.ModelConfig, prompt, max_new: int,
                    max_len: Optional[int] = None, frontend_embeds=None):
    """Reference generation loop (tests compare engine output to this).

    The decode step donates its caches: each iteration rebinds ``caches``
    to the step's output, so the donated buffer is never read again.
    """
    b, s = prompt.shape
    max_len = max_len or (s + max_new)
    caches = T.init_caches(cfg, b, max_len)
    logits, caches = prefill(params, cfg, prompt, caches,
                             frontend_embeds=frontend_embeds)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    step = make_serve_step(cfg, donate=True)
    for _ in range(max_new - 1):
        tok, caches = step(params, tok, caches,
                           frontend_embeds=frontend_embeds)
        out.append(tok)
    return jnp.stack(out, axis=1)


def _counter_view(key: str, doc: str) -> property:
    """A legacy engine counter as a view over ``telemetry.counters``.

    Readable and writable (benches zero counters at the warm-up
    boundary), but the stored value lives in the telemetry aggregates —
    the event trace and the counter can never disagree."""
    def get(self):
        return self.telemetry.counters.get(key, 0)

    def set_(self, v):
        self.telemetry.counters[key] = int(v)

    return property(get, set_, doc=doc)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rclass: str = "default"      # SLO class name (ServeConfig.classes)
    preempt_count: int = 0       # times evicted back to the queue
    readmitted_at: Optional[int] = None  # tick of last re-admission
    # (preemption-storm guard input; None until first preemption)


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch.

    Requests join free slots as they arrive; each engine tick decodes one
    token for every active slot. Finished slots free immediately and their
    ``last_tok`` entry resets to 0 so a stale token can never collide with
    ``eos_id`` on a later tick.
    """

    def __init__(self, params, cfg: T.ModelConfig, serve_cfg: ServeConfig,
                 mesh=None):
        self.cfg = cfg
        self.scfg = serve_cfg
        self.mesh = mesh
        # The cost-constant set pricing every choose_* decision this
        # engine makes: calibrated (core.calibrate probes for this
        # backend+mesh, read from the tuning cache) when available,
        # the documented defaults otherwise. REPRO_DEFAULT_CONSTANTS=1
        # forces the defaults — the reproducibility escape hatch.
        from repro.core import autotune as _autotune
        self.constants = _autotune.resolve_constants(mesh_shape=mesh)
        # Distributed serving (``serve.dist``): weights tensor-parallel
        # under the serving ruleset, the page pool device-sharded over the
        # pool axis, the unembed GEMM routed through the overlapped
        # collective ring. All host-side scheduling below is mesh-blind —
        # it prices admission/preemption against the *global* pool, so the
        # sharded engine's token streams and scheduling decisions are
        # bit-identical to the single-device paged engine's.
        if mesh is not None:
            from repro.dist import collective_matmul
            from repro.serve import dist as serve_dist
            assert serve_cfg.paged, "mesh serving is paged-only"
            self._ruleset = serve_dist.serve_ruleset(mesh)
            axis = self._ruleset._rule(serve_dist.POOL_RULE)
            self._pool_axis = axis
            self._n_dev = int(dict(mesh.shape).get(axis, 1))
            self._unembed_fn = collective_matmul.serve_unembed(mesh, axis)
            self.params = self._shard_params(params, mesh)
        else:
            self._ruleset = None
            self._pool_axis = None
            self._n_dev = 1
            self._unembed_fn = None
            self.params = params
        # Bucketing pads the prompt on the right; that only composes with
        # attention layers (masked K/V). SSM/hybrid stacks carry recurrent
        # state through every position, so they prefill at exact length
        # (still jitted + fused — just one executable per distinct length).
        self._bucketed = all(k in ("attn", "cross") for k in cfg.pattern) \
            and cfg.encoder is None and not cfg.n_frontend_tokens
        if serve_cfg.paged:
            assert self._bucketed, \
                "paged KV serving requires an attention-only stack"
            assert serve_cfg.max_len % serve_cfg.page_size == 0, \
                (serve_cfg.max_len, serve_cfg.page_size)
            n_pages = serve_cfg.n_pages or (
                1 + serve_cfg.batch * serve_cfg.max_len
                // serve_cfg.page_size)
            if n_pages % self._n_dev:
                # Striping needs equal blocks; rounding up only ever adds
                # capacity. Explicit n_pages on a mesh should already
                # divide it (parity runs pass the same pool both ways).
                n_pages += self._n_dev - n_pages % self._n_dev
            self.pool: Optional[paged_mod.PageAllocator] = \
                paged_mod.PageAllocator(n_pages, serve_cfg.page_size,
                                        n_devices=self._n_dev)
            self.caches = T.init_paged_caches(
                cfg, serve_cfg.batch, serve_cfg.max_len,
                serve_cfg.page_size, n_pages, mesh=mesh,
                pool_axis=self._pool_axis or "model")
            chunk = serve_cfg.chunk_size
            if chunk is None:
                from repro.core import autotune
                chunk, _ = autotune.choose_prefill_chunk(
                    serve_cfg.max_len, cfg.n_heads, cfg.n_kv_heads,
                    cfg.dhead, serve_cfg.page_size,
                    constants=self.constants)
            assert chunk % serve_cfg.page_size == 0 \
                and 0 < chunk <= serve_cfg.max_len, \
                (chunk, serve_cfg.page_size, serve_cfg.max_len)
            self.chunk: Optional[int] = chunk
            self._chunk_fn = self._make_chunk_fn()
            # Prefix cache: hash-keyed index over the pool's pages.
            # Host-side only (refcounts + digests) — the device caches
            # and kernels are untouched; sharing is purely which page
            # ids appear in which slots' tables.
            self.prefix: Optional[paged_mod.PrefixIndex] = \
                paged_mod.PrefixIndex(self.pool) \
                if serve_cfg.prefix_cache else None
        else:
            assert not serve_cfg.prefix_cache, \
                "prefix_cache requires paged=True (it shares pages)"
            self.prefix = None
            self.pool = None
            self.chunk = None
            self.caches = T.init_caches(cfg, serve_cfg.batch,
                                        serve_cfg.max_len,
                                        per_slot_index=True)
        self.slots: List[Optional[Request]] = [None] * serve_cfg.batch
        self.queue: List[Request] = []
        self.last_tok = jnp.zeros((serve_cfg.batch,), jnp.int32)
        self.finished: Dict[int, List[int]] = {}
        self._base_key = jax.random.PRNGKey(serve_cfg.seed)
        self._rid_keys: Dict[int, Any] = {}
        self._zero_key = jnp.zeros((2,), jnp.uint32)
        self._zero_ids = jnp.zeros((serve_cfg.batch,), jnp.int32)
        self._prefill_fns: Dict[int, Callable] = {}
        self.prefill_traces: Dict[int, int] = {}
        self.decode_traces = 0
        self.verify_traces = 0            # spec verify executables traced
        # Observability (``serve.telemetry``): the event trace IS the
        # bookkeeping — the legacy counters below the class body
        # (admission_rejections, preemptions, spec stats, shed_by_class,
        # preemption_log, ...) are properties reading the telemetry
        # aggregates, so decision accounting has exactly one home.
        self.telemetry = telemetry_mod.Telemetry(
            enabled=serve_cfg.telemetry, capacity=serve_cfg.trace_capacity)
        self.ticks = 0
        self.first_token_tick: Dict[int, int] = {}   # rid -> TTFT (ticks)
        self._prefilling: Dict[int, int] = {}   # slot -> prompt rows written
        self._prefill_wait: Dict[int, int] = {} # slot -> ticks since served
        self._slot_seq: Dict[int, int] = {}     # slot -> admission sequence
        # Prefix-cache publish cursor per slot: (digest of the deepest
        # published/matched prefix, pages published so far). Seeded at
        # admission from the probe; advanced as prefill completes pages.
        self._chain: Dict[int, Tuple[bytes, int]] = {}
        self._admit_seq = 0
        # -- overload-robustness accounting -----------------------------------
        self.submit_tick: Dict[int, int] = {}   # rid -> tick of submit()
        self.finish_tick: Dict[int, int] = {}   # rid -> tick of last token
        self.rejected: Dict[int, str] = {}      # rid -> shed/reject reason
        self.outcome: Dict[int, str] = {}       # rid -> done|forced:*|rejected:*
        self._arrival_seq: Dict[int, int] = {}  # rid -> submit order
        self._n_arrivals = 0
        self._classes: Dict[str, SLOClass] = {
            c.name: c for c in (serve_cfg.classes or ())}
        assert len(self._classes) == len(serve_cfg.classes or ()), \
            "duplicate SLO class names"
        for c in self._classes.values():
            assert c.rate is None or c.rate > 0, (c.name, c.rate)
        self._buckets: Dict[str, float] = {
            c.name: c.bucket_cap for c in self._classes.values()
            if c.rate is not None}
        if serve_cfg.max_queue is not None:
            assert serve_cfg.max_queue >= 1, serve_cfg.max_queue
        if serve_cfg.max_preemptions is not None:
            assert serve_cfg.max_preemptions >= 0, serve_cfg.max_preemptions
        assert serve_cfg.preempt_cooldown >= 0
        self.degraded = False           # load-shedding downshift latch
        self.last_pressure = 0.0
        self._probe_wait = 0
        self.spec_k = serve_cfg.spec_k
        self.k_live = self.spec_k     # adaptive draft width (<= spec_k)
        self._adapt_ticks = 0         # verify ticks since last re-choice
        self._adapt_proposed = 0      # drafted tokens in the window
        self._adapt_accepted = 0      # ... of which accepted
        if self.spec_k:
            assert self.spec_k >= 1
            assert self.pool is not None, \
                "speculative decoding needs paged=True (verify runs the " \
                "paged s>1 attention path)"
            self.draft = spec_mod.resolve_draft(serve_cfg.draft, cfg, params)
            self._verify_fn = self._make_verify_fn()
        if serve_cfg.spec_adapt_every is not None:
            assert serve_cfg.spec_adapt_every >= 1 and self.spec_k
        if serve_cfg.spec_probe_every is not None:
            # Probing needs the adaptation clock: trial-tick accept stats
            # recover k_live through the same rechoose_k window.
            assert serve_cfg.spec_probe_every >= 1 and self.spec_k \
                and serve_cfg.spec_adapt_every is not None
        if serve_cfg.prefill_chunks_per_tick is not None:
            assert serve_cfg.prefill_chunks_per_tick >= 1, \
                serve_cfg.prefill_chunks_per_tick
        self._step = self._make_decode_step()

    # -- telemetry-backed counter views ---------------------------------------
    # One bookkeeping home: these are the same attributes callers always
    # read (and benches reset), backed by the event-trace aggregates.

    admission_rejections = _counter_view(
        "admit_hold", "pool-exhausted admission holds")
    preemptions = _counter_view(
        "preempt", "slots evicted back to the queue")
    spec_ticks = _counter_view(
        "spec_verify", "(slot, tick) verify events")
    spec_accepted = _counter_view(
        "spec_accepted", "drafted tokens accepted")
    spec_emitted = _counter_view(
        "spec_emitted", "tokens emitted by verify ticks")
    spec_probes = _counter_view(
        "probe_tick", "k=1 trial ticks while speculation is disabled")
    downshifts = _counter_view(
        "degrade_enter", "clean->degraded ladder transitions")
    degraded_ticks = _counter_view(
        "degraded_tick", "ticks spent in degraded mode")
    prefix_hits = _counter_view(
        "prefix_hit", "admissions that mapped cached prefix pages")
    prefix_misses = _counter_view(
        "prefix_miss", "admissions that probed the index and found none")
    prefix_hit_pages = _counter_view(
        "prefix_hit_pages", "cached pages mapped by admissions (sum)")
    cow_copies = _counter_view(
        "cow_copy", "copy-on-write splits of shared pages")
    prefix_evictions = _counter_view(
        "prefix_evict", "LRU reclaims of cached-idle prefix runs")

    @property
    def shed_by_class(self) -> Dict[str, int]:
        """Clean rejects per class (view over ``shed`` events)."""
        return self.telemetry.shed_by_class

    @property
    def preemption_log(self) -> List[Tuple[int, str, int]]:
        """(rid, class, tokens generated at eviction) per ``preempt``
        event — fairness accounting."""
        return self.telemetry.preemption_log

    # -- distributed placement ------------------------------------------------

    def _shard_params(self, params, mesh):
        """Tensor-parallel placement: each leaf lands with the spec its
        name resolves to under the serving ruleset (heads/mlp/vocab over
        "model"; norms and non-divisible leaves replicate). device_put
        up front — the executables then see committed shardings and emit
        no surprise resharding on the hot path."""
        from repro.dist import sharding as shd

        def put(path, leaf):
            names = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path)
            spec = shd.param_spec(names, leaf.shape, self._ruleset)
            return jax.device_put(
                leaf, jax.sharding.NamedSharding(mesh, spec))

        return jax.tree_util.tree_map_with_path(put, params)

    # -- jitted executables ---------------------------------------------------

    def _make_decode_step(self) -> Callable:
        temp = self.scfg.temperature
        pick = spec_mod.per_row_sampler(temp)
        cfg, base = self.cfg, self._base_key

        def step(params, last_tokens, caches, rids, ts):
            self.decode_traces += 1          # runs at trace time only
            with sharding_mod.use_ruleset(self._ruleset):
                logits, caches = decode_step(
                    params, cfg, last_tokens, caches,
                    unembed_fn=self._unembed_fn)
            # Keys fold inside the executable (no per-tick host fold_ins);
            # greedy never consumes them, so skip the fold entirely.
            keys = spec_mod.fold_row_keys(base, rids, ts) if temp else None
            return pick(logits, keys), caches

        return jax.jit(step, donate_argnums=(2,))

    def _make_verify_fn(self) -> Callable:
        """The ONE jitted draft-verify executable. Width is fixed at
        ``spec_k + 1`` (the pending token + k drafts), so it traces
        exactly once — ``verify_traces`` gates it like the prefill
        executables. One batched forward scores every slot's candidate
        row through the paged s>1 attention path (write-then-attend in
        ``layers._paged_apply``: the candidates' K/V rows scatter through
        the page table, each query attends the slot's live prefix plus
        its own candidate prefix) and picks a target token per position —
        position j's key belongs to emitted index ``len(generated) + j``,
        so sampling matches sequential decode token for token."""
        temp = self.scfg.temperature
        pick = spec_mod.per_row_sampler(temp)
        cfg, base, width = self.cfg, self._base_key, self.spec_k + 1

        def verify(params, tokens, caches, rids, t0s):
            self.verify_traces += 1          # runs at trace time only
            with sharding_mod.use_ruleset(self._ruleset):
                logits, caches, _ = T.forward(params, cfg, tokens,
                                              caches=caches,
                                              unembed_fn=self._unembed_fn)
            keys = spec_mod.fold_span_keys(base, rids, t0s, width) \
                if temp else None
            return pick(logits, keys), caches

        return jax.jit(verify, donate_argnums=(2,))

    # -- sampling keys --------------------------------------------------------

    def _slot_key(self, rid: int, t: int):
        """PRNG key for request ``rid``'s ``t``-th emitted token.

        Keyed by (request, emitted index) — never by engine tick — so a
        preempted and re-admitted stream replays bit-identically and a
        speculative verify scoring positions t..t+k consumes exactly the
        keys the plain engine would, one tick at a time."""
        base = self._rid_keys.get(rid)
        if base is None:
            # & 0xffffffff: negative rids (warm-up requests) fold as their
            # uint32 bit pattern — the same coercion the traced int32 path
            # (spec.fold_row_keys) applies, so host and device keys agree.
            base = self._rid_keys[rid] = jax.random.fold_in(
                self._base_key, rid & 0xffffffff)
        return jax.random.fold_in(base, t)

    def _emit_key(self, req: Request):
        """Key for the next token ``req`` will emit (greedy: unused)."""
        if self.scfg.temperature == 0.0:
            return self._zero_key
        return self._slot_key(req.rid, len(req.generated))

    def _rid_ts(self, active):
        """(batch,) request ids + (batch,) next emitted indices — the two
        int vectors the jitted decode/verify steps fold into sampling
        keys on-device (``spec.fold_row_keys``/``fold_span_keys``). Host
        cost is two tiny int arrays per tick; greedy reuses zeros (the
        executables never consume them)."""
        if self.scfg.temperature == 0.0:
            return self._zero_ids, self._zero_ids
        rids = np.zeros((self.scfg.batch,), np.int32)
        ts = np.zeros((self.scfg.batch,), np.int32)
        for i in active:
            req = self.slots[i]
            rids[i] = req.rid
            ts[i] = len(req.generated)
        return jnp.asarray(rids), jnp.asarray(ts)

    def bucket_for(self, prompt_len: int) -> int:
        if not self._bucketed:
            return prompt_len
        b = self.scfg.min_bucket
        while b < prompt_len:
            b *= 2
        return min(b, self.scfg.max_len)

    def _prefill_fn(self, bucket: int) -> Callable:
        """One jitted prefill-install-sample executable per bucket
        (contiguous caches only — the paged engine prefills in chunks)."""
        fn = self._prefill_fns.get(bucket)
        if fn is not None:
            return fn
        cfg, scfg = self.cfg, self.scfg
        pick = sampler(scfg.temperature)

        def prefill_into_slot(params, tokens, true_len, slot, caches, key):
            # tokens: (1, bucket) right-padded prompt.
            self.prefill_traces[bucket] = \
                self.prefill_traces.get(bucket, 0) + 1   # trace-time only
            row = T.init_caches(cfg, 1, scfg.max_len, per_slot_index=True)
            logits, row, _ = T.forward(params, cfg, tokens, caches=row)
            last = jax.lax.dynamic_index_in_dim(logits, true_len - 1,
                                                axis=1, keepdims=False)
            # Padded K/V rows sit at positions >= true_len: resetting the
            # per-slot write position masks them out of every future step
            # and decode overwrites them in place.
            row = T.set_cache_lengths(row, true_len)

            def install(f, r):
                return jax.lax.dynamic_update_slice_in_dim(
                    f, r.astype(f.dtype), slot, axis=1)

            caches = [jax.tree.map(install, f, r)
                      for f, r in zip(caches, row)]
            return pick(last[0], key), caches

        fn = jax.jit(prefill_into_slot, donate_argnums=(4,))
        self._prefill_fns[bucket] = fn
        return fn

    def _make_chunk_fn(self) -> Callable:
        """The one jitted chunked-prefill executable (chunk size is fixed,
        so this traces exactly once no matter the prompt-length mix).

        Runs one ``chunk``-token slice of one slot's prompt *in place*
        through the page table: the model forward sees a batch-1 view of
        the shared pools (this slot's table row, write position =
        ``start``), the chunk's K/V rows scatter into their pages as they
        are computed (``layers._paged_apply``), and the logit at
        ``last_in_chunk`` is sampled — the host uses it only on the final
        chunk. ``end`` (true prompt length on a padded final chunk)
        overwrites the slot's write position so padded rows are never
        attended. No row cache, no install scatter."""
        cfg, scfg = self.cfg, self.scfg
        pick = sampler(scfg.temperature)
        chunk = self.chunk

        def prefill_chunk(params, tokens, start, end, last_in_chunk, slot,
                          caches, key):
            # tokens: (1, chunk); start: rows already written; end: live
            # rows after this chunk.
            self.prefill_traces[chunk] = \
                self.prefill_traces.get(chunk, 0) + 1    # trace-time only
            view = []
            for c in caches:
                pages = jax.lax.dynamic_slice_in_dim(c["pages"], slot, 1,
                                                     axis=1)
                idx = jnp.full((c["index"].shape[0], 1), start,
                               c["index"].dtype)
                view.append(dict(c, pages=pages, index=idx))
            with sharding_mod.use_ruleset(self._ruleset):
                logits, view, _ = T.forward(params, cfg, tokens,
                                            caches=view,
                                            unembed_fn=self._unembed_fn)
            last = jax.lax.dynamic_index_in_dim(logits[0], last_in_chunk,
                                                axis=0, keepdims=False)
            new_caches = [
                dict(c, kp=v["kp"], vp=v["vp"],
                     index=c["index"].at[:, slot].set(end))
                for c, v in zip(caches, view)
            ]
            return pick(last, key), new_caches

        return jax.jit(prefill_chunk, donate_argnums=(6,))

    # -- page-table plumbing --------------------------------------------------

    def _append_pages(self, slot: int, pages: List[int],
                      fresh: bool = True) -> None:
        """Extend a slot's logical->physical map in every layer cache
        (entries [have, have+n) — chunked prefill and lazy decode growth
        both append, never overwrite live entries). ``fresh=False`` skips
        the ``page_alloc`` event: a prefix-cache hit maps *existing*
        pages (``pool.share``), traced by ``prefix_hit`` instead, so the
        page_alloc event sum stays reconciled with the allocator's
        ``pages_allocated``."""
        if not pages:
            return
        if fresh:
            self.telemetry.emit(self.ticks, "page_alloc", slot=slot,
                                n=len(pages))
        have = len(self.pool.slot_pages[slot]) - len(pages)
        cols = jnp.arange(have, have + len(pages))
        vals = jnp.asarray(pages, jnp.int32)
        self.caches = [
            dict(c, pages=c["pages"].at[:, slot, cols].set(vals))
            for c in self.caches
        ]

    # -- prefix cache (``paged.PrefixIndex``) ---------------------------------

    def _cow_page(self, slot: int, pos: int) -> None:
        """Copy-on-write split of slot table position ``pos``: allocate a
        fresh page, copy the K/V rows on device, swap the table entry.
        The one data-movement cost of sharing — ``page_size`` rows per
        layer, paid only when a write would otherwise land in a page
        another holder (slot or index) still reads."""
        old, new = self.pool.cow(slot, pos)
        self.telemetry.emit(self.ticks, "cow_copy", slot=slot,
                            old=old, new=new, pos=pos)
        self.caches = [
            dict(c, kp=c["kp"].at[:, new].set(c["kp"][:, old]),
                 vp=c["vp"].at[:, new].set(c["vp"][:, old]),
                 pages=c["pages"].at[:, slot, pos].set(new))
            for c in self.caches
        ]

    def _cow_range(self, slot: int, lo: int, hi: int) -> None:
        """Split any *shared* page backing rows [lo, hi) before a write
        lands there. In steady state this never fires — shared pages sit
        strictly below every write cursor (hits are full pages below the
        prefill cursor; published pages are full pages below the decode
        position) — except the one admission case ``_admit`` handles
        eagerly. Kept as the write-barrier invariant: *no* write path
        may touch a page with refcount >= 2."""
        if self.prefix is None:
            return
        held = self.pool.slot_pages.get(slot, ())
        ps = self.scfg.page_size
        for pos in range(lo // ps, min((max(hi, lo + 1) - 1) // ps,
                                       len(held) - 1) + 1):
            if self.pool.refcount(held[pos]) >= 2:
                self._cow_page(slot, pos)

    def _publish_rows(self, slot: int, req: Request, rows: int) -> None:
        """Advance ``slot``'s publish chain: register every *full* page
        of the effective prompt below ``rows`` (rows actually written)
        with the prefix index. Generated-token pages are never published
        (they sit at the live write cursor); a published page is always
        strictly below every later write position, so its content is
        frozen for the lifetime of the index's hold."""
        if self.prefix is None or slot not in self._chain:
            return
        ps = self.scfg.page_size
        digest, done = self._chain[slot]
        limit = min(int(rows), self._effective_len(req)) // ps
        if limit <= done:
            return
        prompt = self._effective_prompt(req)
        held = self.pool.slot_pages.get(slot, ())
        for j in range(done, min(limit, len(held))):
            nxt = self.prefix.publish(prompt[j * ps:(j + 1) * ps],
                                      held[j], digest, now=self.ticks)
            if nxt is None:      # digest collision: stop the chain here
                break
            digest, done = nxt, j + 1
        self._chain[slot] = (digest, done)

    def _evict_prefixes(self, need: int) -> bool:
        """Reclaim cached-idle prefix pages (LRU) until ``need`` pages
        are allocatable. Runs *before* any preemption: dropping an idle
        cache entry costs a future prefill at most, evicting a live slot
        costs re-prefilling work already paid for. Returns True when the
        pool can now satisfy ``need``."""
        if self.prefix is None:
            return self.pool.can_alloc(need)
        while not self.pool.can_alloc(need):
            short = need - self.pool.free_pages
            n = self.prefix.evict(short, now=self.ticks)
            if not n:
                break
            self.telemetry.emit(self.ticks, "prefix_evict", n=n)
        return self.pool.can_alloc(need)

    def _pages_through_tick(self, slot: Request) -> int:
        """Table entries ``slot`` must have for this tick's decode write.

        The slot's cache length, host-side (no device sync), is the prompt
        plus every decoded token except the freshly sampled one — which
        this tick writes at position ``length``. A speculative tick writes
        ``spec_k`` drafted rows after it (all backed *optimistically*: an
        accepted row must land in a real page; a rejected row in an owned
        page is dead weight the next write overwrites). Writes at/past
        ``max_len`` spill to the null page and need no backing. Both the
        admission headroom check and the lazy allocator below use this one
        number, so they can never disagree."""
        length = len(slot.prompt) + len(slot.generated) - 1 + self.spec_k
        max_pages = self.scfg.max_len // self.scfg.page_size
        return min(length // self.scfg.page_size + 1, max_pages)

    def _ensure_decode_pages(self) -> None:
        """Lazily grow each decode-active slot's table so the next decode
        token's write position is backed by a real page (admission only
        reserved the first chunk's pages). A short pool preempts another
        slot in ``_choose_victim`` order; a pool with nothing left to
        preempt raises ``PagePoolExhausted`` — unless
        ``ServeConfig.max_preemptions`` is set, in which case the lone
        slot *self-preempts* (graceful ladder: its partial stream
        requeues, or force-completes at the cap) instead of crashing the
        engine."""
        if self.pool is None:
            return
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            if i in self._prefilling:
                # Mid-prefill slots ride the batched decode step too —
                # their (reset) write cursor takes 1 + spec_k dead rows
                # this tick. Width-aware write barrier: split any shared
                # page those rows could touch (never fires in steady
                # state — the cursor sits at/above every shared page).
                cur = self._prefilling[i]
                self._cow_range(i, cur, cur + 1 + self.spec_k)
                continue
            # Decode write barrier: this tick writes rows
            # [eff_len - 1, eff_len + spec_k) (spec drafts included).
            eff = self._effective_len(slot)
            self._cow_range(i, max(0, eff - 1), eff + self.spec_k)
            target = self._pages_through_tick(slot)
            while len(self.pool.slot_pages.get(i, ())) < target:
                if not self._preempt_for(1, protect={i}):
                    if self.scfg.max_preemptions is not None:
                        self._preempt(i)
                        break
                    raise paged_mod.PagePoolExhausted(
                        f"slot {i} needs a decode page and no other slot "
                        f"is left to preempt; raise n_pages")
                self._append_pages(i, self.pool.alloc(i, 1))

    # -- preemption -----------------------------------------------------------

    def _class_priority(self, req: Request) -> int:
        cls = self._classes.get(req.rclass)
        return cls.priority if cls is not None else 0

    def _choose_victim(self, victims: List[int]) -> int:
        """Priority + cost preemption policy (replaces youngest-slot):

        * lowest-class-priority slots are evicted first (protect
          high-class tenants),
        * within a class, the slot with the least completion progress
          loses (protect near-done streams — their sunk prefill+decode
          work is the most expensive to re-pay),
        * ties break youngest-admitted (least total sunk work).

        Two guards rank *above* everything else in the victim score, so
        they always yield when no alternative exists (a preemption that
        must happen always can) and never force a worse class out to
        satisfy a softer guard:

        * **cap guard** (strongest) — a slot whose request already hit
          ``max_preemptions`` ranks last: preempting it again would
          force-terminate it, so any victim that can still requeue is
          preferred — across class lines.
        * **storm guard** — a slot re-admitted within the last
          ``preempt_cooldown`` ticks ranks behind its class peers, so an
          admit/evict/admit livelock can't spin on one request. Unlike
          the cap guard it yields to class protection: a cooling
          low-class slot is still evicted before a fresh high-class one
          (cooling costs a re-prefill; terminating a paying tenant's
          stream costs the SLO).
        """
        lim = self.scfg.max_preemptions
        cool = self.scfg.preempt_cooldown

        def score(i):
            req = self.slots[i]
            ra = req.readmitted_at
            cooling = ra is not None and self.ticks - ra < cool
            capped = lim is not None and req.preempt_count >= lim
            done = len(req.generated) / max(1, req.max_new)
            return (capped, self._class_priority(req), cooling, done,
                    -self._slot_seq[i])

        return min(victims, key=score)

    def _preempt_for(self, need: int, protect: set) -> bool:
        """Free pages until ``need`` are available by preempting slots
        outside ``protect`` in ``_choose_victim`` order. Returns False
        when no victim is left (the caller decides whether that is a
        stall, a self-preemption, or a crash)."""
        if self.pool is None:
            return False
        # Cached-idle prefix pages are the cheapest pages in the pool:
        # reclaim them (LRU) before any live stream is evicted.
        if self._evict_prefixes(need):
            return True
        while not self.pool.can_alloc(need):
            victims = [i for i, s in enumerate(self.slots)
                       if s is not None and i not in protect]
            if not victims:
                return False
            self._preempt(self._choose_victim(victims))
        return True

    def _finish_forced(self, req: Request, reason: str) -> None:
        """Terminal: keep the partial stream (a bit-identical *prefix* of
        the uncontended stream — per-(rid, position) sampling keys make
        every emitted token exact) and leave the system."""
        req.done = True
        self.finished[req.rid] = req.generated
        self.finish_tick[req.rid] = self.ticks
        self.outcome[req.rid] = f"forced:{reason}"
        self.telemetry.emit(self.ticks, "finish", rid=req.rid,
                            rclass=req.rclass, outcome=f"forced:{reason}",
                            n_tokens=len(req.generated))

    def _reject(self, req: Request, reason: str) -> None:
        """Terminal: clean reject with explicit accounting — the request
        emitted nothing and is reported shed, never silently dropped.
        The ``shed`` event is the record; ``shed_by_class`` is its
        aggregate view."""
        req.done = True
        self.rejected[req.rid] = reason
        self.outcome[req.rid] = f"rejected:{reason}"
        self.telemetry.emit(self.ticks, "shed", rid=req.rid,
                            rclass=req.rclass, reason=reason)

    def _preempt(self, i: int) -> None:
        """Evict slot ``i``: its pages return to the pool and its
        generated tokens are preserved — on re-admission they prefill as
        prompt context and generation continues where it stopped
        (requeued at the head). A request already at
        ``ServeConfig.max_preemptions`` is not preempted again: it
        force-completes with its partial stream (or cleanly rejects when
        it never emitted), so no request can livelock through the
        evict/re-admit cycle and ``preempt_count`` is bounded by the cap."""
        req = self.slots[i]
        self.free_slot(i)
        self.last_tok = self.last_tok.at[i].set(0)
        if len(req.prompt) + len(req.generated) >= self.scfg.max_len:
            # Context already at the cache boundary: nothing re-prefillable
            # remains (the contiguous engine would be spilling writes too),
            # so finish with what it generated instead of requeueing an
            # unservable request.
            self._finish_forced(req, "max_len")
            return
        lim = self.scfg.max_preemptions
        if lim is not None and req.preempt_count >= lim:
            if req.generated:
                self._finish_forced(req, "preempt_limit")
            else:
                self._reject(req, "preempt_limit")
            return
        self.telemetry.emit(self.ticks, "preempt", rid=req.rid,
                            rclass=req.rclass,
                            n_generated=len(req.generated))
        req.preempt_count += 1
        self.queue.insert(0, req)

    # -- request lifecycle ----------------------------------------------------

    def submit(self, req: Request):
        self.submit_tick.setdefault(req.rid, self.ticks)
        self._arrival_seq.setdefault(req.rid, self._n_arrivals)
        self._n_arrivals += 1
        self.telemetry.emit(self.ticks, "submit", rid=req.rid,
                            rclass=req.rclass, prompt_rows=len(req.prompt),
                            max_new=req.max_new)
        self.queue.append(req)
        mq = self.scfg.max_queue
        if mq is None or len(self.queue) <= mq:
            return
        # Bounded queue: shed the lowest-priority *newest* fresh request
        # (never a preempted one — its generated tokens must survive to a
        # terminal outcome) with explicit accounting. The just-submitted
        # request is always a candidate, so the bound always holds.
        cands = [r for r in self.queue if not r.preempt_count]
        victim = min(cands, key=lambda r: (
            self._class_priority(r), -self._arrival_seq[r.rid]))
        self.queue.remove(victim)
        self._reject(victim, "queue_full")

    # -- SLO-aware admission --------------------------------------------------

    def _refill_buckets(self) -> None:
        """One tick's refill for every metered class (tokens/tick,
        capped at the class's burst)."""
        for name, cls in self._classes.items():
            if cls.rate is None:
                continue
            self._buckets[name] = min(cls.bucket_cap,
                                      self._buckets[name] + cls.rate)

    def _bucket_ok(self, req: Request) -> bool:
        """Debit-style token bucket: a class may admit whenever its
        bucket is non-negative; the admitted request's full token cost
        then debits it (possibly below zero), so an oversized request is
        admitted once and paid off by refills rather than blocked
        forever. Re-admissions after preemption were charged at first
        admission and pass free."""
        cls = self._classes.get(req.rclass)
        if cls is None or cls.rate is None or req.preempt_count:
            return True
        return self._buckets[req.rclass] >= 0.0

    def _charge_bucket(self, req: Request) -> None:
        cls = self._classes.get(req.rclass)
        if cls is None or cls.rate is None or req.preempt_count:
            return
        self._buckets[req.rclass] -= \
            self._effective_len(req) + req.max_new

    def _admission_order(self) -> List[int]:
        """Queue indices in admission order. Legacy (no classes): FIFO.
        With classes: preempted re-admissions first (their sunk
        prefill+decode work is the most expensive to lose, and the
        requeue-at-head contract bounds their re-admission latency),
        then class priority descending, then arrival order."""
        if not self._classes:
            return list(range(len(self.queue)))

        def key(qi):
            r = self.queue[qi]
            return (0 if r.preempt_count else 1,
                    -self._class_priority(r),
                    self._arrival_seq.get(r.rid, qi), qi)

        return sorted(range(len(self.queue)), key=key)

    def _next_admission(self) -> Optional[int]:
        """First queue index in admission order whose class bucket
        admits; None when every queued request is bucket-throttled
        (they wait for refills — a metered class never blocks another
        class's admission)."""
        for qi in self._admission_order():
            if self._bucket_ok(self.queue[qi]):
                return qi
        return None

    def _effective_prompt(self, req: Request) -> np.ndarray:
        """The rows a (re-)admission must prefill: the original prompt
        plus any tokens generated before a preemption."""
        prompt = np.asarray(req.prompt, np.int32)
        if req.generated:
            prompt = np.concatenate(
                [prompt, np.asarray(req.generated, np.int32)])
        return prompt

    @staticmethod
    def _effective_len(req: Request) -> int:
        """len(_effective_prompt(req)) without materializing it — the
        admission-headroom and chunk-accounting paths only need lengths."""
        return len(req.prompt) + len(req.generated)

    def _draft_history(self, req: Request) -> np.ndarray:
        """The history the draft source sees each tick. Drafters that
        declare a ``window`` (n-gram lookup, sliding-window model draft)
        get only the trailing window — O(window) host work per tick, the
        bound that lets ``autotune.NGRAM_DRAFT_S`` price a draft token as
        a context-length-independent constant. Windowless drafters (the
        scripted test oracle locates itself by absolute position) get the
        full history."""
        window = getattr(self.draft, "window", None)
        if window is None:
            return self._effective_prompt(req)
        gen = req.generated
        if len(gen) >= window:
            return np.asarray(gen[-window:], np.int32)
        head = req.prompt[max(0, len(req.prompt) - (window - len(gen))):]
        if not gen:
            return np.asarray(head, np.int32)
        return np.concatenate([np.asarray(head, np.int32),
                               np.asarray(gen, np.int32)])

    def context_lengths(self) -> np.ndarray:
        """Per-slot live KV length (prompt + generated so far), shape
        (batch,) — the vector the flash-decode kernel scalar-prefetches."""
        return np.asarray(T.cache_lengths(self.caches))

    def _record(self, i: int, req: Request, tok: int) -> bool:
        """Append ``tok``; finish + free the slot on EOS/max_new.

        ``last_tok`` needs no reset here: tick's rebuild parks finished and
        empty slots at 0, and a slot freed during admission already was 0
        (the invariant: free slots always read 0).
        """
        req.generated.append(tok)
        if len(req.generated) == 1 and req.rid not in self.first_token_tick:
            self.first_token_tick[req.rid] = self.ticks
        if tok == self.scfg.eos_id or len(req.generated) >= req.max_new:
            req.done = True
            self.finished[req.rid] = req.generated
            self.finish_tick[req.rid] = self.ticks
            self.outcome[req.rid] = "done"
            self.telemetry.emit(self.ticks, "finish", rid=req.rid,
                                rclass=req.rclass, outcome="done",
                                n_tokens=len(req.generated))
            self.free_slot(i)
            return True
        return False

    def free_slot(self, i: int) -> None:
        """Release slot ``i``: zero its per-slot write position (flash
        decode stops streaming the dead context) and, when paged, return
        its pages to the pool and null out its page table row — the freed
        slot's drifting writes land in the null page, never in a page the
        pool may immediately re-assign."""
        self.slots[i] = None
        self._prefilling.pop(i, None)
        self._prefill_wait.pop(i, None)
        self._slot_seq.pop(i, None)
        self._chain.pop(i, None)
        if self.pool is not None:
            # Refcounted: only pages whose last holder left are freed —
            # pages the prefix index (or a co-sharing slot) still holds
            # stay resident, so ``page_free`` sizes keep reconciling with
            # the allocator's ``pages_freed``.
            freed = self.pool.free_slot(i)
            if freed:
                self.telemetry.emit(self.ticks, "page_free", slot=i,
                                    n=len(freed))
            self.caches = [
                dict(c, index=c["index"].at[:, i].set(0),
                     pages=c["pages"].at[:, i].set(0))
                for c in self.caches
            ]
        else:
            self.caches = [
                dict(c, index=c["index"].at[:, i].set(0))
                for c in self.caches
            ]

    def _imminent_page_need(self) -> int:
        """Pages committed slots will take this tick: decode growth for
        decode-active slots, the *next chunk* for mid-prefill slots.
        Admission must leave this headroom: a new request that grabs the
        pool's last page and strands an already-admitted slot turns a
        clean hold into a preemption."""
        ps, max_len = self.scfg.page_size, self.scfg.max_len
        total = 0
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            have = len(self.pool.slot_pages.get(i, ()))
            if i in self._prefilling:
                cursor = self._prefilling[i]
                true_len = self._effective_len(slot)
                total += paged_mod.chunk_page_need(
                    cursor, min(self.chunk, true_len - cursor), have, ps,
                    max_len)
            else:
                total += max(0, self._pages_through_tick(slot) - have)
        return total

    def _admit(self):
        self._refill_buckets()
        for i, slot in enumerate(self.slots):
            if slot is not None:
                continue
            while self.queue:
                qi = self._next_admission()
                if qi is None:
                    return            # all queued classes bucket-throttled
                req = self.queue[qi]
                if self.pool is not None:
                    # Chunked admission needs only the length (tokens are
                    # materialized chunk-by-chunk in _prefill_tick) and
                    # reserves only the *first chunk's* pages; a short
                    # pool rejects cleanly — the request stays queued
                    # (later requests wait too) and retries next tick,
                    # after finished slots return pages. The headroom
                    # check also covers the imminent growth of
                    # already-committed slots.
                    ps = self.scfg.page_size
                    plen = self._effective_len(req)
                    assert plen <= self.scfg.max_len, \
                        (plen, self.scfg.max_len)
                    # A request over the pool's *capacity* (whole prompt +
                    # its first decode write, speculative width included)
                    # can never finish even with every other slot
                    # preempted. Legacy: fail loudly instead of holding it
                    # forever. Graceful mode (max_preemptions set): give
                    # it a terminal outcome — force-complete a partial
                    # stream, cleanly reject a fresh one — and move on.
                    with_decode = paged_mod.pages_for(
                        min(plen + 1 + self.spec_k, self.scfg.max_len), ps)
                    if with_decode > self.pool.capacity:
                        if self.scfg.max_preemptions is not None:
                            self.queue.pop(qi)
                            if req.generated:
                                self._finish_forced(req, "capacity")
                            else:
                                self._reject(req, "capacity")
                            continue   # retry this slot with the next
                        raise paged_mod.PagePoolExhausted(
                            f"request {req.rid}: needs {with_decode} pages "
                            f"but the pool holds {self.pool.capacity}; "
                            f"raise n_pages or page_size")
                    # Prefix-cache probe: the longest cached full-page
                    # prefix of the effective prompt. A full-coverage
                    # hit (page-aligned prompt entirely cached) still
                    # re-prefills the *last* row — the sampled first
                    # token needs its logit — so the cursor is clamped
                    # to plen - 1 and the page that row lands in is
                    # split eagerly (copy-on-write) below: the batched
                    # decode step would otherwise scribble dead rows
                    # into a page other holders read.
                    hit_pages: List[int] = []
                    hit_digest = paged_mod.ROOT_DIGEST
                    n_hit = 0
                    if self.prefix is not None:
                        hit_pages, hit_digest, n_hit = self.prefix.probe(
                            self._effective_prompt(req), plen // ps,
                            now=self.ticks)
                    cursor = min(n_hit * ps, plen - 1)
                    cow_at = (n_hit - 1) if n_hit * ps > cursor else None
                    # Unified admission pricing (bugfix): reserve the
                    # *first uncached chunk* only — cursor starts at the
                    # cached rows and the hit pages count as held — so a
                    # mostly-cached long prompt is admittable on a
                    # nearly-full pool instead of being priced as if it
                    # prefilled from row 0. (+1 page when the clamped
                    # cursor forces the eager copy-on-write split.)
                    suffix_need = paged_mod.chunk_page_need(
                        cursor, min(self.chunk, plen - cursor), n_hit, ps,
                        self.scfg.max_len)
                    first = suffix_need + (1 if cow_at is not None else 0)
                    # Cached-idle prefixes are reclaimed (LRU) before
                    # this turns into a hold — an idle cache entry never
                    # blocks a live admission.
                    if not self._evict_prefixes(
                            first + self._imminent_page_need()):
                        self.telemetry.emit(
                            self.ticks, "admit_hold", rid=req.rid,
                            rclass=req.rclass, need=first,
                            free=self.pool.free_pages)
                        return        # hold: everyone waits for pages
                    self.queue.pop(qi)
                    self._charge_bucket(req)
                    self.slots[i] = req
                    if req.preempt_count:
                        req.readmitted_at = self.ticks   # storm guard
                    self._prefilling[i] = cursor
                    self._slot_seq[i] = self._admit_seq
                    self._admit_seq += 1
                    self.telemetry.emit(
                        self.ticks, "admit", rid=req.rid, slot=i,
                        rclass=req.rclass, rows=plen,
                        readmit=req.preempt_count)
                    if self.prefix is not None:
                        if n_hit:
                            self.pool.share(i, hit_pages)
                            self._append_pages(i, hit_pages, fresh=False)
                            self.telemetry.emit(
                                self.ticks, "prefix_hit", rid=req.rid,
                                slot=i, pages=n_hit, rows=cursor)
                            self.telemetry.count("prefix_hit_pages",
                                                 n_hit)
                        else:
                            self.telemetry.emit(
                                self.ticks, "prefix_miss", rid=req.rid,
                                slot=i)
                        self._chain[i] = (hit_digest, n_hit)
                    if cow_at is not None:
                        self._cow_page(i, cow_at)
                    self._append_pages(i, self.pool.alloc(i, suffix_need))
                    break             # chunks run in _prefill_tick
                prompt = self._effective_prompt(req)
                bucket = self.bucket_for(len(prompt))
                assert len(prompt) <= bucket <= self.scfg.max_len, \
                    (len(prompt), bucket, self.scfg.max_len)
                self.queue.pop(qi)
                self._charge_bucket(req)
                self.telemetry.emit(
                    self.ticks, "admit", rid=req.rid, slot=i,
                    rclass=req.rclass, rows=len(prompt),
                    readmit=req.preempt_count)
                padded = np.zeros((1, bucket), np.int32)
                padded[0, :len(prompt)] = prompt
                with self.telemetry.span("prefill_bucket", self.ticks,
                                         slot=i) as sp:
                    n0 = self.prefill_traces.get(bucket, 0)
                    tok, self.caches = self._prefill_fn(bucket)(
                        self.params, jnp.asarray(padded),
                        jnp.int32(len(prompt)), jnp.int32(i), self.caches,
                        self._emit_key(req))
                    sp.compile = self.prefill_traces.get(bucket, 0) > n0
                self.slots[i] = req
                self._slot_seq[i] = self._admit_seq
                self._admit_seq += 1
                tok = int(np.asarray(tok))
                if not self._record(i, req, tok):
                    self.last_tok = self.last_tok.at[i].set(tok)
                break

    def _prefill_order(self) -> List[int]:
        """Mid-prefill slots in shortest-remaining-first order with aging
        (admission sequence breaks ties). Finishing the nearest-done
        prompt first is classic SRPT: it minimizes mean time-to-first-
        token under mixed prompt lengths. Pure SRPT starves: under a
        ``prefill_chunks_per_tick`` budget a long prompt would wait out
        every shorter arrival forever, so each tick a slot spends waiting
        ages it by one chunk of effective remaining work — a prompt with
        R chunks left runs after at most ~R ticks of being outranked.
        The order decides who runs at all under a budget, and who gets
        pages first when the pool is short; with neither constraint every
        slot still advances one chunk per tick, so throughput is
        unchanged."""
        def key(i):
            remaining = -(-(self._effective_len(self.slots[i])
                            - self._prefilling[i]) // self.chunk)
            return (remaining - self._prefill_wait.get(i, 0),
                    self._slot_seq[i])

        return sorted(self._prefilling, key=key)

    def _prefill_tick(self) -> None:
        """Advance mid-prefill slots by one chunk each (the interleave
        unit: between chunks the decode step below keeps every active
        stream moving), shortest-remaining-first, up to the per-tick
        chunk budget (``prefill_chunks_per_tick``; None -> every slot).
        Each chunk's pages are pre-allocated right here, immediately
        before the chunk that writes them; a short pool preempts younger
        slots, or — with nothing to preempt — stalls this slot's prefill
        for the tick (decode ticks still run and eventually return
        pages)."""
        ps, max_len = self.scfg.page_size, self.scfg.max_len
        budget = self.scfg.prefill_chunks_per_tick
        if self.degraded:
            # Downshift: one chunk per tick keeps admission live while
            # decode (the SLO-bearing work) gets the tick back. Prompt
            # *content* is untouched — only when it finishes prefilling.
            budget = 1 if budget is None else min(1, budget)
        served = 0
        for i in self._prefill_order():
            if budget is not None and served >= budget:
                # Outranked this tick: age so a long prompt can't be
                # starved by a stream of shorter arrivals. Only slots a
                # *served* chunk outranked age — a stalled or preempted
                # top slot doesn't consume budget.
                if i in self._prefilling:
                    self._prefill_wait[i] = self._prefill_wait.get(i, 0) + 1
                continue
            if i not in self._prefilling:      # preempted by an earlier
                continue                       # slot's chunk this tick
            req = self.slots[i]
            cursor = self._prefilling[i]
            prompt = self._effective_prompt(req)
            true_len = len(prompt)
            n = min(self.chunk, true_len - cursor)
            need = paged_mod.chunk_page_need(
                cursor, n, len(self.pool.slot_pages.get(i, ())), ps,
                max_len)
            if need:
                if not self._preempt_for(need, protect={i}):
                    continue                   # stalled, retry next tick
                self._append_pages(i, self.pool.alloc(i, need))
            # Write barrier: the chunk executable writes its full padded
            # width [cursor, cursor + chunk) — split any shared page in
            # reach first (no-op in steady state; see _cow_range).
            self._cow_range(i, cursor, cursor + self.chunk)
            served += 1
            self._prefill_wait.pop(i, None)    # served: aging resets
            chunk_toks = np.zeros((1, self.chunk), np.int32)
            chunk_toks[0, :n] = prompt[cursor:cursor + n]
            end = cursor + n
            # Padded final-chunk rows sit at/past true_len: `end` resets
            # the write position so they are never attended, and the
            # sampled logit row is the prompt's true last token.
            last_in = (true_len - 1 - cursor) if end == true_len else n - 1
            tel = self.telemetry
            tel.emit(self.ticks, "prefill_chunk", rid=req.rid, slot=i,
                     start=cursor, rows=n)
            with tel.span("prefill_chunk", self.ticks, slot=i) as sp:
                n0 = self.prefill_traces.get(self.chunk, 0)
                tok, self.caches = self._chunk_fn(
                    self.params, jnp.asarray(chunk_toks), jnp.int32(cursor),
                    jnp.int32(end), jnp.int32(last_in), jnp.int32(i),
                    self.caches, self._emit_key(req))
                sp.compile = self.prefill_traces.get(self.chunk, 0) > n0
            # Publish the prefix pages this chunk completed: every row
            # below ``end`` went through the (deterministic) chunk
            # executable, so equal token prefixes yield equal page
            # contents and a future admission can share them.
            self._publish_rows(i, req, end)
            if end < true_len:
                self._prefilling[i] = end
                continue
            del self._prefilling[i]            # prefill complete
            tok = int(np.asarray(tok))
            if not self._record(i, req, tok):
                self.last_tok = self.last_tok.at[i].set(tok)

    def _update_pressure(self) -> None:
        """Load-shedding downshift latch (``ServeConfig.degrade``): the
        pressure signal (pool occupancy vs queue depth,
        ``core.autotune.serve_pressure``) drives a hysteresis band
        (``choose_degradation``) — at/above ``pressure_high`` the engine
        enters degraded mode (speculation off, prefill chunk budget
        tightened to 1), and it stays degraded until pressure falls
        to/below ``pressure_low``. Both downshifts are stream-transparent
        (spec == plain is bit-identical; the chunk budget only re-orders
        *when* prompts finish prefilling), so degraded ticks emit exactly
        the tokens clean ticks would."""
        if not self.scfg.degrade:
            return
        from repro.core import autotune
        occ = (self.pool.pages_in_use / max(1, self.pool.capacity)
               if self.pool is not None else
               sum(s is not None for s in self.slots) / self.scfg.batch)
        self.last_pressure = autotune.serve_pressure(
            occ, len(self.queue), self.scfg.batch)
        was = self.degraded
        self.degraded = autotune.choose_degradation(
            self.last_pressure, was,
            self.scfg.pressure_high, self.scfg.pressure_low)
        if self.degraded:
            # Aggregate-only (no ring event): one count per degraded tick
            # would flood the ring; the enter/exit *transitions* are the
            # events worth a timeline mark.
            self.telemetry.count("degraded_tick")
            if not was:
                self.telemetry.emit(self.ticks, "degrade_enter",
                                    pressure=self.last_pressure)
        elif was:
            self.telemetry.emit(self.ticks, "degrade_exit",
                                pressure=self.last_pressure)

    def _spec_width(self) -> int:
        """Draft width for this tick. ``k_live`` normally; 0 while the
        degradation ladder has speculation shed; and — the probe clock —
        a single k=1 trial every ``spec_probe_every`` plain ticks while
        the adaptive disable regime (``k_live == 0``) holds. The trial
        tick's accept stats feed the same ``_maybe_adapt_k`` window as
        normal verify ticks, so a recovered accept rate re-opens
        speculation instead of the disable regime being terminal."""
        if not self.spec_k:
            return 0
        if self.degraded:
            return 0
        if self.k_live:
            return self.k_live
        if self.scfg.spec_probe_every is None:
            return 0
        self._probe_wait += 1
        if self._probe_wait < self.scfg.spec_probe_every:
            return 0
        self._probe_wait = 0
        self.telemetry.emit(self.ticks, "probe_tick")
        return 1

    def tick(self) -> int:
        """Admit, advance prefill chunks, one decode step — or one
        speculative draft/verify step (``spec_k > 0``) — for all
        decode-active slots; returns #slots making progress.

        The whole tick runs under a wall-clock span (plus per-phase
        spans inside): purely host-observed timing — no device syncs or
        transfers are added, so the traced tick does exactly the work an
        untraced tick does."""
        tel = self.telemetry
        t0 = tel.clock()
        self.ticks += 1
        self._update_pressure()
        with tel.span("admit", self.ticks):
            self._admit()
        with tel.span("prefill", self.ticks):
            self._prefill_tick()
            self._ensure_decode_pages()
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and i not in self._prefilling]
        if not active:
            tel.tick_done(self.ticks, t0)
            return len(self._prefilling)
        n = len(active) + len(self._prefilling)
        k = self._spec_width()
        if k:
            self._spec_tick(active, k)
            self._maybe_adapt_k()
        else:
            self._decode_tick(active)
        self._reset_prefill_positions()
        tel.tick_done(self.ticks, t0)
        return n

    def _maybe_adapt_k(self) -> None:
        """Runtime feedback into the spec cost model: every
        ``spec_adapt_every`` verify ticks, re-choose the live draft
        width from the window's measured accept rate
        (``serve.spec.rechoose_k`` -> ``core.autotune.choose_spec_k``).
        A collapsing accept rate prices speculation below plain decode
        and drives ``k_live`` to 0 — the disable regime: the workload
        has shown drafts don't land, so the verify width is pure
        overhead. Terminal by default; with ``spec_probe_every`` set,
        periodic k=1 trial ticks (``_spec_width``) keep feeding this
        window so a recovered accept rate re-opens speculation. The
        verify executable (width spec_k + 1) stays traced either way."""
        every = self.scfg.spec_adapt_every
        if every is None:
            return
        self._adapt_ticks += 1
        if self._adapt_ticks < every:
            return
        rate = (self._adapt_accepted / self._adapt_proposed
                if self._adapt_proposed else 0.0)
        self.k_live, _ = spec_mod.rechoose_k(
            self.cfg, self.scfg.page_size,
            [max(1, l) for l in self.context_lengths()], rate, self.spec_k,
            constants=self.constants)
        self._adapt_ticks = 0
        self._adapt_proposed = 0
        self._adapt_accepted = 0

    def _decode_tick(self, active: List[int]) -> None:
        """One plain batched decode step: one token per active slot."""
        tel = self.telemetry
        # Host-side context accounting for the drift gate (cheap ints —
        # context_lengths() would sync the device every tick).
        tel.count("decode_slot_ticks", len(active))
        tel.count("decode_context_rows",
                  sum(self._effective_len(self.slots[i]) for i in active))
        rids, ts = self._rid_ts(active)
        with tel.span("decode", self.ticks) as sp:
            n0 = self.decode_traces
            nxt, self.caches = self._step(self.params, self.last_tok,
                                          self.caches, rids, ts)
            nxt_host = np.asarray(nxt).copy()
            sp.compile = self.decode_traces > n0
        active_set = set(active)
        for i in range(self.scfg.batch):
            if i in active_set:
                if not self._record(i, self.slots[i], int(nxt_host[i])):
                    continue
            # Freed or empty slot: park the fed-back token at 0 so stale
            # output can't alias eos_id (and decodes stay deterministic).
            nxt_host[i] = 0
        self.last_tok = jnp.asarray(nxt_host, jnp.int32)

    def _spec_tick(self, active: List[int],
                   k: Optional[int] = None) -> None:
        """One draft/verify step (``serve.spec``): up to ``spec_k``
        drafted tokens per active slot are scored together with the
        pending token in the single verify executable, and the longest
        accepted prefix plus the corrected bonus token is recorded — at
        least one token per slot per tick, so a zero-accept tick is
        exactly a plain decode tick.

        Rollback invariant: the verify advanced *every* slot's write
        position by ``spec_k + 1`` and scattered that many K/V rows
        through each slot's table. The rows for [pending, accepted
        drafts] are precisely the rows a plain engine would have written;
        the host rolls each slot's write position back to its true live
        length, leaving rejected rows as dead weight in owned pages
        (overwritten by the next tick's write at the same positions) or
        in the null page (positions past the table's reach). Slot state
        after the tick is therefore bit-identical to a plain engine that
        emitted the same tokens."""
        k = self.k_live if k is None else k
        width = self.spec_k + 1
        tel = self.telemetry
        tel.count("verify_slot_ticks", len(active))
        tel.count("verify_context_rows",
                  sum(self._effective_len(self.slots[i]) for i in active))
        tokens = np.zeros((self.scfg.batch, width), np.int32)
        tokens[:, 0] = np.asarray(self.last_tok)
        base_len: Dict[int, int] = {}
        n_prop: Dict[int, int] = {}
        with tel.span("draft", self.ticks):
            for i in active:
                req = self.slots[i]
                # Write position before the tick (host, no device sync).
                base_len[i] = self._effective_len(req) - 1
                # Draft at the *live* width (adaptive: <= spec_k); the
                # verify executable keeps its fixed spec_k + 1 shape.
                prop = np.asarray(
                    self.draft.propose(self._draft_history(req), k),
                    np.int32).ravel()[:k]
                n_prop[i] = len(prop)
                tokens[i, 1:1 + len(prop)] = np.clip(prop, 0,
                                                     self.cfg.vocab - 1)
        rids, t0s = self._rid_ts(active)
        with tel.span("spec_verify", self.ticks) as sp:
            n0 = self.verify_traces
            picks, self.caches = self._verify_fn(
                self.params, jnp.asarray(tokens), self.caches, rids, t0s)
            picks = np.asarray(picks)
            sp.compile = self.verify_traces > n0
        last = np.zeros((self.scfg.batch,), np.int32)
        cols: List[int] = []
        vals: List[int] = []
        for i in active:
            req = self.slots[i]
            # Score only what the drafter actually proposed: a zero-padded
            # undrafted position that happened to match the target would
            # otherwise inflate the accept stats (the gated accept-rate
            # cell and any measured-accept feedback into choose_spec_k).
            accepted, emitted = spec_mod.longest_accept(
                tokens[i, 1:1 + n_prop[i]], picks[i, :n_prop[i] + 1])
            self._adapt_proposed += n_prop[i]
            self._adapt_accepted += accepted
            done, n_rec = False, 0
            for tok in emitted:
                n_rec += 1
                if self._record(i, req, int(tok)):
                    done = True          # EOS or max_new: rest discarded
                    break
            # One spec_verify event per (slot, tick): its payload carries
            # the accept accounting (the spec_* counters are aggregates
            # over these events).
            tel.emit(self.ticks, "spec_verify", rid=req.rid, slot=i,
                     proposed=n_prop[i], accepted=accepted, emitted=n_rec)
            if not done:
                # Live rows gained: the pending token plus n_rec - 1
                # accepted drafts (the last emitted token is the unwritten
                # bonus/divergence token, fed back as last_tok).
                cols.append(i)
                vals.append(base_len[i] + n_rec)
                last[i] = emitted[n_rec - 1]
        if cols:
            cj = jnp.asarray(cols, jnp.int32)
            vj = jnp.asarray(vals, jnp.int32)
            self.caches = [dict(c, index=c["index"].at[:, cj].set(vj))
                           for c in self.caches]
        # Freed slots were zeroed by free_slot (after the verify, so its
        # donation-rebound caches are what got zeroed); mid-prefill slots
        # reset in _reset_prefill_positions; empty slots drift through
        # the null page exactly like a plain tick, just k+1 wide.
        self.last_tok = jnp.asarray(last, jnp.int32)

    def _reset_prefill_positions(self) -> None:
        """The batched decode/verify step advanced every slot's write
        position and wrote garbage K/V rows for mid-prefill slots (from
        the cursor — the next chunks overwrite them, or the null page
        absorbed them). Reset their positions so the next chunk resumes
        correctly."""
        if not self._prefilling:
            return
        items = sorted(self._prefilling.items())
        cols = jnp.asarray([i for i, _ in items], jnp.int32)
        vals = jnp.asarray([v for _, v in items], jnp.int32)
        self.caches = [dict(c, index=c["index"].at[:, cols].set(vals))
                       for c in self.caches]

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            n = self.tick()
            if n == 0 and not self.queue:
                break
        return self.finished
