"""Serving: prefill + decode steps and a batched continuous-batching engine.

``make_serve_step`` builds the jitted one-token decode step the dry-run
lowers for the ``decode_32k`` / ``long_500k`` cells: one new token against a
KV/SSM cache of the cell's sequence length, caches donated in-place.

``ServingEngine`` is the decode fast path around it (see README.md here):

  * **Bucketed, jitted prefill** — prompts pad right to power-of-two
    buckets, so each bucket traces and compiles exactly once instead of
    once per distinct prompt length. The padded K/V rows are never
    attended (per-slot write positions are reset to the true length) and
    are overwritten as decode advances.
  * **Fused slot install** — the row caches produced by prefill scatter
    into the engine's batch caches inside the same jitted executable
    (one ``dynamic_update_slice`` per leaf, caches donated), not as a
    per-leaf host loop.
  * **Donated decode** — ``tick`` threads the engine caches through the
    decode step with buffer donation, so the cache never exists twice.
  * **Per-slot lengths** — caches carry one write position per slot;
    with ``use_flash`` the flash-decode kernel scalar-prefetches them and
    streams only each slot's live K/V blocks (O(context), not O(max_len)).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    batch: int
    temperature: float = 0.0     # 0 -> greedy
    eos_id: int = 1
    seed: int = 0                # sampling PRNG (temperature > 0)
    min_bucket: int = 8          # smallest prefill bucket (power of two)


def prefill(params, cfg: T.ModelConfig, tokens, caches,
            frontend_embeds=None):
    """Run the prompt through the model, filling the caches."""
    logits, caches, _ = T.forward(params, cfg, tokens, caches=caches,
                                  frontend_embeds=frontend_embeds)
    return logits[:, -1], caches


def decode_step(params, cfg: T.ModelConfig, last_tokens, caches,
                frontend_embeds=None):
    """One decode step: (b,) token ids -> (b,) next ids + new caches."""
    logits, caches, _ = T.forward(params, cfg, last_tokens[:, None],
                                  caches=caches,
                                  frontend_embeds=frontend_embeds)
    return logits[:, -1], caches


def sampler(temperature: float) -> Callable:
    """logits (..., vocab) -> token ids; greedy at temperature 0."""
    if temperature == 0.0:
        return lambda logits, key: jnp.argmax(logits, -1).astype(jnp.int32)

    def sample(logits, key):
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)

    return sample


def make_serve_step(cfg: T.ModelConfig, donate: bool = True,
                    temperature: float = 0.0) -> Callable:
    """Jitted decode step (the dry-run's serve_step), caches donated."""
    pick = sampler(temperature)

    def step(params, last_tokens, caches, frontend_embeds=None, key=None):
        logits, caches = decode_step(params, cfg, last_tokens, caches,
                                     frontend_embeds=frontend_embeds)
        return pick(logits, key), caches

    return jax.jit(step, donate_argnums=(2,) if donate else ())


def greedy_generate(params, cfg: T.ModelConfig, prompt, max_new: int,
                    max_len: Optional[int] = None, frontend_embeds=None):
    """Reference generation loop (tests compare engine output to this).

    The decode step donates its caches: each iteration rebinds ``caches``
    to the step's output, so the donated buffer is never read again.
    """
    b, s = prompt.shape
    max_len = max_len or (s + max_new)
    caches = T.init_caches(cfg, b, max_len)
    logits, caches = prefill(params, cfg, prompt, caches,
                             frontend_embeds=frontend_embeds)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    step = make_serve_step(cfg, donate=True)
    for _ in range(max_new - 1):
        tok, caches = step(params, tok, caches,
                           frontend_embeds=frontend_embeds)
        out.append(tok)
    return jnp.stack(out, axis=1)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch.

    Requests join free slots as they arrive; each engine tick decodes one
    token for every active slot. Finished slots free immediately and their
    ``last_tok`` entry resets to 0 so a stale token can never collide with
    ``eos_id`` on a later tick.
    """

    def __init__(self, params, cfg: T.ModelConfig, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        self.caches = T.init_caches(cfg, serve_cfg.batch, serve_cfg.max_len,
                                    per_slot_index=True)
        self.slots: List[Optional[Request]] = [None] * serve_cfg.batch
        self.queue: List[Request] = []
        self.last_tok = jnp.zeros((serve_cfg.batch,), jnp.int32)
        self.finished: Dict[int, List[int]] = {}
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        # Bucketing pads the prompt on the right; that only composes with
        # attention layers (masked K/V). SSM/hybrid stacks carry recurrent
        # state through every position, so they prefill at exact length
        # (still jitted + fused — just one executable per distinct length).
        self._bucketed = all(k in ("attn", "cross") for k in cfg.pattern) \
            and cfg.encoder is None and not cfg.n_frontend_tokens
        self._prefill_fns: Dict[int, Callable] = {}
        self.prefill_traces: Dict[int, int] = {}
        self.decode_traces = 0
        self._step = self._make_decode_step()

    # -- jitted executables ---------------------------------------------------

    def _make_decode_step(self) -> Callable:
        pick = sampler(self.scfg.temperature)
        cfg = self.cfg

        def step(params, last_tokens, caches, key):
            self.decode_traces += 1          # runs at trace time only
            logits, caches = decode_step(params, cfg, last_tokens, caches)
            return pick(logits, key), caches

        return jax.jit(step, donate_argnums=(2,))

    def bucket_for(self, prompt_len: int) -> int:
        if not self._bucketed:
            return prompt_len
        b = self.scfg.min_bucket
        while b < prompt_len:
            b *= 2
        return min(b, self.scfg.max_len)

    def _prefill_fn(self, bucket: int) -> Callable:
        """One jitted prefill-install-sample executable per bucket."""
        fn = self._prefill_fns.get(bucket)
        if fn is not None:
            return fn
        cfg, scfg = self.cfg, self.scfg
        pick = sampler(scfg.temperature)

        def prefill_into_slot(params, tokens, true_len, slot, caches, key):
            # tokens: (1, bucket) right-padded prompt.
            self.prefill_traces[bucket] = \
                self.prefill_traces.get(bucket, 0) + 1   # trace-time only
            row = T.init_caches(cfg, 1, scfg.max_len, per_slot_index=True)
            logits, row, _ = T.forward(params, cfg, tokens, caches=row)
            last = jax.lax.dynamic_index_in_dim(logits, true_len - 1,
                                                axis=1, keepdims=False)
            # Padded K/V rows sit at positions >= true_len: resetting the
            # per-slot write position masks them out of every future step
            # and decode overwrites them in place.
            row = T.set_cache_lengths(row, true_len)

            def install(f, r):
                return jax.lax.dynamic_update_slice_in_dim(
                    f, r.astype(f.dtype), slot, axis=1)

            caches = [jax.tree.map(install, f, r)
                      for f, r in zip(caches, row)]
            return pick(last[0], key), caches

        fn = jax.jit(prefill_into_slot, donate_argnums=(4,))
        self._prefill_fns[bucket] = fn
        return fn

    # -- request lifecycle ----------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def context_lengths(self) -> np.ndarray:
        """Per-slot live KV length (prompt + generated so far), shape
        (batch,) — the vector the flash-decode kernel scalar-prefetches."""
        return np.asarray(T.cache_lengths(self.caches))

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _record(self, i: int, req: Request, tok: int) -> bool:
        """Append ``tok``; finish + free the slot on EOS/max_new.

        ``last_tok`` needs no reset here: tick's rebuild parks finished and
        empty slots at 0, and a slot freed during admission already was 0
        (the invariant: free slots always read 0).
        """
        req.generated.append(tok)
        if tok == self.scfg.eos_id or len(req.generated) >= req.max_new:
            req.done = True
            self.finished[req.rid] = req.generated
            self.slots[i] = None
            # Zero the slot's per-slot write position so flash decode stops
            # streaming the dead context (lengths drift back up by one per
            # tick until the slot is re-admitted, but never to ~max_len).
            self.caches = [
                dict(c, index=c["index"].at[:, i].set(0))
                for c in self.caches
            ]
            return True
        return False

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                prompt = np.asarray(req.prompt, np.int32)
                bucket = self.bucket_for(len(prompt))
                assert len(prompt) <= bucket <= self.scfg.max_len, \
                    (len(prompt), bucket, self.scfg.max_len)
                padded = np.zeros((1, bucket), np.int32)
                padded[0, :len(prompt)] = prompt
                tok, self.caches = self._prefill_fn(bucket)(
                    self.params, jnp.asarray(padded),
                    jnp.int32(len(prompt)), jnp.int32(i), self.caches,
                    self._next_key())
                self.slots[i] = req
                tok = int(np.asarray(tok))
                if not self._record(i, req, tok):
                    self.last_tok = self.last_tok.at[i].set(tok)

    def tick(self) -> int:
        """Admit + one decode step for all active slots; returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        nxt, self.caches = self._step(self.params, self.last_tok,
                                      self.caches, self._next_key())
        nxt_host = np.asarray(nxt).copy()
        active_set = set(active)
        for i in range(self.scfg.batch):
            if i in active_set:
                if not self._record(i, self.slots[i], int(nxt_host[i])):
                    continue
            # Freed or empty slot: park the fed-back token at 0 so stale
            # output can't alias eos_id (and decodes stay deterministic).
            nxt_host[i] = 0
        self.last_tok = jnp.asarray(nxt_host, jnp.int32)
        return len(active)

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            n = self.tick()
            if n == 0 and not self.queue:
                break
        return self.finished
