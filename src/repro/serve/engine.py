"""Serving: prefill + decode steps and a batched continuous-batching engine.

``make_serve_step`` builds the jitted one-token decode step the dry-run
lowers for the ``decode_32k`` / ``long_500k`` cells: one new token against a
KV/SSM cache of the cell's sequence length, caches donated in-place.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    batch: int
    temperature: float = 0.0     # 0 -> greedy
    eos_id: int = 1


def prefill(params, cfg: T.ModelConfig, tokens, caches,
            frontend_embeds=None):
    """Run the prompt through the model, filling the caches."""
    logits, caches, _ = T.forward(params, cfg, tokens, caches=caches,
                                  frontend_embeds=frontend_embeds)
    return logits[:, -1], caches


def decode_step(params, cfg: T.ModelConfig, last_tokens, caches,
                frontend_embeds=None):
    """One decode step: (b,) token ids -> (b,) next ids + new caches."""
    logits, caches, _ = T.forward(params, cfg, last_tokens[:, None],
                                  caches=caches,
                                  frontend_embeds=frontend_embeds)
    return logits[:, -1], caches


def make_serve_step(cfg: T.ModelConfig, donate: bool = True) -> Callable:
    """Jitted greedy decode step (the dry-run's serve_step)."""

    def step(params, last_tokens, caches, frontend_embeds=None):
        logits, caches = decode_step(params, cfg, last_tokens, caches,
                                     frontend_embeds=frontend_embeds)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, caches

    return jax.jit(step, donate_argnums=(2,) if donate else ())


def greedy_generate(params, cfg: T.ModelConfig, prompt, max_new: int,
                    max_len: Optional[int] = None, frontend_embeds=None):
    """Reference generation loop (tests compare engine output to this)."""
    b, s = prompt.shape
    max_len = max_len or (s + max_new)
    caches = T.init_caches(cfg, b, max_len)
    logits, caches = prefill(params, cfg, prompt, caches,
                             frontend_embeds=frontend_embeds)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    step = make_serve_step(cfg, donate=False)
    for _ in range(max_new - 1):
        tok, caches = step(params, tok, caches,
                           frontend_embeds=frontend_embeds)
        out.append(tok)
    return jnp.stack(out, axis=1)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch.

    Requests join free slots as they arrive; each engine tick decodes one
    token for every active slot. Finished slots free immediately — the
    batched-requests serving path of deliverable (b).
    """

    def __init__(self, params, cfg: T.ModelConfig, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        self.caches = T.init_caches(cfg, serve_cfg.batch, serve_cfg.max_len,
                                    per_slot_index=True)
        self.slots: List[Optional[Request]] = [None] * serve_cfg.batch
        self.queue: List[Request] = []
        self.last_tok = jnp.zeros((serve_cfg.batch,), jnp.int32)
        self.finished: Dict[int, List[int]] = {}
        self._step = make_serve_step(cfg, donate=False)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # Per-slot prefill: single-row prompt fill at slot i.
                row = jnp.asarray(req.prompt)[None]
                row_caches = T.init_caches(self.cfg, 1, self.scfg.max_len,
                                           per_slot_index=True)
                logits, row_caches = prefill(self.params, self.cfg, row,
                                             row_caches)
                self._write_slot(i, row_caches)
                tok = int(np.asarray(jnp.argmax(logits, -1))[0])
                req.generated.append(tok)
                self.last_tok = self.last_tok.at[i].set(tok)

    def _write_slot(self, i: int, row_caches):
        # Every cache leaf is (periods, batch, ...) — including the per-slot
        # index — so one slice-update on axis 1 installs the row.
        def write(f, r):
            return jax.lax.dynamic_update_slice_in_dim(
                f, r.astype(f.dtype), i, axis=1)

        self.caches = [jax.tree.map(write, f, r)
                       for f, r in zip(self.caches, row_caches)]

    def tick(self) -> int:
        """Admit + one decode step for all active slots; returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        nxt, self.caches = self._step(self.params, self.last_tok, self.caches)
        nxt_host = np.asarray(nxt)
        for i in active:
            req = self.slots[i]
            tok = int(nxt_host[i])
            req.generated.append(tok)
            if tok == self.scfg.eos_id or len(req.generated) >= req.max_new:
                self.finished[req.rid] = req.generated
                self.slots[i] = None
        self.last_tok = jnp.asarray(nxt_host, jnp.int32)
        return len(active)

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            n = self.tick()
            if n == 0 and not self.queue:
                break
        return self.finished
