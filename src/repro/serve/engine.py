"""Serving: prefill + decode steps and a batched continuous-batching engine.

``make_serve_step`` builds the jitted one-token decode step the dry-run
lowers for the ``decode_32k`` / ``long_500k`` cells: one new token against a
KV/SSM cache of the cell's sequence length, caches donated in-place.

``ServingEngine`` is the decode fast path around it (see README.md here):

  * **Bucketed, jitted prefill** — prompts pad right to power-of-two
    buckets, so each bucket traces and compiles exactly once instead of
    once per distinct prompt length. The padded K/V rows are never
    attended (per-slot write positions are reset to the true length) and
    are overwritten as decode advances.
  * **Fused slot install** — the row caches produced by prefill scatter
    into the engine's batch caches inside the same jitted executable
    (one ``dynamic_update_slice`` per leaf, caches donated), not as a
    per-leaf host loop.
  * **Donated decode** — ``tick`` threads the engine caches through the
    decode step with buffer donation, so the cache never exists twice.
  * **Per-slot lengths** — caches carry one write position per slot;
    with ``use_flash`` the flash-decode kernel scalar-prefetches them and
    streams only each slot's live K/V blocks (O(context), not O(max_len)).
  * **Paged KV** (``ServeConfig.paged``) — slots stop reserving ``max_len``
    rows each: K/V rows live in a shared page pool (``serve.paged``) and
    each slot owns a page table. Admission allocates the prompt's pages
    (rejecting cleanly when the pool is short — the request stays queued),
    decode allocates lazily one page at a time as contexts grow, and
    freeing a slot returns its pages for immediate reuse.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.serve import paged as paged_mod


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    batch: int
    temperature: float = 0.0     # 0 -> greedy
    eos_id: int = 1
    seed: int = 0                # sampling PRNG (temperature > 0)
    min_bucket: int = 8          # smallest prefill bucket (power of two)
    paged: bool = False          # KV rows from a shared page pool
    page_size: int = 16          # KV rows per page (paged=True)
    n_pages: Optional[int] = None  # pool size incl. null page; None ->
    # the contiguous equivalent (batch * max_len / page_size + 1), i.e.
    # no savings but no exhaustion risk; size it down to reclaim HBM.


def prefill(params, cfg: T.ModelConfig, tokens, caches,
            frontend_embeds=None):
    """Run the prompt through the model, filling the caches."""
    logits, caches, _ = T.forward(params, cfg, tokens, caches=caches,
                                  frontend_embeds=frontend_embeds)
    return logits[:, -1], caches


def decode_step(params, cfg: T.ModelConfig, last_tokens, caches,
                frontend_embeds=None):
    """One decode step: (b,) token ids -> (b,) next ids + new caches."""
    logits, caches, _ = T.forward(params, cfg, last_tokens[:, None],
                                  caches=caches,
                                  frontend_embeds=frontend_embeds)
    return logits[:, -1], caches


def sampler(temperature: float) -> Callable:
    """logits (..., vocab) -> token ids; greedy at temperature 0."""
    if temperature == 0.0:
        return lambda logits, key: jnp.argmax(logits, -1).astype(jnp.int32)

    def sample(logits, key):
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)

    return sample


def make_serve_step(cfg: T.ModelConfig, donate: bool = True,
                    temperature: float = 0.0) -> Callable:
    """Jitted decode step (the dry-run's serve_step), caches donated."""
    pick = sampler(temperature)

    def step(params, last_tokens, caches, frontend_embeds=None, key=None):
        logits, caches = decode_step(params, cfg, last_tokens, caches,
                                     frontend_embeds=frontend_embeds)
        return pick(logits, key), caches

    return jax.jit(step, donate_argnums=(2,) if donate else ())


def greedy_generate(params, cfg: T.ModelConfig, prompt, max_new: int,
                    max_len: Optional[int] = None, frontend_embeds=None):
    """Reference generation loop (tests compare engine output to this).

    The decode step donates its caches: each iteration rebinds ``caches``
    to the step's output, so the donated buffer is never read again.
    """
    b, s = prompt.shape
    max_len = max_len or (s + max_new)
    caches = T.init_caches(cfg, b, max_len)
    logits, caches = prefill(params, cfg, prompt, caches,
                             frontend_embeds=frontend_embeds)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    step = make_serve_step(cfg, donate=True)
    for _ in range(max_new - 1):
        tok, caches = step(params, tok, caches,
                           frontend_embeds=frontend_embeds)
        out.append(tok)
    return jnp.stack(out, axis=1)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch.

    Requests join free slots as they arrive; each engine tick decodes one
    token for every active slot. Finished slots free immediately and their
    ``last_tok`` entry resets to 0 so a stale token can never collide with
    ``eos_id`` on a later tick.
    """

    def __init__(self, params, cfg: T.ModelConfig, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        # Bucketing pads the prompt on the right; that only composes with
        # attention layers (masked K/V). SSM/hybrid stacks carry recurrent
        # state through every position, so they prefill at exact length
        # (still jitted + fused — just one executable per distinct length).
        self._bucketed = all(k in ("attn", "cross") for k in cfg.pattern) \
            and cfg.encoder is None and not cfg.n_frontend_tokens
        if serve_cfg.paged:
            assert self._bucketed, \
                "paged KV serving requires an attention-only stack"
            assert serve_cfg.max_len % serve_cfg.page_size == 0, \
                (serve_cfg.max_len, serve_cfg.page_size)
            n_pages = serve_cfg.n_pages or (
                1 + serve_cfg.batch * serve_cfg.max_len
                // serve_cfg.page_size)
            self.pool: Optional[paged_mod.PageAllocator] = \
                paged_mod.PageAllocator(n_pages, serve_cfg.page_size)
            self.caches = T.init_paged_caches(
                cfg, serve_cfg.batch, serve_cfg.max_len,
                serve_cfg.page_size, n_pages)
        else:
            self.pool = None
            self.caches = T.init_caches(cfg, serve_cfg.batch,
                                        serve_cfg.max_len,
                                        per_slot_index=True)
        self.slots: List[Optional[Request]] = [None] * serve_cfg.batch
        self.queue: List[Request] = []
        self.last_tok = jnp.zeros((serve_cfg.batch,), jnp.int32)
        self.finished: Dict[int, List[int]] = {}
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._prefill_fns: Dict[int, Callable] = {}
        self.prefill_traces: Dict[int, int] = {}
        self.decode_traces = 0
        self.admission_rejections = 0     # pool-exhausted admission holds
        self._step = self._make_decode_step()

    # -- jitted executables ---------------------------------------------------

    def _make_decode_step(self) -> Callable:
        pick = sampler(self.scfg.temperature)
        cfg = self.cfg

        def step(params, last_tokens, caches, key):
            self.decode_traces += 1          # runs at trace time only
            logits, caches = decode_step(params, cfg, last_tokens, caches)
            return pick(logits, key), caches

        return jax.jit(step, donate_argnums=(2,))

    def bucket_for(self, prompt_len: int) -> int:
        if not self._bucketed:
            return prompt_len
        b = self.scfg.min_bucket
        while b < prompt_len:
            b *= 2
        return min(b, self.scfg.max_len)

    def _prefill_fn(self, bucket: int) -> Callable:
        """One jitted prefill-install-sample executable per bucket."""
        fn = self._prefill_fns.get(bucket)
        if fn is not None:
            return fn
        if self.pool is not None:
            fn = self._paged_prefill_fn(bucket)
            self._prefill_fns[bucket] = fn
            return fn
        cfg, scfg = self.cfg, self.scfg
        pick = sampler(scfg.temperature)

        def prefill_into_slot(params, tokens, true_len, slot, caches, key):
            # tokens: (1, bucket) right-padded prompt.
            self.prefill_traces[bucket] = \
                self.prefill_traces.get(bucket, 0) + 1   # trace-time only
            row = T.init_caches(cfg, 1, scfg.max_len, per_slot_index=True)
            logits, row, _ = T.forward(params, cfg, tokens, caches=row)
            last = jax.lax.dynamic_index_in_dim(logits, true_len - 1,
                                                axis=1, keepdims=False)
            # Padded K/V rows sit at positions >= true_len: resetting the
            # per-slot write position masks them out of every future step
            # and decode overwrites them in place.
            row = T.set_cache_lengths(row, true_len)

            def install(f, r):
                return jax.lax.dynamic_update_slice_in_dim(
                    f, r.astype(f.dtype), slot, axis=1)

            caches = [jax.tree.map(install, f, r)
                      for f, r in zip(caches, row)]
            return pick(last[0], key), caches

        fn = jax.jit(prefill_into_slot, donate_argnums=(4,))
        self._prefill_fns[bucket] = fn
        return fn

    def _paged_prefill_fn(self, bucket: int) -> Callable:
        """Paged install: prefill runs on a contiguous *row* cache (the
        model's prompt pass is unchanged), then the row's K/V scatters
        through the slot's page table into each layer's pool. Positions
        past the allocated pages walk null (0) table entries and land in
        the null page — padded bucket rows can never touch live pages."""
        cfg, scfg = self.cfg, self.scfg
        ps = scfg.page_size
        n_rows = paged_mod.pages_for(bucket, ps) * ps   # page-aligned
        pick = sampler(scfg.temperature)

        def prefill_into_slot(params, tokens, true_len, slot, caches, key):
            self.prefill_traces[bucket] = \
                self.prefill_traces.get(bucket, 0) + 1   # trace-time only
            row = T.init_caches(cfg, 1, n_rows, per_slot_index=True)
            logits, row, _ = T.forward(params, cfg, tokens, caches=row)
            last = jax.lax.dynamic_index_in_dim(logits, true_len - 1,
                                                axis=1, keepdims=False)
            pos = jnp.arange(n_rows)
            new_caches = []
            for c, r in zip(caches, row):
                table = c["pages"][0, slot]          # same for every period
                page_of = table[pos // ps]
                row_of = pos % ps
                # r["k"]: (periods, 1, n_rows, kvh, d) -> pool scatter at
                # (period, page_of[t], row_of[t]).
                kp = c["kp"].at[:, page_of, row_of].set(
                    r["k"][:, 0].astype(c["kp"].dtype))
                vp = c["vp"].at[:, page_of, row_of].set(
                    r["v"][:, 0].astype(c["vp"].dtype))
                index = c["index"].at[:, slot].set(true_len)
                new_caches.append(dict(c, kp=kp, vp=vp, index=index))
            return pick(last[0], key), new_caches

        return jax.jit(prefill_into_slot, donate_argnums=(4,))

    # -- page-table plumbing --------------------------------------------------

    def _set_page_table_row(self, slot: int, pages: List[int]) -> None:
        """Install a slot's logical->physical map in every layer cache."""
        max_pages = self.scfg.max_len // self.scfg.page_size
        table = np.zeros((max_pages,), np.int32)
        table[:len(pages)] = pages
        table = jnp.asarray(table)
        self.caches = [dict(c, pages=c["pages"].at[:, slot].set(table))
                       for c in self.caches]

    def _pages_through_tick(self, slot: Request) -> int:
        """Table entries ``slot`` must have for this tick's decode write.

        The slot's cache length, host-side (no device sync), is the prompt
        plus every decoded token except the freshly sampled one — which
        this tick writes at position ``length``. Writes at/past ``max_len``
        spill to the null page and need no backing. Both the admission
        headroom check and the lazy allocator below use this one number,
        so they can never disagree."""
        length = len(slot.prompt) + len(slot.generated) - 1
        max_pages = self.scfg.max_len // self.scfg.page_size
        return min(length // self.scfg.page_size + 1, max_pages)

    def _ensure_decode_pages(self) -> None:
        """Lazily grow each active slot's table so the next decode token's
        write position is backed by a real page (admission only reserved
        the prompt's pages). Raises ``PagePoolExhausted`` when the pool
        can't cover an already-admitted slot — size ``n_pages`` for the
        decode growth you admit (see serve/README.md)."""
        if self.pool is None:
            return
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            target = self._pages_through_tick(slot)
            while len(self.pool.slot_pages.get(i, ())) < target:
                have = len(self.pool.slot_pages.get(i, ()))
                pid = self.pool.alloc(i, 1)[0]
                self.caches = [
                    dict(c, pages=c["pages"].at[:, i, have].set(pid))
                    for c in self.caches
                ]

    # -- request lifecycle ----------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def context_lengths(self) -> np.ndarray:
        """Per-slot live KV length (prompt + generated so far), shape
        (batch,) — the vector the flash-decode kernel scalar-prefetches."""
        return np.asarray(T.cache_lengths(self.caches))

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _record(self, i: int, req: Request, tok: int) -> bool:
        """Append ``tok``; finish + free the slot on EOS/max_new.

        ``last_tok`` needs no reset here: tick's rebuild parks finished and
        empty slots at 0, and a slot freed during admission already was 0
        (the invariant: free slots always read 0).
        """
        req.generated.append(tok)
        if tok == self.scfg.eos_id or len(req.generated) >= req.max_new:
            req.done = True
            self.finished[req.rid] = req.generated
            self.free_slot(i)
            return True
        return False

    def free_slot(self, i: int) -> None:
        """Release slot ``i``: zero its per-slot write position (flash
        decode stops streaming the dead context) and, when paged, return
        its pages to the pool and null out its page table row — the freed
        slot's drifting writes land in the null page, never in a page the
        pool may immediately re-assign."""
        self.slots[i] = None
        if self.pool is not None:
            self.pool.free_slot(i)
            self.caches = [
                dict(c, index=c["index"].at[:, i].set(0),
                     pages=c["pages"].at[:, i].set(0))
                for c in self.caches
            ]
        else:
            self.caches = [
                dict(c, index=c["index"].at[:, i].set(0))
                for c in self.caches
            ]

    def _imminent_page_need(self) -> int:
        """Pages ``_ensure_decode_pages`` will take for committed slots
        this tick. Admission must leave this headroom: a new request that
        grabs the pool's last page and strands an already-admitted slot's
        boundary crossing turns a clean hold into a mid-tick crash."""
        return sum(
            max(0, self._pages_through_tick(slot)
                - len(self.pool.slot_pages.get(i, ())))
            for i, slot in enumerate(self.slots) if slot is not None)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue[0]
                prompt = np.asarray(req.prompt, np.int32)
                bucket = self.bucket_for(len(prompt))
                assert len(prompt) <= bucket <= self.scfg.max_len, \
                    (len(prompt), bucket, self.scfg.max_len)
                if self.pool is not None:
                    # Reserve the prompt's pages up front; a short pool
                    # rejects cleanly — the request stays queued (FIFO:
                    # later requests wait too) and retries next tick,
                    # after finished slots return pages. The check covers
                    # the prompt, this slot's first decode write (which
                    # lands this same tick), and the imminent growth of
                    # already-committed slots.
                    ps = self.scfg.page_size
                    need = paged_mod.pages_for(len(prompt), ps)
                    # The admission bar is prompt pages + the first decode
                    # write (which lands this same tick) — a request over
                    # the pool's *capacity* on that bar can never admit,
                    # so fail loudly instead of holding it forever.
                    with_decode = paged_mod.pages_for(
                        min(len(prompt) + 1, self.scfg.max_len), ps)
                    if with_decode > self.pool.n_pages - 1:
                        raise paged_mod.PagePoolExhausted(
                            f"request {req.rid}: needs {with_decode} pages "
                            f"but the pool holds {self.pool.n_pages - 1}; "
                            f"raise n_pages or page_size")
                    if not self.pool.can_alloc(
                            with_decode + self._imminent_page_need()):
                        self.admission_rejections += 1
                        break
                    self._set_page_table_row(i, self.pool.alloc(i, need))
                self.queue.pop(0)
                padded = np.zeros((1, bucket), np.int32)
                padded[0, :len(prompt)] = prompt
                tok, self.caches = self._prefill_fn(bucket)(
                    self.params, jnp.asarray(padded),
                    jnp.int32(len(prompt)), jnp.int32(i), self.caches,
                    self._next_key())
                self.slots[i] = req
                tok = int(np.asarray(tok))
                if not self._record(i, req, tok):
                    self.last_tok = self.last_tok.at[i].set(tok)

    def tick(self) -> int:
        """Admit + one decode step for all active slots; returns #active."""
        self._admit()
        self._ensure_decode_pages()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        nxt, self.caches = self._step(self.params, self.last_tok,
                                      self.caches, self._next_key())
        nxt_host = np.asarray(nxt).copy()
        active_set = set(active)
        for i in range(self.scfg.batch):
            if i in active_set:
                if not self._record(i, self.slots[i], int(nxt_host[i])):
                    continue
            # Freed or empty slot: park the fed-back token at 0 so stale
            # output can't alias eos_id (and decodes stay deterministic).
            nxt_host[i] = 0
        self.last_tok = jnp.asarray(nxt_host, jnp.int32)
        return len(active)

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            n = self.tick()
            if n == 0 and not self.queue:
                break
        return self.finished
