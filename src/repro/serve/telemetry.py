"""Structured observability for the serving engine (paper ethos: observe).

The source paper dissects Volta by instrumenting tight loops and reading
the clocks; this module applies the same probe-and-compare discipline to
our own serving stack. Three surfaces, one bookkeeping home:

  * **Event trace** — a ring-buffered, schema-versioned stream of typed
    tick events (``admit``, ``shed``, ``preempt``, ``degrade_enter`` /
    ``degrade_exit``, ``spec_verify`` with accept counts,
    ``prefill_chunk``, ``page_alloc`` / ``page_free``, ``probe_tick``,
    ``prefix_hit`` / ``prefix_miss`` / ``cow_copy`` / ``prefix_evict``,
    terminal outcomes) emitted from the engine's existing decision
    points. The legacy ad-hoc counters (``admission_rejections``,
    ``shed_by_class``, ``preemption_log``, spec stats) are *views over
    this trace's aggregates*, not parallel bookkeeping: the aggregate
    side of ``emit`` runs even when tracing is disabled (and even after
    ring eviction), so the counters stay exact while the ring bounds
    memory.
  * **Wall-clock spans** — ``perf_counter`` spans around the decode /
    verify / chunk executables and the host-side scheduling phases, with
    trace-vs-execute separation (the first call of each executable is
    flagged ``compile`` via the engine's trace-time counters — exact,
    not heuristic), plus a per-tick wall-time histogram (p50/p99). Spans
    measure *host-observed* time: dispatch plus whatever synchronization
    the engine already performs. No device syncs or host<->device
    transfers are added anywhere — instrumentation is purely
    observational and the traced engine's token streams are bit-identical
    to an untraced engine's (gated by tests/test_telemetry.py).
  * **Exporters** — ``chrome_trace()`` emits a Chrome-trace/Perfetto JSON
    timeline (one track per engine phase, one per slot; load it at
    ``ui.perfetto.dev`` or ``chrome://tracing``); ``metrics()`` flattens
    everything into one scalar dict for operator reports and bench cells.

``drift_report`` is the model-vs-measured gate: it compares the
``core.autotune`` cost-model predictions (``paged_decode_model``,
``prefill_chunk_model``, ``spec_decode_model``) against the measured
execute-phase spans for the same configuration — the direct on-ramp for
the ROADMAP's microbenchmark-calibrated cost models. On a CPU test
backend the ratios are far from 1 (the models price TPU HBM streams);
the gate is that they are *finite, positive, and recorded*.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

TRACE_SCHEMA_VERSION = 1

# Typed event kinds (schema v1). ``emit`` asserts membership so a typo'd
# kind fails loudly in tests instead of minting an unqueryable stream.
EVENT_KINDS = frozenset({
    "submit",         # request entered the queue
    "admit",          # request installed into a slot
    "admit_hold",     # pool-exhausted admission hold (everyone waits)
    "shed",           # terminal: clean reject (queue_full/capacity/...)
    "finish",         # terminal: done | forced:* (partial stream kept)
    "preempt",        # slot evicted back to the queue
    "degrade_enter",  # ladder: clean -> degraded transition
    "degrade_exit",   # ladder: degraded -> clean transition
    "spec_verify",    # one slot's verify outcome (proposed/accepted)
    "prefill_chunk",  # one prompt chunk written through the page table
    "page_alloc",     # pages granted to a slot
    "page_free",      # a freed slot's pages returned to the pool
    "probe_tick",     # k=1 trial tick while speculation is disabled
    "prefix_hit",     # admission mapped cached prefix pages (refcounts)
    "prefix_miss",    # admission probed the prefix index and found none
    "cow_copy",       # copy-on-write split of a shared page
    "prefix_evict",   # LRU reclaim of cached-idle prefix pages
})


class _Span:
    """Context manager recording one wall-clock span. ``compile`` is set
    by the caller from the engine's trace-time counter delta (exact
    first-call detection); it must be assigned *inside* the block."""

    __slots__ = ("_tel", "name", "tick", "slot", "compile", "_t0")

    def __init__(self, tel: "Telemetry", name: str, tick: int,
                 slot: Optional[int]):
        self._tel = tel
        self.name = name
        self.tick = tick
        self.slot = slot
        self.compile = False

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._tel._record_span(self, self._t0,
                               time.perf_counter() - self._t0)


class _NullSpan:
    """Shared no-op span for disabled telemetry (zero per-call garbage)."""

    __slots__ = ("compile",)

    def __init__(self):
        self.compile = False

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Telemetry:
    """One engine's observability state: event ring + aggregates + spans.

    Aggregates (``counters``, ``shed_by_class``, ``preemption_log``) are
    updated by every ``emit``/``count`` call regardless of ``enabled`` —
    they are the backing store for the engine's legacy counter views and
    must stay exact. The *ring buffers* (events, spans, tick times) and
    the ``perf_counter`` reads are what ``enabled`` gates: a disabled
    engine pays only dict arithmetic.
    """

    def __init__(self, enabled: bool = True, capacity: int = 4096):
        assert capacity >= 1, capacity
        self.enabled = enabled
        self.capacity = capacity
        self.schema_version = TRACE_SCHEMA_VERSION
        # Ring entries: (t_rel_s, tick, kind, payload_dict).
        self.events: deque = deque(maxlen=capacity)
        # Ring entries: (name, t0_rel_s, dur_s, tick, slot, compile).
        self.spans: deque = deque(maxlen=capacity)
        # Ring entries: (tick, dur_s) — percentile window.
        self.tick_times: deque = deque(maxlen=capacity)
        self.dropped_events = 0          # ring evictions (aggregates exact)
        # Aggregates (exact over the whole run, never evicted):
        self.counters: Dict[str, Any] = {}
        self.shed_by_class: Dict[str, int] = {}
        self.preemption_log: List[Tuple[int, str, int]] = []
        # name -> [n, total_s, max_s, compile_n, compile_s]
        self._span_agg: Dict[str, List] = {}
        self._tick_n = 0
        self._tick_total_s = 0.0
        self._epoch = time.perf_counter()

    # -- recording ------------------------------------------------------------

    def count(self, key: str, n: int = 1) -> None:
        """Bump an aggregate counter with no ring event (high-frequency
        accounting like per-tick context-row sums)."""
        self.counters[key] = self.counters.get(key, 0) + n

    def emit(self, tick: int, kind: str, **payload) -> None:
        """Record one typed event. Aggregates always update; the ring
        entry is appended only when tracing is enabled."""
        assert kind in EVENT_KINDS, kind
        # .item(): numpy scalars (token counts, lengths) must not leak
        # into the aggregates or the ring — chrome_trace()/metrics()
        # json-serialize these as-is.
        payload = {k: (v.item() if hasattr(v, "item") else v)
                   for k, v in payload.items()}
        c = self.counters
        c[kind] = c.get(kind, 0) + 1
        if kind == "shed":
            rc = payload["rclass"]
            self.shed_by_class[rc] = self.shed_by_class.get(rc, 0) + 1
        elif kind == "preempt":
            self.preemption_log.append(
                (payload["rid"], payload["rclass"], payload["n_generated"]))
        elif kind == "spec_verify":
            c["spec_proposed"] = c.get("spec_proposed", 0) \
                + payload["proposed"]
            c["spec_accepted"] = c.get("spec_accepted", 0) \
                + payload["accepted"]
            c["spec_emitted"] = c.get("spec_emitted", 0) \
                + payload["emitted"]
        if not self.enabled:
            return
        if len(self.events) == self.capacity:
            self.dropped_events += 1
        self.events.append(
            (time.perf_counter() - self._epoch, tick, kind, payload))

    def span(self, name: str, tick: int,
             slot: Optional[int] = None):
        """Wall-clock span context manager; no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, tick, slot)

    def _record_span(self, sp: _Span, t0: float, dur: float) -> None:
        agg = self._span_agg.get(sp.name)
        if agg is None:
            agg = self._span_agg[sp.name] = [0, 0.0, 0.0, 0, 0.0]
        agg[0] += 1
        agg[1] += dur
        agg[2] = max(agg[2], dur)
        if sp.compile:
            agg[3] += 1
            agg[4] += dur
        self.spans.append((sp.name, t0 - self._epoch, dur, sp.tick,
                           sp.slot, sp.compile))

    def clock(self) -> float:
        """Tick-start timestamp (0.0 when disabled — tick_done ignores)."""
        return time.perf_counter() if self.enabled else 0.0

    def tick_done(self, tick: int, t0: float) -> None:
        """Close the whole-tick wall span opened by ``clock()``."""
        if not self.enabled:
            return
        dur = time.perf_counter() - t0
        self._tick_n += 1
        self._tick_total_s += dur
        self.tick_times.append((tick, dur))

    def reset(self) -> None:
        """Drop everything — rings, aggregates, epoch. The bench warm-up
        boundary: compile spans and warm-up events must not pollute the
        measured cells."""
        self.events.clear()
        self.spans.clear()
        self.tick_times.clear()
        self.dropped_events = 0
        self.counters.clear()
        self.shed_by_class.clear()
        self.preemption_log.clear()
        self._span_agg.clear()
        self._tick_n = 0
        self._tick_total_s = 0.0
        self._epoch = time.perf_counter()

    # -- queries --------------------------------------------------------------

    def events_of(self, kind: Optional[str] = None) -> List[Tuple]:
        """Ring events, optionally filtered by kind (recent window only —
        use the aggregates for exact whole-run totals)."""
        if kind is None:
            return list(self.events)
        assert kind in EVENT_KINDS, kind
        return [e for e in self.events if e[2] == kind]

    def tick_stats(self) -> Dict[str, float]:
        """Whole-tick wall-time histogram. ``mean_s``/``total_s`` are
        exact over the run; percentiles cover the ring window."""
        if not self._tick_n:
            return {"n": 0, "total_s": 0.0, "mean_s": 0.0,
                    "p50_s": 0.0, "p99_s": 0.0, "max_s": 0.0}
        durs = [d for _, d in self.tick_times]
        return {"n": self._tick_n,
                "total_s": self._tick_total_s,
                "mean_s": self._tick_total_s / self._tick_n,
                "p50_s": float(np.percentile(durs, 50)),
                "p99_s": float(np.percentile(durs, 99)),
                "max_s": float(max(durs))}

    def span_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-span-name aggregates with trace-vs-execute separation:
        ``compile_*`` isolates first-call (tracing+compile) cost,
        ``execute_mean_s`` is the steady-state mean the cost models are
        judged against."""
        out = {}
        for name, (n, total, mx, cn, cs) in self._span_agg.items():
            en = n - cn
            out[name] = {
                "n": n, "total_s": total, "mean_s": total / n, "max_s": mx,
                "compile_n": cn, "compile_s": cs, "execute_n": en,
                "execute_mean_s": (total - cs) / en if en else 0.0,
            }
        return out

    # -- exporters ------------------------------------------------------------

    def metrics(self) -> Dict[str, Any]:
        """Everything as one flat scalar dict (operator reports, bench
        cells). Keys: ``count_*`` aggregates, ``tick_*`` histogram,
        ``span_<name>_*`` per-span stats."""
        out: Dict[str, Any] = {
            "schema_version": self.schema_version,
            "enabled": self.enabled,
            "events_in_ring": len(self.events),
            "events_dropped": self.dropped_events,
        }
        for k in sorted(self.counters):
            out[f"count_{k}"] = self.counters[k]
        for k, v in self.tick_stats().items():
            out[f"tick_{k}"] = v
        for name, st in sorted(self.span_stats().items()):
            out[f"span_{name}_n"] = st["n"]
            out[f"span_{name}_mean_s"] = st["mean_s"]
            out[f"span_{name}_compile_n"] = st["compile_n"]
            out[f"span_{name}_execute_mean_s"] = st["execute_mean_s"]
        return out

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome-trace/Perfetto JSON (the ``traceEvents`` array format).

        One track (tid) per engine phase (``phase:decode``, ...) carries
        the wall-clock spans as complete events (ph="X"); per-slot tracks
        (``slot:0``, ...) carry slot-attributed spans (prefill chunks)
        and the decision events as instants (ph="i"). Counter tracks
        (ph="C") reconstruct pool occupancy, queue depth, and the live
        speculation width from the decision events, so calibration runs
        and degradation-ladder transitions read off one timeline.
        Timestamps are microseconds relative to the telemetry epoch.
        Write with ``json.dump`` and open at ui.perfetto.dev or
        chrome://tracing."""
        tev = []
        for name, t0, dur, tick, slot, comp in self.spans:
            tid = f"slot:{slot}" if slot is not None else f"phase:{name}"
            tev.append({"name": name, "ph": "X", "pid": 0, "tid": tid,
                        "ts": t0 * 1e6, "dur": dur * 1e6,
                        "args": {"tick": tick, "compile": comp}})
        # Counter tracks, integrated from the decision events in ring
        # order. The ring may have evicted the prefix of the run, so the
        # integrals are clamped at zero — the *shape* (admission waves,
        # preemption storms, k collapsing under degradation) is what the
        # timeline is for; exact totals live in the aggregates.
        pool = queue = 0
        for t, tick, kind, payload in self.events:
            slot = payload.get("slot")
            tid = f"slot:{slot}" if slot is not None else "phase:events"
            tev.append({"name": kind, "ph": "i", "s": "t", "pid": 0,
                        "tid": tid, "ts": t * 1e6,
                        "args": dict(payload, tick=tick)})
            ts = t * 1e6
            if kind in ("page_alloc", "page_free"):
                pool += payload.get("n", 0) * (1 if kind == "page_alloc"
                                               else -1)
                pool = max(0, pool)
                tev.append({"name": "pool_pages", "ph": "C", "pid": 0,
                            "ts": ts, "args": {"pages": pool}})
            elif kind in ("submit", "admit", "shed", "preempt"):
                queue += 1 if kind in ("submit", "preempt") else -1
                queue = max(0, queue)
                tev.append({"name": "queue_depth", "ph": "C", "pid": 0,
                            "ts": ts, "args": {"requests": queue}})
            elif kind == "spec_verify":
                tev.append({"name": "spec_k_live", "ph": "C", "pid": 0,
                            "ts": ts,
                            "args": {"k": payload.get("proposed", 0)}})
            elif kind == "probe_tick":
                tev.append({"name": "spec_k_live", "ph": "C", "pid": 0,
                            "ts": ts, "args": {"k": 1}})
        return {"traceEvents": tev, "displayTimeUnit": "ms",
                "otherData": {"schema_version": self.schema_version}}


# -- model-vs-measured drift gate ---------------------------------------------


def drift_report(engine, persist: bool = False) -> Dict[str, Any]:
    """Compare the autotune cost models against measured execute spans
    for this engine's own configuration (paged engines only).

    Components (present when the engine measured execute-phase spans for
    them):

      * ``decode`` — measured mean plain-decode span vs
        ``paged_decode_model(...)["paged_s"]`` at the run's mean context
        length and active-slot count (tracked host-side per tick, no
        device syncs).
      * ``prefill_chunk`` — measured mean chunk span vs
        ``prefill_chunk_model(...)["prefill_s"]`` for one chunk.
      * ``spec_verify`` — measured mean verify span vs
        ``spec_decode_model(...)["spec_tick_s"]`` at the measured accept
        rate.

    Each component carries ``measured_s``, ``modeled_s`` and ``ratio``
    (= measured/modeled, ``autotune.drift_ratio``) — modeled under the
    constant set the engine actually priced its decisions with
    (``engine.constants``) — plus ``modeled_default_s``/``ratio_default``
    under the hand-set defaults, so a calibrated run shows both how far
    the model drifted and how much calibration closed the gap. The
    report also embeds which set was active (``constants``) and the
    per-constant measured-vs-assumed rollup
    (``calibration`` = ``autotune.calibration_report``). With
    ``persist=True`` the measurements are written into the persistent
    tuning cache under the ``serve_measured:`` key namespace — the
    substrate the calibration pass reads alongside the hand-set
    constants.
    """
    from repro.core import autotune
    from repro.models import transformer as T

    assert engine.pool is not None, "drift_report needs a paged engine"
    tel = engine.telemetry
    cfg, scfg = engine.cfg, engine.scfg
    stats = tel.span_stats()
    c = tel.counters

    def mean_geom(rows_key: str, slots_key: str, n_spans: int):
        slot_ticks = c.get(slots_key, 0)
        rows = c.get(rows_key, 0)
        mean_len = max(1, int(round(rows / max(1, slot_ticks))))
        mean_slots = max(1, int(round(slot_ticks / max(1, n_spans))))
        return mean_len, mean_slots

    out: Dict[str, Any] = {"schema_version": TRACE_SCHEMA_VERSION}
    geom = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.dhead, page_size=scfg.page_size)
    const = getattr(engine, "constants", None)
    if const is None:
        const = autotune.resolve_constants(
            mesh_shape=getattr(engine, "mesh", None))

    def cell(measured, model_fn, **kw):
        """measured vs the model priced under the engine's active
        constant set (headline) and under the defaults (comparison)."""
        modeled = model_fn(constants=const, **kw)
        modeled_default = modeled if const.source == "default" \
            else model_fn(constants=autotune.DEFAULT_CONSTANTS, **kw)
        return {
            "measured_s": measured, "modeled_s": modeled,
            "ratio": autotune.drift_ratio(measured, modeled),
            "modeled_default_s": modeled_default,
            "ratio_default": autotune.drift_ratio(measured,
                                                  modeled_default)}

    dec = stats.get("decode")
    if dec and dec["execute_n"]:
        mean_len, mean_slots = mean_geom(
            "decode_context_rows", "decode_slot_ticks", dec["n"])
        out["decode"] = dict(cell(
            dec["execute_mean_s"],
            lambda **kw: autotune.paged_decode_model(
                scfg.max_len, [mean_len] * mean_slots, **geom,
                **kw)["paged_s"]),
            n_spans=dec["execute_n"], mean_context=mean_len,
            mean_slots=mean_slots)

    pc = stats.get("prefill_chunk")
    if pc and pc["execute_n"]:
        out["prefill_chunk"] = dict(cell(
            pc["execute_mean_s"],
            lambda **kw: autotune.prefill_chunk_model(
                engine.chunk, engine.chunk, **geom, **kw)["prefill_s"]),
            n_spans=pc["execute_n"], chunk=engine.chunk)

    sv = stats.get("spec_verify")
    if sv and sv["execute_n"] and engine.spec_k:
        mean_len, mean_slots = mean_geom(
            "verify_context_rows", "verify_slot_ticks", sv["n"])
        proposed = c.get("spec_proposed", 0)
        rate = c.get("spec_accepted", 0) / proposed if proposed else 0.0
        out["spec_verify"] = dict(cell(
            sv["execute_mean_s"],
            lambda **kw: autotune.spec_decode_model(
                [mean_len] * mean_slots, k=engine.spec_k,
                accept_rate=rate,
                param_bytes=T.active_param_count(cfg) * 2.0,
                **geom, **kw)["spec_tick_s"]),
            n_spans=sv["execute_n"], spec_k=engine.spec_k,
            accept_rate=rate)

    out["constants"] = {"source": const.source, "backend": const.backend,
                        "mesh": const.mesh,
                        "timestamp": const.timestamp}
    out["calibration"] = autotune.calibration_report(
        mesh_shape=getattr(engine, "mesh", None))

    if persist:
        ident = (f"{cfg.n_heads}h{cfg.n_kv_heads}kv{cfg.dhead}d"
                 f":page{scfg.page_size}:chunk{engine.chunk}")
        for comp in ("decode", "prefill_chunk", "spec_verify"):
            cell = out.get(comp)
            if cell is None:
                continue
            autotune.record_serve_measurement(f"{comp}:{ident}", {
                "time_s": cell["measured_s"],
                "modeled_s": cell["modeled_s"],
                "ratio": cell["ratio"],
                "n": cell["n_spans"],
                "source": "serve.telemetry",
            })
    return out
