from repro.serve.engine import (ServeConfig, ServingEngine, decode_step,  # noqa
                                greedy_generate, make_serve_step, prefill)
