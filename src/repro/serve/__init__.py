from repro.serve.engine import (ServeConfig, ServingEngine, decode_step,  # noqa
                                greedy_generate, make_serve_step, prefill)
from repro.serve.paged import (PageAllocator, PagePoolExhausted,  # noqa
                               pages_for)
from repro.serve.spec import (ModelDraft, NgramDraft, ScriptedDraft,  # noqa
                              longest_accept, resolve_draft)
