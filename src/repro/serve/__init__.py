from repro.serve.engine import (Request, ServeConfig, ServingEngine,  # noqa
                                SLOClass, decode_step, greedy_generate,
                                make_serve_step, prefill)
from repro.serve.faults import (Fault, FaultInjector,  # noqa
                                canonical_schedule)
from repro.serve.paged import (PageAllocator, PagePoolExhausted,  # noqa
                               pages_for)
from repro.serve.spec import (ModelDraft, NgramDraft, ScriptedDraft,  # noqa
                              longest_accept, resolve_draft)
from repro.serve.traffic import (TrafficClass, TrafficConfig,  # noqa
                                 TrafficGenerator, run_open_loop, summarize)
