"""Device-sharded KV page pools: shard_map scatter/gather over a mesh.

The paged cache treats serving HBM as virtual memory; this module is its
NUMA layer. The physical pools (``models.transformer.init_paged_caches``)
are sharded over one mesh axis with **pages as the shard unit** — global
page id ``p`` lives on device ``p // block`` at local slot ``p % block``,
the (device, local_page) pair ``serve.paged.PageAllocator`` hands out.
Slots are *not* the shard unit on purpose: a slot's page table can then
span devices, so one context can grow past any single chip's pool (the
ROADMAP's ``long_500k`` cell) and admission stays priced against the
global pool, exactly like the paper's NVLink remote-access chapter where
a GPU reaches pages resident on a peer instead of faulting.

Two shard_map primitives do all the cross-device work:

* ``scatter_pages`` — write the s new KV rows through the page table.
  Each device resolves the global page ids against its own block and
  drops writes it does not own (``mode="drop"``) — no communication at
  all: ownership is a partition, so every row lands exactly once.
* ``gather_pages`` — the page-table walk. Each device gathers the rows
  it owns into the slot-contiguous layout (zeros elsewhere) and one
  ``psum`` over the pool axis assembles the replicated contiguous view —
  the "remote page access" collective. Payload is the gathered view, not
  the pool, so it scales with live context, and because exactly one
  device contributes each row the sum is exact (no float reordering:
  the oracle's bit-identical streams survive).

The engine never sees any of this: it keeps one flat allocator and one
logical page table, and ``models.layers._paged_apply`` routes through
these helpers only when the ambient ruleset (``dist.sharding``) carries a
real mesh whose ``kv_pages`` axis is non-trivial.

Prefix caching composes unchanged: refcounts and the prefix index are
host-side state on the flat allocator, and a cache hit only installs
already-resident page ids into another slot's table. A shared page lives
on its owning device like any other; scatter/gather address pages by id,
blind to how many tables map them. Copy-on-write allocates the fresh page
wherever the allocator's least-loaded placement puts it — the copy is a
device-local pool-to-pool row move expressed through the same donated
cache update the engine already uses.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding

# Logical name of the pool's page axis (rule target: the mesh axis the
# pool shards over — "model" by default, alongside the TP weights).
POOL_RULE = "kv_pages"


def serve_ruleset(mesh, rules: Optional[dict] = None) -> sharding.Ruleset:
    """The serving engine's ruleset: TP params/activations (no FSDP — no
    per-token gather on the decode path) + the sharded page pool."""
    return sharding.Ruleset(mesh=mesh, rules=dict(rules or {}), fsdp=False)


def active_pool_mesh() -> Optional[Tuple[Any, str]]:
    """(mesh, axis) when the ambient ruleset shards the page pool.

    Requires a *real* jax Mesh (rule stubs used by the sharding unit
    tests don't run shard_map) with a non-trivial ``kv_pages`` axis;
    returns None otherwise, which keeps every single-device path — and
    therefore every existing test — byte-identical.
    """
    rs = sharding.current_ruleset()
    if rs is None or not isinstance(rs.mesh, jax.sharding.Mesh):
        return None
    target = rs._rule(POOL_RULE)
    if target is None:
        return None
    axis = target if isinstance(target, str) else tuple(target)[0]
    if int(dict(rs.mesh.shape).get(axis, 1)) <= 1:
        return None
    return rs.mesh, axis


def pool_sharding(mesh, axis: str, ndim: int, page_dim: int):
    """NamedSharding for a pool array sharded over its page dimension."""
    spec = [None] * ndim
    spec[page_dim] = axis
    return NamedSharding(mesh, P(*spec))


def shard_caches(caches, mesh, axis: str = "model"):
    """Place paged caches on the mesh: kp/vp page-sharded (dim 1 — dim 0
    is the period stack), page tables and write indices replicated."""
    repl = NamedSharding(mesh, P())
    out = []
    for c in caches:
        if "kp" in c:
            n_pages = c["kp"].shape[1]
            assert n_pages % int(dict(mesh.shape)[axis]) == 0, \
                (n_pages, dict(mesh.shape))
            sh = pool_sharding(mesh, axis, c["kp"].ndim, page_dim=1)
            out.append({"kp": jax.device_put(c["kp"], sh),
                        "vp": jax.device_put(c["vp"], sh),
                        "pages": jax.device_put(c["pages"], repl),
                        "index": jax.device_put(c["index"], repl)})
        else:
            out.append({k: jax.device_put(v, repl) for k, v in c.items()})
    return out


def scatter_pages(kp, vp, k, v, page, row, mesh, axis: str = "model"):
    """Write rows (b, s) through the global page table into the sharded
    pool: each device keeps the writes whose pages it owns, drops the
    rest. kp/vp: (n_pages, page_size, kvh, hd) page-sharded; k/v:
    (b, s, kvh, hd); page/row: (b, s) global page id / in-page row."""
    n_dev = int(dict(mesh.shape)[axis])
    block = kp.shape[0] // n_dev

    def body(kp_l, vp_l, k, v, page, row):
        d = jax.lax.axis_index(axis)
        local = page - d * block
        owned = (local >= 0) & (local < block)
        # Not-owned writes get an out-of-range local id and are dropped
        # by the scatter itself — ownership is a partition, so every row
        # is written by exactly one device and none twice.
        lp = jnp.where(owned, local, block)
        kp_l = kp_l.at[lp, row].set(k.astype(kp_l.dtype), mode="drop")
        vp_l = vp_l.at[lp, row].set(v.astype(vp_l.dtype), mode="drop")
        return kp_l, vp_l

    pool = P(axis, None, None, None)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(pool, pool, P(None, None, None, None),
                             P(None, None, None, None), P(None, None),
                             P(None, None)),
                   out_specs=(pool, pool), check_rep=False)
    return fn(kp, vp, k, v, page, row)


def gather_pages(kp, vp, pages, mesh, axis: str = "model"):
    """Page-table walk over the sharded pool: materialize the replicated
    contiguous (b, max_pages*page_size, kvh, hd) view.

    Each device resolves the global table against its block — rows it
    owns in place, zeros elsewhere — and a single psum over ``axis``
    assembles the view (exact: one contributor per row). Rows mapped
    through the null page are garbage, masked by the caller's lengths
    exactly as in the single-device walk (``serve.paged.gather_kv``).
    """
    n_dev = int(dict(mesh.shape)[axis])
    block = kp.shape[0] // n_dev
    b, max_pages = pages.shape
    ps = kp.shape[1]

    def body(kp_l, vp_l, pages):
        d = jax.lax.axis_index(axis)
        local = pages - d * block
        owned = (local >= 0) & (local < block)
        lp = jnp.where(owned, local, 0)
        m = owned[..., None, None, None]
        kc = jnp.where(m, jnp.take(kp_l, lp, axis=0), 0)
        vc = jnp.where(m, jnp.take(vp_l, lp, axis=0), 0)
        kc = jax.lax.psum(kc, axis)
        vc = jax.lax.psum(vc, axis)
        return (kc.reshape(b, max_pages * ps, *kp_l.shape[2:]),
                vc.reshape(b, max_pages * ps, *vp_l.shape[2:]))

    pool = P(axis, None, None, None)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(pool, pool, P(None, None)),
                   out_specs=(P(None, None, None, None),
                              P(None, None, None, None)),
                   check_rep=False)
    return fn(kp, vp, pages)
