"""Deterministic fault injection for the serving engine.

The robustness contract this repo's serving stack claims — every
degraded mode bit-identical on the tokens it emits, every fault with a
bounded, recovering response — is only a claim until something *forces*
the failure paths. This module is that something: a scheduled, seeded
injector that wraps a live engine and drives each failure mode on a
fixed tick schedule, so tests and the breaking-point bench exercise
pool exhaustion, accept-rate collapse, torn tuning-cache reads, and
preemption churn reproducibly (same seed, same schedule, same engine
decisions) rather than waiting for production to find them.

Faults (``FaultKind``):

  * ``POOL_SQUEEZE`` — allocate pages to a *phantom* slot id that no
    engine slot owns, shrinking the pool's free list out from under the
    scheduler (the software analogue of a co-tenant stealing HBM). The
    window end frees the phantom slot; the engine's admission holds,
    preemptions, and degradation latch are the measured response.
  * ``ACCEPT_COLLAPSE`` — wrap the engine's draft source so every
    proposed token is off by one (``(tok + 1) % vocab``): drafts stop
    landing, the measured accept rate collapses, and the spec-k
    adaptation clock must disable speculation (and, with
    ``spec_probe_every``, recover after the window ends). Emitted
    tokens are untouched — the verify step corrects every wrong draft
    by construction, which is exactly why this fault is stream-safe.
  * ``CACHE_TORN`` — truncate the autotune tuning-cache file mid-JSON
    (a torn concurrent write). ``autotune._load_tuning_cache`` must
    discard and re-measure, never crash; the window end restores the
    original bytes.
  * ``SLOT_CHURN`` — preempt one victim slot per tick through the
    engine's own victim policy: a sustained preemption storm that the
    storm guard (``preempt_cooldown``) and fairness cap
    (``max_preemptions``) must keep live and bounded.

Scheduling is in engine ticks: each ``Fault`` is a [start, stop)
window; ``FaultInjector.step(engine)`` is called once per tick (before
``engine.tick()``, as ``traffic.run_open_loop`` does) and arms/disarms
windows as the clock passes them. ``injected``/``cleared`` counters let
tests assert the fault actually fired and actually ended.
"""

from __future__ import annotations

import dataclasses
import os
from typing import List, Optional

import numpy as np

# Phantom pool tenant: PageAllocator keys slot_pages by arbitrary ids,
# so a negative id can hold pages without colliding with engine slots.
PHANTOM_SLOT = -0xFA117


class _CorruptingDraft:
    """Draft-source proxy that breaks every proposal by one token id.

    The verify executable still scores and corrects each position, so
    the emitted stream is bit-identical to the fault-free engine's —
    the fault collapses the *accept rate*, not correctness. (That
    separation is the whole point of draft/verify speculation, and this
    proxy is the test that the engine actually honors it.)"""

    def __init__(self, inner, vocab: int):
        self._inner = inner
        self._vocab = vocab
        # Windowed drafters expose `window` so the engine can bound the
        # history it materializes; forward it.
        window = getattr(inner, "window", None)
        if window is not None:
            self.window = window

    def propose(self, history, k):
        prop = np.asarray(self._inner.propose(history, k), np.int64)
        return ((prop + 1) % self._vocab).astype(np.int32)


@dataclasses.dataclass
class Fault:
    """One scheduled fault window [start, stop) in engine ticks."""

    kind: str                 # a FaultKind value
    start: int
    stop: int
    pages: int = 0            # POOL_SQUEEZE: pages to hold (0 -> all
    # free pages above a 2-page floor, re-squeezed every tick)
    min_free: int = 2         # POOL_SQUEEZE floor (pages=0 mode)
    victims_per_tick: int = 1  # SLOT_CHURN: preemptions per tick
    active: bool = False

    def __post_init__(self):
        assert self.kind in (FaultInjector.POOL_SQUEEZE,
                             FaultInjector.ACCEPT_COLLAPSE,
                             FaultInjector.CACHE_TORN,
                             FaultInjector.SLOT_CHURN), self.kind
        assert 0 <= self.start < self.stop, (self.start, self.stop)


class FaultInjector:
    """Arms/disarms a schedule of ``Fault`` windows against one engine.

    Deterministic by construction: the schedule is fixed tick windows,
    the pool squeeze holds exact page counts, the draft corruption is a
    pure function, and churn victims come from the engine's own
    (deterministic) victim policy — two runs with the same schedule and
    traffic make identical scheduling decisions."""

    POOL_SQUEEZE = "pool_squeeze"
    ACCEPT_COLLAPSE = "accept_collapse"
    CACHE_TORN = "cache_torn"
    SLOT_CHURN = "slot_churn"

    def __init__(self, schedule: List[Fault],
                 cache_path: Optional[str] = None):
        self.schedule = list(schedule)
        self.injected = 0             # windows armed
        self.cleared = 0              # windows disarmed
        self._saved_draft = None
        self._cache_path = cache_path
        self._cache_bytes: Optional[bytes] = None

    # -- individual faults ----------------------------------------------------

    def _squeeze(self, engine, fault: Fault) -> None:
        pool = engine.pool
        if pool is None:
            return
        if fault.pages:
            held = len(pool.slot_pages.get(PHANTOM_SLOT, ()))
            n = min(fault.pages - held, pool.free_pages)
        else:
            n = pool.free_pages - fault.min_free
        if n > 0:
            pool.alloc(PHANTOM_SLOT, n)

    def _release(self, engine) -> None:
        if engine.pool is not None and \
                PHANTOM_SLOT in engine.pool.slot_pages:
            engine.pool.free_slot(PHANTOM_SLOT)

    def _corrupt_draft(self, engine) -> None:
        if getattr(engine, "draft", None) is not None and \
                self._saved_draft is None:
            self._saved_draft = engine.draft
            engine.draft = _CorruptingDraft(engine.draft,
                                            engine.cfg.vocab)

    def _restore_draft(self, engine) -> None:
        if self._saved_draft is not None:
            engine.draft = self._saved_draft
            self._saved_draft = None

    def _tear_cache(self) -> None:
        from repro.core import autotune
        path = self._cache_path or autotune.TUNING_CACHE_PATH
        if not os.path.exists(path):
            return
        with open(path, "rb") as f:
            data = f.read()
        if self._cache_bytes is None:
            self._cache_bytes = data
        with open(path, "wb") as f:
            f.write(data[:max(1, len(data) // 2)])   # mid-JSON truncation
        # The cached parse would mask the torn file; force a re-read.
        autotune._tuning_cache = None

    def _heal_cache(self) -> None:
        from repro.core import autotune
        path = self._cache_path or autotune.TUNING_CACHE_PATH
        if self._cache_bytes is not None:
            with open(path, "wb") as f:
                f.write(self._cache_bytes)
            self._cache_bytes = None
            autotune._tuning_cache = None

    def _churn(self, engine, fault: Fault) -> None:
        for _ in range(fault.victims_per_tick):
            victims = [i for i, s in enumerate(engine.slots)
                       if s is not None and i not in engine._prefilling]
            if not victims:
                return
            engine._preempt(engine._choose_victim(victims))

    # -- the tick hook --------------------------------------------------------

    def step(self, engine) -> None:
        """Advance the schedule to ``engine.ticks`` (call once per tick,
        before ``engine.tick()``)."""
        t = engine.ticks
        for fault in self.schedule:
            starting = fault.start <= t < fault.stop
            if starting and not fault.active:
                fault.active = True
                self.injected += 1
                if fault.kind == self.ACCEPT_COLLAPSE:
                    self._corrupt_draft(engine)
                elif fault.kind == self.CACHE_TORN:
                    self._tear_cache()
            elif not starting and fault.active:
                fault.active = False
                self.cleared += 1
                if fault.kind == self.POOL_SQUEEZE:
                    self._release(engine)
                elif fault.kind == self.ACCEPT_COLLAPSE:
                    self._restore_draft(engine)
                elif fault.kind == self.CACHE_TORN:
                    self._heal_cache()
            if fault.active:
                # Per-tick actions (squeeze re-grabs pages freed by
                # finishing slots; churn evicts fresh victims).
                if fault.kind == self.POOL_SQUEEZE:
                    self._squeeze(engine, fault)
                elif fault.kind == self.SLOT_CHURN:
                    self._churn(engine, fault)

    def finish(self, engine) -> None:
        """Disarm everything (end-of-run cleanup even if the schedule's
        windows extend past the last tick)."""
        for fault in self.schedule:
            if fault.active:
                fault.active = False
                self.cleared += 1
        self._release(engine)
        self._restore_draft(engine)
        self._heal_cache()


def canonical_schedule(t0: int = 6, dwell: int = 10,
                       gap: int = 8) -> List[Fault]:
    """The seeded fault schedule the acceptance criteria name: pool
    exhaustion, then accept collapse, then a churn storm — sequential
    windows with recovery gaps so each fault's *clearing* is also
    exercised. (CACHE_TORN is scheduled separately by tests that own a
    tuning-cache tmp path.)"""
    k = FaultInjector
    w = [(k.POOL_SQUEEZE, t0), (k.ACCEPT_COLLAPSE, t0 + dwell + gap),
         (k.SLOT_CHURN, t0 + 2 * (dwell + gap))]
    return [Fault(kind=kind, start=s, stop=s + dwell) for kind, s in w]
