"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gemm(x, y):
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def flash_attention(q, k, v, causal: bool = True):
    """Exact softmax attention with GQA broadcast, fp32 softmax."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    qg = q.reshape(b, sq, kvh, group, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) / np.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def flash_decode(q, k, v, lengths):
    """Ragged single-token GQA decode: slot i attends its first lengths[i]
    cache rows; zero-length slots produce zeros (freed engine slots)."""
    b, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    qg = q.reshape(b, kvh, group, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, kf) / np.sqrt(d)
    valid = jnp.arange(skv)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, vf)
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(b, h, d).astype(q.dtype)


def ssd_scan(x, a_log, b, c):
    """Sequential SSD recurrence (same as models.mamba.ssd_reference)."""
    from repro.models.mamba import ssd_reference

    y, h = ssd_reference(x.astype(jnp.float32), a_log.astype(jnp.float32),
                         b.astype(jnp.float32), c.astype(jnp.float32))
    return y.astype(x.dtype), h


def pchase(chain: np.ndarray, steps: int) -> np.ndarray:
    out = np.empty(steps, dtype=np.int32)
    pos = 0
    for i in range(steps):
        out[i] = pos
        pos = int(chain[pos])
    return out
