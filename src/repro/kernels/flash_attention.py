"""Flash attention (online softmax) Pallas kernel, causal + GQA, with a
scalar-prefetch grid that skips out-of-diagonal K-block *loads*.

HBM->VMEM tiling: the (block_q, head_dim) query tile stays resident while
K/V tiles stream; running max/denominator/accumulator live in VMEM scratch
and persist across the sequential K steps. GQA is handled in the K/V
BlockSpec index maps (no materialized head repeat).

Causality is a *grid* property here, not a ``pl.when`` guard: the grid's
second dimension enumerates only the (q-block, k-block) pairs at or below
the diagonal, with the pair decoded from scalar-prefetched ``qmap``/``kmap``
arrays inside the index maps. Blocks past the diagonal are never part of
the grid, so their K/V tiles are never streamed from HBM — the skipped-load
optimization the seed kernel documented as out of scope. Block sizes default
to the microbench-priced attention cost model
(``core.autotune.choose_attn_block``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _largest_divisor(dim: int, upper: int) -> int:
    for c in range(min(upper, dim), 0, -1):
        if dim % c == 0:
            return c
    return dim


def _lower_tri_maps(sq: int, skv: int, block_q: int, block_k: int,
                    causal: bool):
    """Enumerate visited (q-block, k-block) pairs, q-major.

    Causal: for query block qi only the K blocks whose first column is
    <= the block's last row (+ the skv-sq diagonal offset) are visited.
    Returns int32 (qmap, kmap, last) where last flags each q row's final
    K step (the online-softmax write-out point).
    """
    nq, nk = sq // block_q, skv // block_k
    off = skv - sq                 # query i attends keys <= i + off
    qmap, kmap, last = [], [], []
    for qi in range(nq):
        if causal:
            last_row = qi * block_q + block_q - 1
            kmax = min(max((last_row + off) // block_k + 1, 1), nk)
        else:
            kmax = nk
        for ki in range(kmax):
            qmap.append(qi)
            kmap.append(ki)
            last.append(1 if ki == kmax - 1 else 0)
    return (np.asarray(qmap, np.int32), np.asarray(kmap, np.int32),
            np.asarray(last, np.int32))


def _flash_kernel(qmap_ref, kmap_ref, last_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  offset: int):
    t = pl.program_id(1)
    qi, ki = qmap_ref[t], kmap_ref[t]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Every grid step is a visited block (off-diagonal blocks never made it
    # into the maps) — only the diagonal straddlers still need masking.
    q = q_ref[0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows + offset, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(last_ref[t] == 1)
    def _done():
        o_ref[0] = (acc_scr[...] / l_scr[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q=None,
                    block_k=None, interpret: bool = False):
    """q: (b, sq, h, d); k/v: (b, skv, kvh, d) -> (b, sq, h, d).

    ``block_q``/``block_k`` default to the attention cost model's choice
    (``core.autotune.choose_attn_block``), snapped to dividing sizes.
    """
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    # Causal with sq > skv would leave early query rows with zero visitable
    # keys (undefined softmax); no call site produces that shape.
    assert not causal or skv >= sq, (sq, skv)
    if block_q is None or block_k is None:
        from repro.core import autotune
        prob = autotune.AttnProblem(sq=sq, skv=skv, n_heads=h, head_dim=d,
                                    batch=b, causal=causal,
                                    in_bytes=q.dtype.itemsize)
        chosen, _ = autotune.choose_attn_block(prob)
        # Cost-model choices are 128-aligned; snap to dividing sizes so
        # ragged sequence lengths stay launchable.
        block_q = block_q or _largest_divisor(sq, chosen.block_q)
        block_k = block_k or _largest_divisor(skv, chosen.block_k)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)

    qmap, kmap, last = _lower_tri_maps(sq, skv, block_q, block_k, causal)

    def q_index(bh, t, qm, km, lf):
        return (bh, qm[t], 0)

    def kv_index(bh, t, qm, km, lf):
        # flattened q index bh = batch*h + head -> kv row batch*kvh + head//g
        return ((bh // h) * kvh + (bh % h) // group, km[t], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * h, len(qmap)),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_index),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_index),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=1.0 / np.sqrt(d),
                          causal=causal, block_q=block_q, block_k=block_k,
                          offset=skv - sq),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(qmap), jnp.asarray(kmap), jnp.asarray(last), qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
