"""Flash attention (online softmax) Pallas kernel, causal + GQA.

HBM->VMEM tiling: the (block_q, head_dim) query tile stays resident while
K/V tiles stream; running max/denominator/accumulator live in VMEM scratch
and persist across the sequential K grid steps. GQA is handled in the K/V
BlockSpec index maps (no materialized head repeat). Causal K-blocks past the
diagonal are skipped via ``pl.when`` (their loads still stream; skipping the
*loads* too is a documented future optimization — on TPU that needs a
scalar-prefetch grid, out of scope here).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # Query block rows end at qi*bq + bq - 1; skip K blocks fully beyond.
        run = ki * block_k <= qi * block_q + block_q - 1

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = (acc_scr[...] / l_scr[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False):
    """q: (b, sq, h, d); k/v: (b, skv, kvh, d) -> (b, sq, h, d)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)

    def kv_index(bh, qi, ki):
        # flattened q index bh = batch*h + head -> kv row batch*kvh + head//g
        return ((bh // h) * kvh + (bh % h) // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=1.0 / np.sqrt(d),
                          causal=causal, block_q=block_q, block_k=block_k),
        grid=(b * h, sq // block_q, skv // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
