"""Flash attention (online softmax) Pallas kernel, causal + GQA, with a
scalar-prefetch grid that skips out-of-diagonal K-block *loads*.

HBM->VMEM tiling: the (block_q, head_dim) query tile stays resident while
K/V tiles stream; running max/denominator/accumulator live in VMEM scratch
and persist across the sequential K steps. GQA is handled in the K/V
BlockSpec index maps (no materialized head repeat).

Causality is a *grid* property here, not a ``pl.when`` guard: the grid's
second dimension enumerates only the (q-block, k-block) pairs at or below
the diagonal, with the pair decoded from scalar-prefetched ``qmap``/``kmap``
arrays inside the index maps. Blocks past the diagonal are never part of
the grid, so their K/V tiles are never streamed from HBM — the skipped-load
optimization the seed kernel documented as out of scope. Block sizes default
to the microbench-priced attention cost model
(``core.autotune.choose_attn_block``).

``flash_attention_paged`` is the chunked-prefill variant of the same grid:
the queries are one fixed-size chunk of a prompt being written *in place*
through a KV page table (``serve.paged``), so K/V stream from a shared
(n_pages, page_size, kvh, d) pool instead of a contiguous row range. The
page table rides in as an extra scalar-prefetch argument next to
qmap/kmap/last and the K/V index maps first clamp the key block to the
slot's live span (``starts[slot] + chunk`` — the chunk's own rows included,
write-then-attend) and then translate logical→physical before the DMA — the
same software-TLB walk as ``flash_decode_paged``, at prefill width. The
qmap/kmap/last enumeration is built once for the worst-case chunk position
(the chunk ending at the pool's last row), so one executable serves every
chunk of every prompt; blocks past a particular chunk's live span re-map to
the resident block (no fresh DMA) and skip their compute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _largest_divisor(dim: int, upper: int) -> int:
    for c in range(min(upper, dim), 0, -1):
        if dim % c == 0:
            return c
    return dim


def _lower_tri_maps(sq: int, skv: int, block_q: int, block_k: int,
                    causal: bool):
    """Enumerate visited (q-block, k-block) pairs, q-major.

    Causal: for query block qi only the K blocks whose first column is
    <= the block's last row (+ the skv-sq diagonal offset) are visited.
    Returns int32 (qmap, kmap, last) where last flags each q row's final
    K step (the online-softmax write-out point).
    """
    nq, nk = sq // block_q, skv // block_k
    off = skv - sq                 # query i attends keys <= i + off
    qmap, kmap, last = [], [], []
    for qi in range(nq):
        if causal:
            last_row = qi * block_q + block_q - 1
            kmax = min(max((last_row + off) // block_k + 1, 1), nk)
        else:
            kmax = nk
        for ki in range(kmax):
            qmap.append(qi)
            kmap.append(ki)
            last.append(1 if ki == kmax - 1 else 0)
    return (np.asarray(qmap, np.int32), np.asarray(kmap, np.int32),
            np.asarray(last, np.int32))


def _flash_kernel(qmap_ref, kmap_ref, last_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  offset: int):
    t = pl.program_id(1)
    qi, ki = qmap_ref[t], kmap_ref[t]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Every grid step is a visited block (off-diagonal blocks never made it
    # into the maps) — only the diagonal straddlers still need masking.
    q = q_ref[0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows + offset, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(last_ref[t] == 1)
    def _done():
        o_ref[0] = (acc_scr[...] / l_scr[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q=None,
                    block_k=None, interpret: bool = False):
    """q: (b, sq, h, d); k/v: (b, skv, kvh, d) -> (b, sq, h, d).

    ``block_q``/``block_k`` default to the attention cost model's choice
    (``core.autotune.choose_attn_block``), snapped to dividing sizes.
    """
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    # Causal with sq > skv would leave early query rows with zero visitable
    # keys (undefined softmax); no call site produces that shape.
    assert not causal or skv >= sq, (sq, skv)
    if block_q is None or block_k is None:
        from repro.core import autotune
        prob = autotune.AttnProblem(sq=sq, skv=skv, n_heads=h, head_dim=d,
                                    batch=b, causal=causal,
                                    in_bytes=q.dtype.itemsize)
        chosen, _ = autotune.choose_attn_block(prob)
        # Cost-model choices are 128-aligned; snap to dividing sizes so
        # ragged sequence lengths stay launchable.
        block_q = block_q or _largest_divisor(sq, chosen.block_q)
        block_k = block_k or _largest_divisor(skv, chosen.block_k)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)

    qmap, kmap, last = _lower_tri_maps(sq, skv, block_q, block_k, causal)

    def q_index(bh, t, qm, km, lf):
        return (bh, qm[t], 0)

    def kv_index(bh, t, qm, km, lf):
        # flattened q index bh = batch*h + head -> kv row batch*kvh + head//g
        return ((bh // h) * kvh + (bh % h) // group, km[t], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * h, len(qmap)),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_index),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_index),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=1.0 / np.sqrt(d),
                          causal=causal, block_q=block_q, block_k=block_k,
                          offset=skv - sq),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(qmap), jnp.asarray(kmap), jnp.asarray(last), qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def _paged_prefill_kernel(qmap_ref, kmap_ref, last_ref, starts_ref, pages_ref,
                          q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                          *, scale: float, block_q: int, block_k: int,
                          sq: int, h: int, max_rows: int):
    del pages_ref                    # consumed by the index maps (the TLB)
    t = pl.program_id(1)
    qi, ki = qmap_ref[t], kmap_ref[t]
    start = starts_ref[pl.program_id(0) // h]
    kv_end = jnp.minimum(start + sq, max_rows)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Key blocks at/past the live span were never DMA'd (the index map
    # re-visits the resident block); skip their compute too.
    @pl.when(ki * block_k < kv_end)
    def _step():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        # Global positions: query row r of this chunk sits at start + r.
        rows = start + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(last_ref[t] == 1)
    def _done():
        # `last` flags the statically-last K step per q block (worst-case
        # chunk position); skipped steps left acc/l untouched, so the
        # accumulator already holds this chunk's final values here.
        denom = jnp.where(l_scr[...] > 0.0, l_scr[...], 1.0)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def flash_attention_paged(q, k_pages, v_pages, page_table, starts,
                          block_q=None, block_k=None,
                          interpret: bool = False):
    """Causal chunk attention against a paged KV pool (chunked prefill).

    q: (b, sq, h, d) — one chunk of queries per slot, slot i's rows sitting
    at global positions ``starts[i] + [0, sq)``. k_pages/v_pages:
    (n_pages, page_size, kvh, d) shared pool, page 0 the null page;
    ``page_table``: (b, max_pages) logical→physical map. The chunk's own
    K/V rows must already be written through the table (write-then-attend);
    each query attends causally over every previously-written position plus
    its own prefix of the chunk. Returns (b, sq, h, d).

    ``block_k`` must divide ``page_size`` (None -> cost-model choice
    snapped to a dividing size); one executable serves every chunk
    position — ``starts`` is data, not shape.
    """
    b, sq, h, d = q.shape
    n_pages, page_size, kvh, _ = k_pages.shape
    max_pages = page_table.shape[1]
    max_rows = max_pages * page_size
    group = h // kvh
    assert group * kvh == h, (h, kvh)
    if block_q is None or block_k is None:
        from repro.core import autotune
        prob = autotune.AttnProblem(sq=sq, skv=max_rows, n_heads=h,
                                    head_dim=d, batch=b, causal=True,
                                    in_bytes=q.dtype.itemsize)
        chosen, _ = autotune.choose_attn_block(prob)
        block_q = block_q or _largest_divisor(sq, chosen.block_q)
        block_k = block_k or _largest_divisor(page_size, chosen.block_k)
    block_q = min(block_q, sq)
    block_k = min(block_k, page_size)
    assert sq % block_q == 0, (sq, block_q)
    assert page_size % block_k == 0, (page_size, block_k)
    bpp = page_size // block_k          # blocks per page

    # Worst-case enumeration: the chunk ending at the pool's last row
    # (offset = max_rows - sq) visits the most K blocks; real chunks clamp
    # at runtime. One (qmap, kmap, last) set -> one executable for every
    # chunk of every prompt.
    qmap, kmap, last = _lower_tri_maps(sq, max_rows, block_q, block_k,
                                       causal=True)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k_pages.transpose(2, 0, 1, 3)  # (kvh, n_pages, page_size, d)
    vf = v_pages.transpose(2, 0, 1, 3)
    starts = starts.astype(jnp.int32)
    page_table = page_table.astype(jnp.int32)

    def q_index(bh, t, qm, km, lf, st, pages):
        return (bh, qm[t], 0)

    def kv_index(bh, t, qm, km, lf, st, pages):
        # Clamp to the slot's last live block (chunk rows included — they
        # are already written), then walk the page table: logical block ->
        # (physical page, in-page block) before the DMA issues.
        slot = bh // h
        kv_end = jnp.minimum(st[slot] + sq, max_rows)
        last_blk = jnp.maximum(kv_end - 1, 0) // block_k
        kic = jnp.minimum(km[t], last_blk)
        return ((bh % h) // group, pages[slot, kic // bpp], kic % bpp, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(b * h, len(qmap)),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_index),
            pl.BlockSpec((1, 1, block_k, d), kv_index),
            pl.BlockSpec((1, 1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_index),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_prefill_kernel, scale=1.0 / np.sqrt(d),
                          block_q=block_q, block_k=block_k, sq=sq, h=h,
                          max_rows=max_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(qmap), jnp.asarray(kmap), jnp.asarray(last), starts,
      page_table, qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
