"""Pointer-chase probe as a Pallas kernel — the paper's ch.3 measurement
primitive expressed on the TPU.

On a real TPU this kernel issues a serially dependent gather chain through
VMEM/HBM (deployable as a latency probe with hardware timers); in this
container it runs in interpret mode and is validated against the numpy
chase. It is also the access-pattern generator for the device-model
dissection (same chains, same semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chase_kernel(chain_ref, o_ref, *, steps: int):
    def body(i, pos):
        o_ref[i] = pos
        return chain_ref[pos]

    final = jax.lax.fori_loop(0, steps, body, jnp.int32(0))
    o_ref[steps - 1] = o_ref[steps - 1]  # keep shape users honest
    del final


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def pchase(chain, steps: int, interpret: bool = False):
    """Follow ``chain`` (int32 next-index array) for ``steps`` dependent
    loads; returns the visited positions."""
    n = chain.shape[0]
    return pl.pallas_call(
        functools.partial(_chase_kernel, steps=steps),
        grid=(),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)
                  if hasattr(pl, "ANY") else pl.BlockSpec((n,), lambda: (0,))],
        out_specs=pl.BlockSpec((steps,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((steps,), jnp.int32),
        interpret=interpret,
    )(chain.astype(jnp.int32))
