"""Jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels execute in interpret mode (the kernel body
runs in Python for correctness validation); on a TPU backend they compile
natively. Block shapes default to the microbench-informed autotuner's
choices (``core/autotune``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.kernels import flash_attention as _flash
from repro.kernels import flash_decode as _flash_decode
from repro.kernels import gemm as _gemm
from repro.kernels import pchase_probe as _pchase
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gemm(x, y, block=None):
    if block is None:
        p = autotune.GemmProblem(m=x.shape[0], k=x.shape[1], n=y.shape[1],
                                 in_bytes=x.dtype.itemsize)
        cfg, _ = autotune.choose_gemm_block(p)
        bm = min(cfg.bm, x.shape[0])
        bk = min(cfg.bk, x.shape[1])
        bn = min(cfg.bn, y.shape[1])
    else:
        bm, bk, bn = block
    # Fall back to aligned divisors when shapes don't tile.
    bm = _largest_divisor(x.shape[0], bm)
    bk = _largest_divisor(x.shape[1], bk)
    bn = _largest_divisor(y.shape[1], bn)
    return _gemm.gemm(x, y, bm=bm, bk=bk, bn=bn, interpret=_interpret())


_largest_divisor = _flash._largest_divisor


def flash_attention(q, k, v, causal: bool = True, block_q=None,
                    block_k=None):
    # block defaults (None) resolve inside the kernel via the attention
    # cost model; explicit blocks just snap to dividing sizes here.
    if block_q is not None:
        block_q = _largest_divisor(q.shape[1], block_q)
    if block_k is not None:
        block_k = _largest_divisor(k.shape[1], block_k)
    return _flash.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=_interpret())


def flash_attention_paged(q, k_pages, v_pages, page_table, starts,
                          block_q=None, block_k=None):
    """Chunked-prefill causal attention against a paged KV pool: q
    (b, sq, h, d) at global positions ``starts[i] + [0, sq)`` vs a
    (n_pages, page_size, kvh, d) pool walked through ``page_table``.
    The chunk's rows must already be written through the table."""
    if block_q is not None:
        block_q = _largest_divisor(q.shape[1], block_q)
    if block_k is not None:
        block_k = _largest_divisor(k_pages.shape[1], block_k)
    return _flash.flash_attention_paged(
        q, k_pages, v_pages, page_table, starts, block_q=block_q,
        block_k=block_k, interpret=_interpret())


def flash_decode(q, k, v, lengths, block_k=None):
    """Single-token GQA decode: q (b, h, d) vs ragged (b, max_len, kvh, d).

    ``block_k=None`` resolves through the attention cost model inside the
    kernel wrapper."""
    if block_k is not None:
        block_k = _largest_divisor(k.shape[1], block_k)
    return _flash_decode.flash_decode(q, k, v, lengths, block_k=block_k,
                                      interpret=_interpret())


def flash_decode_paged(q, k_pages, v_pages, page_table, lengths,
                       block_k=None):
    """Paged GQA decode: q (b, h, d) vs a (n_pages, page_size, kvh, d)
    pool walked through ``page_table`` (b, max_pages). ``block_k`` snaps
    to a divisor of the page size (None -> cost-model choice)."""
    if block_k is not None:
        block_k = _largest_divisor(k_pages.shape[1], block_k)
    return _flash_decode.flash_decode_paged(
        q, k_pages, v_pages, page_table, lengths, block_k=block_k,
        interpret=_interpret())


def ssd_scan(x, a_log, b, c, chunk: int = 128):
    chunk = _largest_divisor(x.shape[1], chunk)
    return _ssd.ssd_scan(x, a_log, b, c, chunk=chunk,
                         interpret=_interpret())


def pchase(chain, steps: int):
    return _pchase.pchase(chain, steps, interpret=_interpret())
