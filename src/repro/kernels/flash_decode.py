"""GQA flash-decode Pallas kernel: single-token queries vs ragged KV caches.

Decode attention in the serving engine is one (group, head_dim) query row per
(slot, kv head) against that slot's KV cache prefix. The seed path attended
over the full ``max_len`` cache every step; here the per-slot lengths ride in
as scalar-prefetch arguments so the K/V BlockSpec index maps can clamp the
streamed block to each slot's last valid block — grid steps past a slot's
length re-map to the block already resident in VMEM, so on TPU no fresh DMA
is issued and ``pl.when`` skips the compute. Decode attention cost becomes
O(actual context) instead of O(max_len).

Layout: the (slot, kv head) pair is flattened into grid dim 0, exactly like
``flash_attention``'s (batch, head) flattening; GQA needs no materialized
head repeat because the q rows for one kv head are contiguous.

``flash_decode_paged`` is the same kernel against a *paged* cache
(``serve.paged``): K/V live in a shared (n_pages, page_size, kvh, d) pool
and each slot owns a page table instead of a contiguous row range. The
page table rides in as a second scalar-prefetch argument and the K/V index
maps walk it — a software TLB: grid step ki resolves (slot, ki) -> physical
page before the DMA is issued, so non-contiguous pages stream exactly like
the clamped contiguous stream (page 0 is the never-computed null page).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import _largest_divisor

NEG_INF = -1e30


def _decode_body(length, ki, q_ref, read_kv, o_ref, m_scr, l_scr, acc_scr,
                 *, scale: float, block_k: int):
    """Shared online-softmax accumulator for both decode kernels; they
    differ only in how the (block_k, d) K/V block is read (``read_kv``)."""

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Blocks at/after the slot's length are load-skipped by the index map;
    # skip their compute too.
    @pl.when(ki * block_k < length)
    def _step():
        q = q_ref[0].astype(jnp.float32)                  # (group, d)
        k, v = read_kv()                                  # (bk, d) each
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == pl.num_programs(1) - 1)
    def _done():
        # Zero-length slots (freed engine slots) produce zeros, not NaN.
        denom = jnp.where(l_scr[...] > 0.0, l_scr[...], 1.0)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, block_k: int, kvh: int):
    bh, ki = pl.program_id(0), pl.program_id(1)
    _decode_body(lens_ref[bh // kvh], ki, q_ref,
                 lambda: (k_ref[0].astype(jnp.float32),
                          v_ref[0].astype(jnp.float32)),
                 o_ref, m_scr, l_scr, acc_scr, scale=scale, block_k=block_k)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k, v, lengths, block_k=None,
                 interpret: bool = False):
    """q: (b, h, d); k/v: (b, max_len, kvh, d); lengths: (b,) -> (b, h, d).

    ``lengths[i]`` is the number of valid KV rows for slot i (0 allowed:
    the output row is zeros). Only ``ceil(lengths[i] / block_k)`` K/V
    blocks are streamed for slot i. ``block_k=None`` asks the attention
    cost model (``core.autotune.choose_attn_block``), snapped to a
    dividing size.
    """
    b, h, d = q.shape
    _, max_len, kvh, _ = k.shape
    group = h // kvh
    assert group * kvh == h, (h, kvh)
    if block_k is None:
        from repro.core import autotune
        prob = autotune.AttnProblem(sq=group, skv=max_len, n_heads=kvh,
                                    head_dim=d, batch=b, causal=False,
                                    in_bytes=q.dtype.itemsize)
        chosen, _ = autotune.choose_attn_block(prob)
        block_k = _largest_divisor(max_len, chosen.block_k)
    block_k = min(block_k, max_len)
    assert max_len % block_k == 0, (max_len, block_k)
    nk = max_len // block_k

    qf = q.reshape(b * kvh, group, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, max_len, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, max_len, d)
    lengths = lengths.astype(jnp.int32)

    def kv_index(bh, ki, lens):
        # Clamp to the slot's last valid block: out-of-range grid steps
        # re-visit it, so the pipeline issues no new copy.
        last = jnp.maximum(lens[bh // kvh] - 1, 0) // block_k
        return (bh, jnp.minimum(ki, last), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * kvh, nk),
        in_specs=[
            pl.BlockSpec((1, group, d), lambda bh, ki, lens: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, group, d),
                               lambda bh, ki, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=1.0 / np.sqrt(d),
                          block_k=block_k, kvh=kvh),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kvh, group, d), q.dtype),
        interpret=interpret,
    )(lengths, qf, kf, vf)
    return out.reshape(b, h, d)


def _paged_decode_kernel(lens_ref, pages_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *,
                         scale: float, block_k: int, kvh: int):
    del pages_ref                    # consumed by the index maps (the TLB)
    bh, ki = pl.program_id(0), pl.program_id(1)
    # K/V blocks carry a leading (page, in-page) pair instead of a row.
    _decode_body(lens_ref[bh // kvh], ki, q_ref,
                 lambda: (k_ref[0, 0].astype(jnp.float32),
                          v_ref[0, 0].astype(jnp.float32)),
                 o_ref, m_scr, l_scr, acc_scr, scale=scale, block_k=block_k)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_paged(q, k_pages, v_pages, page_table, lengths,
                       block_k=None, interpret: bool = False):
    """Paged flash decode: q (b, h, d) vs a shared KV page pool.

    k_pages/v_pages: (n_pages, page_size, kvh, d) — page 0 is the null
    page. ``page_table``: (b, max_pages) int32 logical->physical map, 0 in
    unallocated entries. ``lengths``: (b,) live rows per slot (0 allowed).
    The table and lengths are both scalar-prefetched; the K/V index maps
    first clamp ki to the slot's last live block (re-visiting the resident
    block, so no fresh DMA) and then translate through the table.
    ``block_k`` must divide ``page_size`` (None -> cost-model choice
    snapped to a dividing size).
    """
    b, h, d = q.shape
    n_pages, page_size, kvh, _ = k_pages.shape
    max_pages = page_table.shape[1]
    group = h // kvh
    assert group * kvh == h, (h, kvh)
    if block_k is None:
        from repro.core import autotune
        prob = autotune.AttnProblem(sq=group, skv=max_pages * page_size,
                                    n_heads=kvh, head_dim=d, batch=b,
                                    causal=False, in_bytes=q.dtype.itemsize)
        chosen, _ = autotune.choose_attn_block(prob)
        block_k = _largest_divisor(page_size, chosen.block_k)
    block_k = min(block_k, page_size)
    assert page_size % block_k == 0, (page_size, block_k)
    bpp = page_size // block_k          # blocks per page
    nk = max_pages * bpp

    qf = q.reshape(b * kvh, group, d)
    kf = k_pages.transpose(2, 0, 1, 3)  # (kvh, n_pages, page_size, d)
    vf = v_pages.transpose(2, 0, 1, 3)
    lengths = lengths.astype(jnp.int32)
    page_table = page_table.astype(jnp.int32)

    def kv_index(bh, ki, lens, pages):
        # Clamp to the slot's last live block (no fresh DMA past the
        # length), then walk the page table for the physical page.
        slot = bh // kvh
        last = jnp.maximum(lens[slot] - 1, 0) // block_k
        kic = jnp.minimum(ki, last)
        return (bh % kvh, pages[slot, kic // bpp], kic % bpp, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * kvh, nk),
        in_specs=[
            pl.BlockSpec((1, group, d), lambda bh, ki, lens, pages: (bh, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), kv_index),
            pl.BlockSpec((1, 1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, group, d),
                               lambda bh, ki, lens, pages: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=1.0 / np.sqrt(d),
                          block_k=block_k, kvh=kvh),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kvh, group, d), q.dtype),
        interpret=interpret,
    )(lengths, page_table, qf, kf, vf)
    return out.reshape(b, h, d)
