"""Chunked SSD (Mamba-2) Pallas kernel.

Grid: (batch*heads, n_chunks) with the chunk axis innermost/sequential; the
(head_dim, d_state) SSM state lives in VMEM scratch and carries across chunk
steps — the TPU-native expression of the inter-chunk recurrence. Per chunk:
the intra-chunk decay-masked attention-like product (three small MXU
matmuls) plus the state update, all fp32 in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)               # (q, p)
    a = a_ref[0].astype(jnp.float32)               # (q,) log-decay
    bm = b_ref[0].astype(jnp.float32)              # (q, n)
    cm = c_ref[0].astype(jnp.float32)              # (q, n)

    a_cum = jnp.cumsum(a)                          # (q,)
    # Intra-chunk decay[l, s] = exp(sum_{s<m<=l} a_m) = exp(cum[l] - cum[s]).
    seg = a_cum[:, None] - a_cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(cols <= rows, jnp.exp(seg), 0.0)

    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32) * decay
    y = jnp.dot(scores, x, preferred_element_type=jnp.float32)   # (q, p)

    # Contribution of the carried state: y += (C * exp(a_cum)) @ state^T.
    state = state_scr[...]                         # (p, n)
    c_decay = cm * jnp.exp(a_cum)[:, None]
    y += jnp.dot(c_decay, state.T, preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    # State update: state = exp(A_chunk) * state + sum_l exp(a_cum[-1]-a_cum[l]) x_l b_l^T
    decay_states = jnp.exp(a_cum[-1] - a_cum)      # (q,)
    new_contrib = jnp.dot((x * decay_states[:, None]).T, bm,
                          preferred_element_type=jnp.float32)    # (p, n)
    state_scr[...] = state * jnp.exp(a_cum[-1]) + new_contrib

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit_state():
        hout_ref[0] = state_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, a_log, b, c, chunk: int = 128, interpret: bool = False):
    """Chunked SSD scan.

    x: (bt, l, h, p) dt-scaled inputs; a_log: (bt, l, h) log decays;
    b, c: (bt, l, n). Returns (y: (bt, l, h, p), state: (bt, h, p, n)).
    """
    bt, l, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    xf = x.transpose(0, 2, 1, 3).reshape(bt * h, l, p)
    af = a_log.transpose(0, 2, 1).reshape(bt * h, l)
    # b/c are shared across heads; index-map them per flattened row.

    def bc_index(bh, ci):
        return (bh // h, ci, 0)

    y, hout = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bt * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, n), bc_index),
            pl.BlockSpec((1, chunk, n), bc_index),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt * h, l, p), x.dtype),
            jax.ShapeDtypeStruct((bt * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xf, af, b, c)
    y = y.reshape(bt, h, l, p).transpose(0, 2, 1, 3)
    hout = hout.reshape(bt, h, p, n)
    return y, hout
