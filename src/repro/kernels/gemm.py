"""Blocked GEMM Pallas kernel — the Ch.1 case study, TPU-idiomatic.

The paper hand-schedules an 8x8 FFMA register tile to dodge bank conflicts;
the MXU equivalent of that register tile is the (bm, bk, bn) VMEM block.
Block shapes come from the microbench-informed autotuner
(``core/autotune.choose_gemm_block``): MXU-aligned (multiples of 128), sized
so double-buffered input tiles plus the fp32 accumulator fit VMEM.

Grid: (M/bm, N/bn, K/bk), K innermost; the accumulator lives in VMEM scratch
and persists across the sequential K steps (TPU grids execute in order).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def gemm(x, y, bm: int = 256, bk: int = 512, bn: int = 256,
         interpret: bool = False):
    """x: (m, k) @ y: (k, n) -> (m, n). Dims must tile by the block shape."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        ((m, k, n), (bm, bk, bn))
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
