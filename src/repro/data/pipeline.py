"""Deterministic, sharded, resumable synthetic LM data pipeline.

Design goals (the ones that matter at 1000 nodes):
  * **Determinism**: batch(step, dp_rank) is a pure function of the seed —
    restarts and elastic re-sharding reproduce the exact token stream.
  * **Shardability**: each data-parallel rank draws only its slice; global
    batch order is invariant to the number of ranks.
  * **Resumability**: pipeline state is one integer (the step), carried in
    the checkpoint manifest.
  * **Prefetch**: a background thread keeps ``prefetch`` batches ready.

Tokens follow a Zipf-like distribution (realistic softmax pressure) with a
parity-markov structure so tiny models can measurably learn (loss decreases
— asserted by integration tests).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3


class SyntheticLMData:
    def __init__(self, cfg: DataConfig, dp_rank: int = 0, dp_size: int = 1,
                 start_step: int = 0, prefetch: int = 2):
        assert cfg.global_batch % dp_size == 0
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.step = start_step
        self._local_batch = cfg.global_batch // dp_size
        # Zipf-ish unigram distribution, fixed by seed.
        rng = np.random.RandomState(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()
        self._queue: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- pure batch function --------------------------------------------------
    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) for this rank at ``step`` — pure in (seed, step,
        rank); independent of dp_size re-partitioning at the sample level."""
        cfg = self.cfg
        tokens = np.empty((self._local_batch, cfg.seq_len + 1), np.int32)
        for i in range(self._local_batch):
            sample = self.dp_rank * self._local_batch + i
            rng = np.random.RandomState(
                (cfg.seed * 1_000_003 + step * 1009 + sample) % (2 ** 31))
            row = rng.choice(cfg.vocab, size=cfg.seq_len + 1, p=self._probs)
            # Inject learnable structure: token t+1 repeats token t on a
            # fixed schedule, so models beat the unigram entropy.
            mask = (np.arange(cfg.seq_len + 1) % 4) == 3
            row[mask] = row[np.maximum(np.arange(cfg.seq_len + 1) - 1, 0)][mask]
            tokens[i] = row
        return tokens[:, :-1], tokens[:, 1:]

    # -- iteration + prefetch --------------------------------------------------
    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self

    def __next__(self):
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def start_prefetch(self):
        if self._thread is not None:
            return

        def worker():
            step = self.step
            while not self._stop.is_set():
                try:
                    self._queue.put((step, self.batch_at(step)), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next_prefetched(self):
        step, batch = self._queue.get()
        self.step = step + 1
        return batch

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    # -- checkpointable state --------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict):
        assert state["seed"] == self.cfg.seed, "data seed mismatch"
        self.step = int(state["step"])
