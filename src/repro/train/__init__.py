from repro.train.steps import TrainState, loss_fn, make_train_step  # noqa
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
