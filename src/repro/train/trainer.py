"""Fault-tolerant training loop.

Production behaviours exercised by integration tests:
  * auto-restore from the latest checkpoint on start;
  * periodic async checkpoints (params + optimizer + data cursor);
  * crash recovery: a step that raises is retried after restoring the last
    checkpoint (``max_recoveries`` guard);
  * straggler watchdog: per-step wall time is tracked against a rolling
    median; slow steps fire ``on_straggler`` (at scale this triggers
    re-scheduling; here it logs and counts).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.train import steps as steps_mod


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0
    max_recoveries: int = 3


class Trainer:
    def __init__(self, cfg, model_cfg, data: SyntheticLMData,
                 step_fn: Callable, init_state_fn: Callable,
                 frontend_fn: Optional[Callable] = None,
                 fail_injector: Optional[Callable] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.data = data
        self.step_fn = step_fn
        self.init_state_fn = init_state_fn
        self.frontend_fn = frontend_fn
        self.fail_injector = fail_injector
        self.ckpt = CheckpointManager(cfg.checkpoint_dir,
                                      keep=cfg.keep_checkpoints,
                                      async_save=cfg.async_checkpoint)
        self.metrics_log: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []
        self.recoveries = 0
        self._durations: List[float] = []

    # -- state ------------------------------------------------------------
    def _fresh_state(self):
        return self.init_state_fn()

    def _restore_or_init(self):
        state_tree = self._fresh_state()
        last = self.ckpt.latest_step()
        if last is not None:
            state_tree, manifest = self.ckpt.restore(state_tree)
            self.data.load_state_dict(manifest["extra"]["data"])
        return state_tree

    def _save(self, state_tree):
        step = int(np.asarray(state_tree["step"]))
        self.ckpt.save(step, state_tree,
                       extra={"data": self.data.state_dict()})

    # -- loop --------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        state = self._restore_or_init()
        start = int(np.asarray(state["step"]))
        step = start
        while step < self.cfg.total_steps:
            tokens, labels = self.data.batch_at(step)
            batch = {"tokens": jnp.asarray(tokens),
                     "labels": jnp.asarray(labels)}
            if self.frontend_fn is not None:
                batch["frontend"] = self.frontend_fn(tokens.shape[0])
            t0 = time.perf_counter()
            try:
                if self.fail_injector is not None:
                    self.fail_injector(step)
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            except _RECOVERABLE as e:
                self.recoveries += 1
                if self.recoveries > self.cfg.max_recoveries:
                    raise
                self.ckpt.wait()
                state = self._restore_or_init()
                step = int(np.asarray(state["step"]))
                continue
            dt = time.perf_counter() - t0
            self._watchdog(step, dt)
            step += 1
            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                self.metrics_log.append(
                    {k: float(np.asarray(v)) for k, v in metrics.items()}
                    | {"step": step, "dt": dt})
            if step % self.cfg.checkpoint_every == 0:
                self._save(state)
        self._save(state)
        self.ckpt.wait()
        return {"state": state, "metrics": self.metrics_log,
                "stragglers": self.straggler_steps,
                "recoveries": self.recoveries}

    def _watchdog(self, step: int, dt: float):
        self._durations.append(dt)
        hist = self._durations[-50:]
        if len(hist) >= 8:
            med = float(np.median(hist))
            if dt > self.cfg.straggler_factor * med:
                self.straggler_steps.append(step)
                self.on_straggler(step, dt, med)

    def on_straggler(self, step: int, dt: float, median: float):
        print(f"[watchdog] step {step}: {dt:.3f}s vs median {median:.3f}s "
              f"(>{self.cfg.straggler_factor}x) — straggler flagged")


class SimulatedPreemption(RuntimeError):
    """Raised by fail injectors to model node loss mid-run."""


_RECOVERABLE = (SimulatedPreemption,)
