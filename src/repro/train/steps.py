"""Training step: loss, grads, clipping, AdamW, optional grad accumulation
and error-feedback gradient compression.

``make_train_step`` builds the jitted step with donated state, so the
launcher and the dry-run lower exactly what production would run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import adamw, schedule


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Dict[str, Any]
    step: Any                  # scalar int32
    ef: Any = None             # error-feedback residual (compressed grads)

    def tree(self):
        t = {"params": self.params, "opt": self.opt, "step": self.step}
        if self.ef is not None:
            # Optional leaf: plain compressed / uncompressed runs keep the
            # exact state pytree older checkpoints and the dry-run's
            # sharding derivation expect.
            t["ef"] = self.ef
        return t

    @classmethod
    def from_tree(cls, t):
        return cls(params=t["params"], opt=t["opt"], step=t["step"],
                   ef=t.get("ef"))


def init_state(key, cfg: T.ModelConfig,
               error_feedback: bool = False) -> TrainState:
    params = T.init_params(key, cfg)
    ef = None
    if error_feedback:
        from repro.dist import compression
        ef = compression.ErrorFeedback.init(params)
    return TrainState(params=params, opt=adamw.adamw_init(params),
                      step=jnp.zeros((), jnp.int32), ef=ef)


def cross_entropy(logits, labels):
    """Mean token NLL, fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    return jnp.mean(logz - gold)


def loss_fn(params, cfg: T.ModelConfig, batch, aux_weight: float = 0.01):
    logits, _, aux = T.forward(params, cfg, batch["tokens"],
                               frontend_embeds=batch.get("frontend"))
    nll = cross_entropy(logits, batch["labels"])
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


def make_train_step(cfg: T.ModelConfig,
                    sched: schedule.ScheduleConfig = schedule.ScheduleConfig(),
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    clip_norm: float = 1.0,
                    accum_steps: int = 1,
                    compress_grads: bool = False,
                    error_feedback: bool = False):
    """Returns step(state_tree, batch) -> (state_tree, metrics).

    ``compress_grads`` quantizes gradients to int8 on the wire;
    ``error_feedback`` additionally carries the per-step quantization
    error in ``TrainState.ef`` and re-injects it next step (EF-SGD), so
    compressed training is bias-free — the state must come from
    ``init_state(..., error_feedback=True)``.
    """
    assert not error_feedback or compress_grads, \
        "error_feedback rides on compress_grads"

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        return loss, parts, grads

    def step(state_tree, batch):
        state = TrainState.from_tree(state_tree)
        if accum_steps == 1:
            loss, parts, grads = grads_of(state.params, batch)
        else:
            # Microbatch accumulation over the leading batch dim.
            def micro(i, carry):
                acc, loss_acc = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum_steps),
                        x.shape[0] // accum_steps, 0), batch)
                loss_i, _, g = grads_of(state.params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, loss_acc + loss_i

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            grads, loss = jax.lax.fori_loop(
                0, accum_steps, micro, (zeros, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            parts = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}
        ef = state.ef
        if compress_grads:
            from repro.dist import compression
            if error_feedback:
                assert ef is not None, \
                    "init_state(..., error_feedback=True) required"
                grads, ef = compression.ErrorFeedback.compress(grads, ef)
            else:
                grads = compression.int8_roundtrip(grads)
        grads, gnorm = adamw.clip_by_global_norm(grads, clip_norm)
        lr = schedule.learning_rate(state.step, sched)
        params, opt = adamw.adamw_update(grads, state.opt, state.params, lr,
                                         opt_cfg)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1,
                               ef=ef)
        metrics = {"loss": loss, "nll": parts["nll"], "aux": parts["aux"],
                   "grad_norm": gnorm, "lr": lr}
        return new_state.tree(), metrics

    return step
