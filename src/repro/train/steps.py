"""Training step: loss, grads, clipping, AdamW, optional grad accumulation
and error-feedback gradient compression.

``make_train_step`` builds the jitted step with donated state, so the
launcher and the dry-run lower exactly what production would run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import adamw, schedule


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Dict[str, Any]
    step: Any                  # scalar int32

    def tree(self):
        return {"params": self.params, "opt": self.opt, "step": self.step}

    @classmethod
    def from_tree(cls, t):
        return cls(params=t["params"], opt=t["opt"], step=t["step"])


def init_state(key, cfg: T.ModelConfig) -> TrainState:
    params = T.init_params(key, cfg)
    return TrainState(params=params, opt=adamw.adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def cross_entropy(logits, labels):
    """Mean token NLL, fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    return jnp.mean(logz - gold)


def loss_fn(params, cfg: T.ModelConfig, batch, aux_weight: float = 0.01):
    logits, _, aux = T.forward(params, cfg, batch["tokens"],
                               frontend_embeds=batch.get("frontend"))
    nll = cross_entropy(logits, batch["labels"])
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


def make_train_step(cfg: T.ModelConfig,
                    sched: schedule.ScheduleConfig = schedule.ScheduleConfig(),
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    clip_norm: float = 1.0,
                    accum_steps: int = 1,
                    compress_grads: bool = False):
    """Returns step(state_tree, batch) -> (state_tree, metrics)."""

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        return loss, parts, grads

    def step(state_tree, batch):
        state = TrainState.from_tree(state_tree)
        if accum_steps == 1:
            loss, parts, grads = grads_of(state.params, batch)
        else:
            # Microbatch accumulation over the leading batch dim.
            def micro(i, carry):
                acc, loss_acc = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum_steps),
                        x.shape[0] // accum_steps, 0), batch)
                loss_i, _, g = grads_of(state.params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, loss_acc + loss_i

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            grads, loss = jax.lax.fori_loop(
                0, accum_steps, micro, (zeros, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            parts = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}
        if compress_grads:
            from repro.dist import compression
            grads = compression.int8_roundtrip(grads)
        grads, gnorm = adamw.clip_by_global_norm(grads, clip_norm)
        lr = schedule.learning_rate(state.step, sched)
        params, opt = adamw.adamw_update(grads, state.opt, state.params, lr,
                                         opt_cfg)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        metrics = {"loss": loss, "nll": parts["nll"], "aux": parts["aux"],
                   "grad_norm": gnorm, "lr": lr}
        return new_state.tree(), metrics

    return step
