"""Assigned input shapes (one set shared by all LM-family archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``); the others lower ``train_step`` / prefill.
``long_500k`` requires sub-quadratic sequence mixing and is runnable only
for the SSM/hybrid archs (DESIGN.md §6 records the skips).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Archs whose sequence mixing is sub-quadratic end-to-end (SSM / hybrid):
# only these run long_500k.
SUBQUADRATIC = ("jamba-v0.1-52b", "mamba2-370m")


def runnable(arch_id: str, shape: str) -> Tuple[bool, Optional[str]]:
    if shape == "long_500k" and arch_id not in SUBQUADRATIC:
        return False, ("full quadratic attention at 524k tokens; skipped per "
                       "assignment (see DESIGN.md §6)")
    return True, None


def cells(arch_ids):
    """All (arch, shape) dry-run cells with skip annotations."""
    out = []
    for a in arch_ids:
        for s in SHAPES:
            ok, why = runnable(a, s)
            out.append((a, s, ok, why))
    return out
