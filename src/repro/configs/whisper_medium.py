"""whisper-medium [audio]: enc-dec, conv frontend stubbed.

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865 [arXiv:2212.04356].
The audio frontend (2x conv) is a stub: input_specs() supplies precomputed
1500-frame embeddings, per the assignment."""
from repro.models.transformer import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", n_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=4096, vocab=51865, norm="layer", activation="gelu",
    qkv_bias=True, rope_theta=None,
    encoder=EncoderConfig(n_layers=24, n_ctx=1500),
    n_frontend_tokens=1500, compute_dtype="bfloat16")

SMOKE = ModelConfig(
    name="whisper-medium-smoke", n_layers=2, d_model=32, n_heads=4,
    n_kv_heads=4, d_ff=64, vocab=128, norm="layer", activation="gelu",
    qkv_bias=True, rope_theta=None,
    encoder=EncoderConfig(n_layers=2, n_ctx=12),
    n_frontend_tokens=12, compute_dtype="float32")
