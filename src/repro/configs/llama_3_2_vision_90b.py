"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — gated cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision]. Vision frontend stubbed:
input_specs() supplies precomputed patch embeddings (1601 tokens)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", n_layers=100, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=28672, vocab=128256,
    pattern=("cross", "attn", "attn", "attn", "attn"),
    n_frontend_tokens=1601, compute_dtype="bfloat16")

SMOKE = ModelConfig(
    name="llama-vision-smoke", n_layers=5, d_model=32, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=128,
    pattern=("cross", "attn", "attn", "attn", "attn"),
    n_frontend_tokens=9, compute_dtype="float32")
