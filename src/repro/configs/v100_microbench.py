"""The paper's own 'architecture': the V100 dissection configuration.

Selecting --arch v100-microbench runs the full ch.3/ch.4 dissection suite
against the V100-configured device model instead of lowering an LM."""
from repro.core import hwmodel

GPU = hwmodel.V100
PROBES = ("l1", "l2", "tlb", "latency_classes", "register_banks",
          "shared_memory", "constant_cache", "table_1_1", "table_2_1")
