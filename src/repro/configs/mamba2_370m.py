"""mamba2-370m [ssm]: 48L d_model=1024 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060].
Sub-quadratic: runs long_500k. d_ff=0: no separate MLP (the Mamba block
carries the gating)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=50280, pattern=("mamba",),
    mamba_d_state=128, mamba_head_dim=64, mamba_expand=2,
    compute_dtype="bfloat16")

SMOKE = ModelConfig(
    name="mamba2-370m-smoke", n_layers=2, d_model=32, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=128, pattern=("mamba",),
    mamba_d_state=8, mamba_head_dim=8, mamba_expand=2,
    compute_dtype="float32")
