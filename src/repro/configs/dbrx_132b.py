"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16e top-4 fine-grained [hf:databricks/dbrx-base]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, pattern=("attn",), moe_positions=(0,),
    n_experts=16, top_k=4, compute_dtype="bfloat16")

SMOKE = ModelConfig(
    name="dbrx-132b-smoke", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=128, pattern=("attn",), moe_positions=(0,),
    n_experts=4, top_k=2, moe_impl="dense_mask", compute_dtype="float32")
