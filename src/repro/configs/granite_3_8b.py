"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab=49155, rope_theta=10000.0, compute_dtype="bfloat16")

SMOKE = ModelConfig(
    name="granite-3-8b-smoke", n_layers=2, d_model=32, n_heads=4,
    n_kv_heads=2, d_ff=96, vocab=128, compute_dtype="float32")
