"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every other
layer [arXiv:2403.19887]. Sub-quadratic: runs long_500k."""
from repro.models.transformer import ModelConfig

_PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba",
            "mamba")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=14336, vocab=65536, pattern=_PATTERN,
    moe_positions=(1, 3, 5, 7), n_experts=16, top_k=2,
    mamba_d_state=16, mamba_head_dim=64, mamba_expand=2,
    compute_dtype="bfloat16")

SMOKE = ModelConfig(
    name="jamba-smoke", n_layers=8, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=128, pattern=_PATTERN, moe_positions=(1, 3, 5, 7),
    n_experts=4, top_k=2, moe_impl="dense_mask",
    mamba_d_state=8, mamba_head_dim=8, mamba_expand=2,
    compute_dtype="float32")
