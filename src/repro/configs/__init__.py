"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published configuration;
``get_smoke(name)`` a reduced same-family config for CPU smoke tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

ARCHS = [
    "whisper_medium",
    "qwen3_4b",
    "qwen2_0_5b",
    "granite_3_8b",
    "phi3_mini_3_8b",
    "dbrx_132b",
    "llama4_maverick_400b",
    "jamba_v0_1_52b",
    "llama_3_2_vision_90b",
    "mamba2_370m",
]

# CLI ids (--arch) use dashes, matching the assignment table.
ALIASES = {
    "whisper-medium": "whisper_medium",
    "qwen3-4b": "qwen3_4b",
    "qwen2-0.5b": "qwen2_0_5b",
    "granite-3-8b": "granite_3_8b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "mamba2-370m": "mamba2_370m",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def list_archs() -> List[str]:
    return list(ARCHS)


def canonical_id(name: str) -> str:
    for cli, mod in ALIASES.items():
        if mod == ALIASES.get(name, name).replace("-", "_").replace(".", "_"):
            return cli
    return name
