"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU MHA [arXiv:2404.14219]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32,
    n_kv_heads=32, d_ff=8192, vocab=32064, compute_dtype="bfloat16")

SMOKE = ModelConfig(
    name="phi3-mini-3.8b-smoke", n_layers=2, d_model=32, n_heads=4,
    n_kv_heads=4, d_ff=64, vocab=128, compute_dtype="float32")
