"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias [arXiv:2407.10671].

14 heads do not divide the 16-way model axis: the sharding divisibility
fallback replicates attention heads and shards d_ff (DESIGN.md §6)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151936, qkv_bias=True, rope_theta=1e6,
    compute_dtype="bfloat16")

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke", n_layers=2, d_model=28, n_heads=7, n_kv_heads=1,
    d_ff=64, vocab=128, qkv_bias=True, compute_dtype="float32")
