"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 + shared expert, alternating
dense/MoE layers [hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, d_ff=8192, vocab=202048,
    pattern=("attn", "attn"), moe_positions=(1,),
    n_experts=128, top_k=1, n_shared_experts=1, compute_dtype="bfloat16")

SMOKE = ModelConfig(
    name="llama4-maverick-smoke", n_layers=2, d_model=32, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=128, pattern=("attn", "attn"),
    moe_positions=(1,), n_experts=8, top_k=1, n_shared_experts=1,
    moe_impl="dense_mask", compute_dtype="float32")
