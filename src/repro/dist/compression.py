"""Gradient compression: symmetric int8 quantization with error feedback.

``int8_roundtrip`` is the wire format a compressed all-reduce would move:
per-leaf symmetric quantization to int8 with a single fp32 scale
(max|g| / 127), immediately dequantized.  The roundtrip error of any
element is bounded by scale/2, so the train step can use it as a drop-in
gradient transform (``make_train_step(compress_grads=True)``).

``ErrorFeedback`` is the standard EF-SGD residual accumulator: the
quantization error of step t is added back into the gradient at step t+1,
so compression bias does not accumulate over training.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize_leaf(g):
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0
    # All-zero leaves: keep scale finite, quantize to exact zeros.
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(g32 / safe), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * safe).astype(g.dtype)


def int8_roundtrip(grads: Any) -> Any:
    """Quantize every leaf to int8 and back.  |err| <= max|g|/254 per
    element (half an int8 step at the leaf's scale)."""
    return jax.tree.map(_quantize_leaf, grads)


class ErrorFeedback:
    """Residual error accumulator for compressed gradients.

    residual = ErrorFeedback.init(grads)           # zeros_like
    compressed, residual = ErrorFeedback.compress(grads, residual)

    ``compressed`` is the int8 roundtrip of ``grads + residual``; the new
    residual is exactly the quantization error, re-injected next step.
    """

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    @staticmethod
    def compress(grads: Any, residual: Any) -> Tuple[Any, Any]:
        corrected = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual)
        compressed = int8_roundtrip(corrected)
        new_residual = jax.tree.map(
            lambda c, q: c - q.astype(jnp.float32), corrected, compressed)
        return compressed, new_residual
