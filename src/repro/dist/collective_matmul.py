"""Overlapped all-gather matmul (collective matmul).

``x @ w`` where ``x`` is sharded along its contracting dim over one mesh
axis.  The naive SPMD lowering is ``all_gather(x) @ w`` — the full gather
must land before the first MAC issues.  Instead we run a shard_map ring:
each device multiplies the x-block it currently holds against the matching
row-block of ``w`` while collective-permuting the block to its neighbour,
so communication for step s+1 hides under the GEMM of step s (the
communication/computation-overlap structure the microbenchmark papers
measure on NVLink rings).  The compiled HLO therefore contains
``collective-permute`` ops and no entry-computation ``all-gather`` — which
``tests/test_sharding_dist.py`` asserts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def ag_matmul(x, w, mesh, axis: str = "model"):
    """Compute ``x @ w`` with the all-gather of ``x`` replaced by an
    overlapped collective-permute ring over mesh ``axis``.

    x: (m, k) sharded (k over ``axis``); w: (k, n) replicated; out: (m, n)
    replicated.  Falls back to a plain matmul when the axis is trivial or
    k doesn't divide it (the same divisibility fallback the sharding rules
    apply).
    """
    n_shards = int(dict(mesh.shape)[axis])
    k = x.shape[-1]
    if n_shards == 1 or k % n_shards:
        return x @ w
    k_block = k // n_shards
    perm = [(j, (j + 1) % n_shards) for j in range(n_shards)]

    def ring(x_block, w_full):
        # x_block: (m, k_block) — this device's current block of x columns.
        # Device i starts with block i; after s permutes it holds block
        # (i - s) mod n, which contracts against w rows [(i-s)*kb, ...).
        i = jax.lax.axis_index(axis)
        acc = jnp.zeros((x_block.shape[0], w_full.shape[-1]),
                        jnp.promote_types(x_block.dtype, w_full.dtype))
        block = x_block
        for s in range(n_shards):
            src = (i - s) % n_shards
            # Issue the permute before the GEMM so XLA can overlap them.
            nxt = (jax.lax.ppermute(block, axis, perm)
                   if s + 1 < n_shards else None)
            w_block = jax.lax.dynamic_slice_in_dim(
                w_full, src * k_block, k_block, axis=0)
            acc = acc + block @ w_block
            if nxt is not None:
                block = nxt
        return acc

    fn = shard_map(ring, mesh=mesh,
                   in_specs=(P(None, axis), P(None, None)),
                   out_specs=P(None, None), check_rep=False)
    return fn(x, w)


def rs_matmul(x, w, mesh, axis: str = "model"):
    """``x @ w`` as a psum-scatter ring: the reduce–scatter dual of
    ``ag_matmul``.

    x: (m, k) sharded (k over ``axis``); w: (k, n) replicated; out:
    (m, n) sharded (n over ``axis``). Where ``ag_matmul`` circulates the
    *inputs* so every device ends with the full product, this ring
    circulates the *partial sums*: device i contributes its
    ``x_block @ w_block`` slice into the accumulator destined for each
    output column block as it passes by, so after n-1 hops device i
    holds output block i, fully reduced. Same overlap structure
    (permute hides under the GEMM), half the resident output — the
    variant MoE dispatch wants, where the next op consumes the output
    already sharded. Falls back to a plain matmul (replicated out) when
    the axis is trivial or k or n doesn't divide it.
    """
    n_shards = int(dict(mesh.shape)[axis])
    m, k = x.shape
    n = w.shape[-1]
    if n_shards == 1 or k % n_shards or n % n_shards:
        return x @ w
    k_block = k // n_shards
    n_block = n // n_shards
    perm = [(j, (j + 1) % n_shards) for j in range(n_shards)]

    def ring(x_block, w_full):
        # The accumulator at device i at step s is destined for output
        # block (i - 1 - s) mod n: each device folds in its contribution
        # for that block, permutes the partial forward, and after the
        # final (unpermuted) step holds its own block, fully reduced.
        i = jax.lax.axis_index(axis)
        acc = jnp.zeros((m, n_block),
                        jnp.promote_types(x_block.dtype, w_full.dtype))
        for s in range(n_shards):
            dest = (i - 1 - s) % n_shards
            w_block = jax.lax.dynamic_slice(
                w_full, (i * k_block, dest * n_block), (k_block, n_block))
            acc = acc + x_block @ w_block
            if s + 1 < n_shards:
                acc = jax.lax.ppermute(acc, axis, perm)
        return acc

    fn = shard_map(ring, mesh=mesh,
                   in_specs=(P(None, axis), P(None, None)),
                   out_specs=P(None, axis), check_rep=False)
    return fn(x, w)


def serve_unembed(mesh, axis: str = "model"):
    """Serving entry point: an ``unembed_fn`` for ``models.transformer.
    forward`` that routes the decode/verify logit matmul — the single
    biggest GEMM on the serving path, (slots·width, d_model) x
    (d_model, vocab) — through the overlapped ``ag_matmul`` ring instead
    of the naive all-gather lowering. Output logits stay replicated, so
    the engine's sampling and stream bookkeeping are unchanged."""

    def unembed_fn(unembed_params, x):
        w = unembed_params["lm_head"].astype(x.dtype)
        b, s, d = x.shape
        out = ag_matmul(x.reshape(b * s, d), w, mesh, axis=axis)
        return out.reshape(b, s, w.shape[-1])

    return unembed_fn
