"""Distribution layer: sharding rules, gradient compression, overlapped
collectives, and pipeline parallelism.

Submodules (see README.md in this directory for the full API):

* ``sharding``          — logical-axis rulesets, param specs, activation
                          annotation (``shard``) and ``use_ruleset``.
* ``compression``       — int8 gradient quantization + error feedback.
* ``collective_matmul`` — all-gather matmul as an overlapped
                          collective-permute ring (``ag_matmul``).
* ``pipeline``          — GPipe transform over a mesh axis (``gpipe``) and
                          ``bubble_fraction``.
"""

from repro.dist import (collective_matmul, compression, pipeline,  # noqa
                        sharding)
