"""GPipe pipeline parallelism over one mesh axis.

``gpipe(layer, mesh, axis)`` turns a per-stage ``layer(weights, x)`` into
a pipelined function over stage-stacked weights and a leading microbatch
dim: stage i (one device along ``axis``) holds its own weights, processes
microbatch t-i at tick t, and hands its activation to stage i+1 via
``collective_permute`` — the classic GPipe schedule with
(stages-1)/(microbatches+stages-1) bubble overhead (``bubble_fraction``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def bubble_fraction(stages: int, microbatches: int) -> float:
    """Fraction of stage-ticks idle in one GPipe forward sweep."""
    return (stages - 1) / (microbatches + stages - 1)


def gpipe(layer, mesh, axis: str = "stage"):
    """Pipeline ``layer`` over mesh ``axis``.

    Returns ``fn(weights, micro)`` where every ``weights`` leaf has a
    leading stage dim equal to the axis size and ``micro`` is
    (microbatches, *sample_shape).  Output == applying the stages
    sequentially to every microbatch; the schedule runs
    microbatches + stages - 1 ticks with activations ring-permuted between
    stages each tick.
    """
    n_stages = int(dict(mesh.shape)[axis])
    perm = [(j, (j + 1) % n_stages) for j in range(n_stages)]

    def transform(weights, micro):
        for leaf in jax.tree.leaves(weights):
            if leaf.shape[0] != n_stages:
                raise ValueError(f"stage dim {leaf.shape[0]} != mesh "
                                 f"axis {axis}={n_stages}")
        n_micro = micro.shape[0]

        def run(w_block, mb):
            i = jax.lax.axis_index(axis)
            w = jax.tree.map(lambda a: a[0], w_block)   # this stage's slice
            state = jnp.zeros(mb.shape[1:], mb.dtype)   # input from stage i-1
            out = jnp.zeros_like(mb)
            for t in range(n_micro + n_stages - 1):
                # Stage 0 feeds fresh microbatches; later stages consume the
                # permuted activation.  Ticks outside a stage's window do
                # masked-out throwaway work (the pipeline bubble).
                feed = mb[min(t, n_micro - 1)]
                y = layer(w, jnp.where(i == 0, feed, state))
                done = t - (n_stages - 1)               # microbatch leaving
                if 0 <= done < n_micro:
                    out = out.at[done].set(
                        jnp.where(i == n_stages - 1, y, out[done]))
                state = jax.lax.ppermute(y, axis, perm)
            # Only the last stage wrote; psum replicates its result.
            return jax.lax.psum(out, axis)

        w_specs = jax.tree.map(
            lambda a: P(axis, *([None] * (a.ndim - 1))), weights)
        fn = shard_map(run, mesh=mesh,
                       in_specs=(w_specs, P(*([None] * micro.ndim))),
                       out_specs=P(*([None] * micro.ndim)), check_rep=False)
        return fn(weights, micro)

    return transform
