"""Sharding rules: logical axis names -> mesh axes, with divisibility
fallback.

The whole model/trainer/server stack names its tensor dimensions with
*logical* axes ("batch", "heads", "mlp", ...).  A :class:`Ruleset` maps
those names onto the axes of whatever mesh is active, replicating any
dimension whose size does not divide the target mesh axes — so the same
model code runs unmodified on 1 chip, a 16x16 pod, or a 2x16x16 multi-pod
mesh, and a config whose head count doesn't divide the model axis simply
replicates those heads instead of failing to lower.

Three entry points:

* ``ruleset.spec(names, shapes)`` — activation/batch specs.
* ``param_spec(path, shape, ruleset)`` — parameter specs driven by the leaf
  name (``_LEAF_NAMES``), with optional FSDP over the "data" axis.
* ``shard(x, *names)`` — annotates an activation with the ambient ruleset
  installed by ``use_ruleset``; a no-op outside a mesh context, so layer
  code never branches on distribution.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# Logical axis -> mesh axis (or tuple of axes, composed left-to-right).
# ``None`` means always replicate.  Overridable per-Ruleset via ``rules=``
# (e.g. the dry-run's sequence-parallel cache: {"cache_seq": "data"}).
_DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "cache_seq": None,
    "embed": None,
    "head_dim": None,
    "heads": "model",
    "kv_heads": "model",
    "ssm_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_capacity": None,
    "stage": "stage",
    # Serving: the paged KV pool shards over its page dim (serve.dist) —
    # pages, not slots, are the shard unit, so one slot's table can span
    # devices and pool capacity scales with the mesh.
    "kv_pages": "model",
}

# Parameter leaf name -> logical names of its *trailing* dims.  Leading
# extra dims (the lax.scan period-stacking in models/transformer.py) are
# replicated.  Leaves not listed here (norm scales, biases, scalars)
# replicate, modulo FSDP.
_LEAF_NAMES: Dict[str, Tuple[Optional[str], ...]] = {
    # attention (layers.py): 3D weights keep true head counts visible.
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "b_q": ("heads", "head_dim"),
    "b_k": ("kv_heads", "head_dim"),
    "b_v": ("kv_heads", "head_dim"),
    # mlp
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "b_up": ("mlp",),
    "w_down": ("mlp", "embed"),
    # embeddings
    "embedding": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    # moe (moe.py): expert dim first; inner dims replicate because "model"
    # is already consumed by the expert-parallel axis.
    "router": ("embed", "experts"),
    "expert_gate": ("experts", "embed", "mlp"),
    "expert_up": ("experts", "embed", "mlp"),
    "expert_down": ("experts", "mlp", "embed"),
    # mamba (mamba.py)
    "w_x": ("embed", "ssm_heads", "head_dim"),
    "w_z": ("embed", "ssm_heads", "head_dim"),
    "w_B": ("embed", None),
    "w_C": ("embed", None),
    "w_dt": ("embed", "ssm_heads"),
    "dt_bias": ("ssm_heads",),
    "A_log": ("ssm_heads",),
    "D": ("ssm_heads",),
    "conv_w": (None, "ssm_heads", "head_dim"),
    "w_ssm_out": ("ssm_heads", "head_dim", "embed"),
}

# FSDP only pays for itself on large leaves; sharding every norm scale
# just adds gather latency.
_FSDP_MIN_ELEMENTS = 1 << 16


@dataclasses.dataclass
class Ruleset:
    """Sharding rules bound to a mesh.

    mesh:  a jax Mesh (or any object with a ``.shape`` mapping of axis name
           -> size; tests use a stub).  ``None`` disables sharding.
    rules: overrides merged over ``_DEFAULT_RULES``.
    fsdp:  additionally shard each large parameter's largest replicated dim
           over the "data" axis (ZeRO-3-style; train-time only in practice).
    """

    mesh: Any = None
    rules: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    fsdp: bool = False

    def _rule(self, name: Optional[str]):
        if name is None:
            return None
        if name in self.rules:
            return self.rules[name]
        return _DEFAULT_RULES.get(name)

    def _axis_for(self, name: Optional[str], dim: Optional[int], used: set):
        """Resolve one logical dim to mesh axes, with divisibility fallback:
        try the full composed axis tuple, then progressively drop the
        outermost axis, then replicate."""
        target = self._rule(name)
        if target is None or self.mesh is None:
            return None
        axes = (target,) if isinstance(target, str) else tuple(target)
        sizes = dict(self.mesh.shape)
        axes = tuple(a for a in axes
                     if a in sizes and sizes[a] > 1 and a not in used)
        while axes:
            prod = int(np.prod([sizes[a] for a in axes]))
            if dim is not None and dim % prod == 0:
                used.update(axes)
                return axes if len(axes) > 1 else axes[0]
            axes = axes[1:]
        return None

    def spec(self, names: Sequence[Optional[str]],
             shapes: Sequence[Optional[int]]) -> P:
        """PartitionSpec for a tensor whose dims carry logical ``names``.
        Each mesh axis is used at most once; non-divisible dims replicate."""
        used: set = set()
        return P(*[self._axis_for(n, d, used)
                   for n, d in zip(names, shapes)])


def param_spec(path: Sequence[Any], shape: Sequence[int],
               ruleset: Ruleset) -> P:
    """PartitionSpec for a parameter leaf, keyed on its pytree leaf name.

    ``path`` is the tuple of pytree keys (strings); only the last entry is
    consulted, so optimizer-state mirrors ({"m": params, ...}) and the
    scan-stacked "blocks" subtree resolve identically to the raw params.
    With ``ruleset.fsdp`` the largest still-replicated divisible dim of any
    large leaf is additionally sharded over "data".
    """
    leaf = str(path[-1]) if len(path) else ""
    names = _LEAF_NAMES.get(leaf, ())
    names = names[-len(shape):] if len(shape) < len(names) else names
    names = (None,) * (len(shape) - len(names)) + tuple(names)
    used: set = set()
    parts = [ruleset._axis_for(n, d, used) for n, d in zip(names, shape)]
    if ruleset.fsdp and ruleset.mesh is not None and "data" not in used:
        sizes = dict(ruleset.mesh.shape)
        data = sizes.get("data", 1)
        if data > 1 and int(np.prod(shape or [1])) >= _FSDP_MIN_ELEMENTS:
            free = sorted((i for i, p in enumerate(parts) if p is None),
                          key=lambda i: -shape[i])
            for i in free:
                if shape[i] % data == 0:
                    parts[i] = "data"
                    break
    return P(*parts)


# ----------------------------------------------------------------------------
# Ambient ruleset context (thread-local, re-entrant)
# ----------------------------------------------------------------------------

_ACTIVE = threading.local()


def current_ruleset() -> Optional[Ruleset]:
    return getattr(_ACTIVE, "ruleset", None)


@contextlib.contextmanager
def use_ruleset(ruleset: Optional[Ruleset]):
    """Install ``ruleset`` as the ambient target of ``shard``.  Passing
    ``None`` (no mesh configured) is allowed and leaves ``shard`` a no-op."""
    prev = current_ruleset()
    _ACTIVE.ruleset = ruleset
    try:
        yield ruleset
    finally:
        _ACTIVE.ruleset = prev


def shard(x, *names: Optional[str]):
    """Annotate activation ``x`` with the ambient ruleset's spec for
    ``names`` (one logical name, or None, per dim).  Outside a
    ``use_ruleset`` context — or with a mesh-less ruleset — returns ``x``
    unchanged, so model code is distribution-agnostic."""
    ruleset = current_ruleset()
    if ruleset is None or ruleset.mesh is None:
        return x
    spec = ruleset.spec(names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ruleset.mesh, spec))
