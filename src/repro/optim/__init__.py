from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,  # noqa
                               clip_by_global_norm, global_norm)
from repro.optim.schedule import ScheduleConfig, learning_rate  # noqa: F401
