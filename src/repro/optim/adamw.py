"""AdamW, pure JAX (no optax dependency), with global-norm clipping.

The optimizer state is a pytree mirroring the params (m, v) plus a scalar
count — FSDP shards it with the same specs as the parameters, which is what
makes the ZeRO memory math work at scale.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state: dict, params, lr,
                 cfg: AdamWConfig = AdamWConfig()) -> Tuple[Any, dict]:
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    new_m, new_v, new_p = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m2, v2, p2 = upd(g, m, v, p)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(p2)
    return (treedef.unflatten(new_p),
            {"m": treedef.unflatten(new_m), "v": treedef.unflatten(new_v),
             "count": count})
