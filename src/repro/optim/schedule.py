"""Learning-rate schedules (warmup + cosine/linear decay), pure functions."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    # Defaults match the launcher's smoke-scale flags (launch/train.py):
    # short warmup so <100-step smoke/integration runs actually leave the
    # warmup ramp and learn.  Production runs pass explicit values.
    peak_lr: float = 3e-3
    warmup_steps: int = 20
    total_steps: int = 10000
    min_ratio: float = 0.1
    kind: str = "cosine"        # "cosine" | "linear" | "constant"


def learning_rate(step, cfg: ScheduleConfig):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.kind == "constant":
        return warm
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.kind == "linear":
        decay = 1.0 - (1.0 - cfg.min_ratio) * frac
    else:
        decay = cfg.min_ratio + (1.0 - cfg.min_ratio) * 0.5 * (
            1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * decay)
