"""Mixture-of-Experts layers: token-choice top-k routing.

Two execution paths with identical math (tested against each other):

* ``dense_mask`` — loop over experts masking tokens. Simple and exact;
  compute scales with n_experts, so it is the small-config/reference path.

* ``capacity`` — sort-based capacity dispatch (production path): flatten
  (token, expert) assignments, sort by expert, take position-in-expert ranks,
  scatter into an (experts, capacity, d) buffer, run batched expert GEMMs,
  scatter back weighted. O(tokens * k) memory, no (T, E, C) one-hot. Under
  SPMD the buffer's expert dim is sharded over "model" (expert parallelism);
  GSPMD materializes the token->expert exchange as collectives, which the
  roofline's collective term prices (hillclimb #2 targets exactly these).

Includes an optional shared expert (DeepSeek/llama4 style) and an auxiliary
load-balancing loss (Switch-style), returned for the trainer to add.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding
from repro.models import layers

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                   # per-expert hidden dim
    n_experts: int
    top_k: int
    n_shared: int = 0           # shared (always-on) experts
    capacity_factor: float = 1.25
    impl: str = "dense_mask"    # "dense_mask" | "capacity"
    router_dtype: Any = jnp.float32


def moe_init(key, cfg: MoEConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": layers._init(ks[0], (d, e), scale=0.02),
        "expert_gate": layers._init(ks[1], (e, d, f)),
        "expert_up": layers._init(ks[2], (e, d, f)),
        "expert_down": layers._init(ks[3], (e, f, d), scale=1.0 / np.sqrt(f)),
    }
    if cfg.n_shared:
        p["shared"] = layers.mlp_init(
            ks[4], layers.MLPConfig(d, f * cfg.n_shared, "swiglu"))
    return p


def _route(params: Params, cfg: MoEConfig, x):
    """Router logits -> (weights, ids, aux_loss). x: (T, d)."""
    logits = (x.astype(cfg.router_dtype)
              @ params["router"].astype(cfg.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)          # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * p_e.
    t = x.shape[0]
    density = jnp.zeros(cfg.n_experts).at[ids.reshape(-1)].add(1.0) / (
        t * cfg.top_k)
    mean_prob = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(density * mean_prob)
    return weights.astype(x.dtype), ids, aux


def _expert_ffn(params: Params, x_e):
    """Batched per-expert SwiGLU. x_e: (E, C, d) -> (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", x_e, params["expert_gate"].astype(x_e.dtype))
    u = jnp.einsum("ecd,edf->ecf", x_e, params["expert_up"].astype(x_e.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, params["expert_down"].astype(x_e.dtype))


def _moe_dense_mask(params: Params, cfg: MoEConfig, x2):
    """Reference path: every expert sees every token, masked by gate."""
    weights, ids, aux = _route(params, cfg, x2)
    gates = jnp.zeros((x2.shape[0], cfg.n_experts), x2.dtype)
    gates = gates.at[jnp.arange(x2.shape[0])[:, None], ids].add(weights)

    def one_expert(e, acc):
        g = jnp.einsum("td,df->tf", x2,
                       params["expert_gate"][e].astype(x2.dtype))
        u = jnp.einsum("td,df->tf", x2,
                       params["expert_up"][e].astype(x2.dtype))
        h = jax.nn.silu(g) * u
        y = jnp.einsum("tf,fd->td", h,
                       params["expert_down"][e].astype(x2.dtype))
        gate_e = jax.lax.dynamic_slice_in_dim(gates, e, 1, axis=1)
        return acc + gate_e * y

    out = jax.lax.fori_loop(0, cfg.n_experts, one_expert,
                            jnp.zeros_like(x2))
    return out, aux


def _moe_capacity(params: Params, cfg: MoEConfig, x2):
    """Production path: sort-based capacity dispatch."""
    t, d = x2.shape
    weights, ids, aux = _route(params, cfg, x2)
    e, k = cfg.n_experts, cfg.top_k
    capacity = int(np.ceil(t * k / e * cfg.capacity_factor))
    capacity = max(capacity, 4)

    flat_ids = ids.reshape(-1)                              # (T*k,)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_ids)                           # stable
    sorted_ids = flat_ids[order]
    # Rank within expert: index minus first occurrence of this expert.
    first = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    rank = jnp.arange(t * k) - first
    keep = rank < capacity
    dest = jnp.where(keep, sorted_ids * capacity + rank, e * capacity)
    src_token = order // k

    buf = jnp.zeros((e * capacity + 1, d), x2.dtype)
    buf = buf.at[dest].set(x2[src_token], mode="drop")
    x_e = buf[:-1].reshape(e, capacity, d)
    x_e = sharding.shard(x_e, "experts", "expert_capacity", "embed")
    y_e = _expert_ffn(params, x_e)
    y_e = sharding.shard(y_e, "experts", "expert_capacity", "embed")

    y_flat = y_e.reshape(e * capacity, d)
    gathered = jnp.where(keep[:, None],
                         y_flat[jnp.clip(dest, 0, e * capacity - 1)], 0.0)
    out = jnp.zeros_like(x2)
    out = out.at[src_token].add(gathered * flat_w[order][:, None]
                                .astype(x2.dtype))
    return out, aux


def moe_apply(params: Params, cfg: MoEConfig, x) -> Tuple[Any, Any]:
    """x: (b, s, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    if cfg.impl == "capacity":
        out, aux = _moe_capacity(params, cfg, x2)
    else:
        out, aux = _moe_dense_mask(params, cfg, x2)
    if cfg.n_shared:
        shared_cfg = layers.MLPConfig(cfg.d_model, cfg.d_ff * cfg.n_shared,
                                      "swiglu")
        out = out + layers.mlp_apply(params["shared"], shared_cfg,
                                     x2[None]).reshape(b * s, d)
    return out.reshape(b, s, d), aux
