"""Transformer stacks: decoder LMs, pattern-interleaved hybrids (Jamba,
llama4, llama-vision) and encoder-decoder (Whisper) — one implementation.

Layer stacks are expressed as a repeating *pattern* of layer kinds
(("attn",), ("mamba",)*4+("attn",)+..., ("cross","attn","attn","attn","attn")).
Parameters are stacked per pattern position with a leading period dim and the
stack runs under ``lax.scan`` — 100-layer models lower as one period body, so
the 512-device dry-run compiles in seconds instead of minutes. Decode caches
are pytrees stacked the same way and threaded through the scan.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding
from repro.models import layers, mamba as mamba_mod, moe as moe_mod

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    n_ctx: int                   # frontend tokens (whisper: 1500 frames)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    norm: str = "rms"            # "rms" | "layer"
    activation: str = "swiglu"   # "swiglu" | "gelu"
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: Optional[float] = 10000.0
    pattern: Tuple[str, ...] = ("attn",)
    moe_positions: Tuple[int, ...] = ()      # pattern positions with MoE MLP
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_impl: str = "capacity"
    moe_capacity_factor: float = 1.25
    mamba_d_state: int = 128
    mamba_head_dim: int = 64
    mamba_expand: int = 2
    encoder: Optional[EncoderConfig] = None  # enc-dec (whisper)
    n_frontend_tokens: int = 0               # vision/audio stub tokens
    scan_layers: bool = True
    compute_dtype: str = "float32"
    use_flash: bool = False
    use_ssd_kernel: bool = False
    expand_kv: bool = False      # GQA KV broadcast for model-axis sharding
    attn_probs_fp32: bool = True # bf16 probs = beyond-paper memory opt
    remat: bool = False
    remat_policy: str = "full"   # "full" | "dots" (save matmul outputs)

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            (self.name, self.n_layers, self.pattern)

    @property
    def dhead(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    def attn_cfg(self, causal=True) -> layers.AttnConfig:
        return layers.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.dhead,
            qk_norm=self.qk_norm, qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta, causal=causal,
            expand_kv=self.expand_kv, probs_fp32=self.attn_probs_fp32)

    def mamba_cfg(self) -> mamba_mod.MambaConfig:
        return mamba_mod.MambaConfig(
            d_model=self.d_model, d_state=self.mamba_d_state,
            head_dim=self.mamba_head_dim, expand=self.mamba_expand)

    def moe_cfg(self) -> moe_mod.MoEConfig:
        return moe_mod.MoEConfig(
            d_model=self.d_model, d_ff=self.d_ff, n_experts=self.n_experts,
            top_k=self.top_k, n_shared=self.n_shared_experts,
            impl=self.moe_impl, capacity_factor=self.moe_capacity_factor)

    def mlp_cfg(self) -> layers.MLPConfig:
        return layers.MLPConfig(self.d_model, self.d_ff, self.activation)


# ----------------------------------------------------------------------------
# Per-layer init/apply
# ----------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, kind: str, pos: int) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": layers.norm_init(cfg.norm, cfg.d_model)}
    if kind == "attn":
        p["attn"] = layers.attention_init(ks[0], cfg.attn_cfg())
    elif kind == "mamba":
        p["mamba"] = mamba_mod.mamba_init(ks[0], cfg.mamba_cfg())
    elif kind == "cross":
        p["attn"] = layers.attention_init(ks[0], cfg.attn_cfg())
        p["ln_x"] = layers.norm_init(cfg.norm, cfg.d_model)
        p["xattn"] = layers.cross_attention_init(
            ks[1], cfg.attn_cfg(causal=False))
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0:
        p["ln2"] = layers.norm_init(cfg.norm, cfg.d_model)
        if pos in cfg.moe_positions and cfg.n_experts:
            p["moe"] = moe_mod.moe_init(ks[2], cfg.moe_cfg())
        else:
            p["mlp"] = layers.mlp_init(ks[2], cfg.mlp_cfg())
    return p


def _layer_apply(params: Params, cfg: ModelConfig, kind: str, x,
                 cross_kv=None, cache=None):
    """One block: mixer + (dense|MoE) MLP, pre-norm residual."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.norm(cfg.norm, params["ln1"], x)
    if kind == "mamba":
        mix, new_cache = mamba_mod.mamba_apply(
            params["mamba"], cfg.mamba_cfg(), h, cache=cache,
            use_kernel=cfg.use_ssd_kernel)
    else:
        mix, new_cache = layers.attention_apply(
            params["attn"], cfg.attn_cfg(), h, cache=cache,
            use_flash=cfg.use_flash)
    x = x + mix
    if kind == "cross":
        hx = layers.norm(cfg.norm, params["ln_x"], x)
        x = x + layers.cross_attention_apply(
            params["xattn"], cfg.attn_cfg(causal=False), hx,
            cross_kv.astype(x.dtype))
    if "moe" in params:
        h2 = layers.norm(cfg.norm, params["ln2"], x)
        y, aux = moe_mod.moe_apply(params["moe"], cfg.moe_cfg(), h2)
        x = x + y
    elif "mlp" in params:
        h2 = layers.norm(cfg.norm, params["ln2"], x)
        x = x + layers.mlp_apply(params["mlp"], cfg.mlp_cfg(), h2)
    return x, new_cache, aux


# ----------------------------------------------------------------------------
# Stacks (pattern scan)
# ----------------------------------------------------------------------------

def _stack_init(key, cfg: ModelConfig) -> List[Params]:
    """Per pattern position: params stacked over periods (leading dim)."""
    blocks = []
    for pos, kind in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(key, pos), cfg.periods)
        per_period = [_layer_init(k, cfg, kind, pos) for k in keys]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_period))
    return blocks


def _stack_apply(blocks: List[Params], cfg: ModelConfig, x, cross_kv=None,
                 caches: Optional[List[Any]] = None):
    """Run the full stack; scan over periods."""

    def period_body(carry, xs):
        x, aux = carry
        block_slices, cache_slices = xs
        new_caches = []
        for pos, kind in enumerate(cfg.pattern):
            cache = cache_slices[pos] if cache_slices is not None else None
            x, nc, a = _layer_apply(block_slices[pos], cfg, kind, x,
                                    cross_kv=cross_kv, cache=cache)
            new_caches.append(nc)
            aux = aux + a
        ys = tuple(new_caches) if cache_slices is not None else None
        return (x, aux), ys

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_saveable
                  if cfg.remat_policy == "dots" else None)
        period_body = jax.checkpoint(period_body, prevent_cse=False,
                                     policy=policy)

    aux0 = jnp.zeros((), jnp.float32)
    xs = (tuple(blocks), tuple(caches) if caches is not None else None)
    if cfg.scan_layers:
        if caches is None:
            (x, aux), _ = jax.lax.scan(
                lambda c, b: period_body(c, (b, None)), (x, aux0),
                tuple(blocks))
            return x, None, aux
        (x, aux), new_caches = jax.lax.scan(period_body, (x, aux0), xs)
        return x, list(new_caches), aux
    # Unrolled path (small configs / debugging).
    aux = aux0
    new_caches: List[Any] = []
    for period in range(cfg.periods):
        block_slices = [jax.tree.map(lambda a: a[period], b) for b in blocks]
        cache_slices = ([jax.tree.map(lambda a: a[period], c)
                         for c in caches] if caches is not None else None)
        (x, aux), ys = period_body(
            (x, aux), (tuple(block_slices),
                       tuple(cache_slices) if cache_slices else None))
        if ys is not None:
            new_caches.append(ys)
    if caches is None:
        return x, None, aux
    stacked = [jax.tree.map(lambda *zs: jnp.stack(zs),
                            *[nc[pos] for nc in new_caches])
               for pos in range(len(cfg.pattern))]
    return x, stacked, aux


# ----------------------------------------------------------------------------
# Whole models
# ----------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "embed": layers.embedding_init(ks[0], cfg.vocab, cfg.d_model),
        "blocks": _stack_init(ks[1], cfg),
        "ln_f": layers.norm_init(cfg.norm, cfg.d_model),
        "unembed": layers.unembed_init(ks[2], cfg.d_model, cfg.vocab),
    }
    if cfg.encoder is not None:
        enc_cfg = dataclasses.replace(
            cfg, n_layers=cfg.encoder.n_layers, pattern=("attn",),
            moe_positions=(), rope_theta=None, name=cfg.name + "-encoder")
        p["encoder"] = {
            "blocks": _enc_stack_init(ks[3], enc_cfg),
            "ln_f": layers.norm_init(cfg.norm, cfg.d_model),
        }
    return p


def _enc_stack_init(key, enc_cfg: ModelConfig) -> List[Params]:
    # Encoder layers are non-causal attention blocks.
    return _stack_init(key, enc_cfg)


def encode(params: Params, cfg: ModelConfig, frontend_embeds):
    """Run the (whisper) encoder over precomputed frontend embeddings."""
    enc_cfg = dataclasses.replace(
        cfg, n_layers=cfg.encoder.n_layers, pattern=("attn",),
        moe_positions=(), rope_theta=None, name=cfg.name + "-encoder")
    x = frontend_embeds.astype(cfg.dtype)
    pos = layers.sinusoidal_positions(x.shape[1], cfg.d_model)
    x = x + pos[None].astype(x.dtype)
    enc_cfg_nc = dataclasses.replace(enc_cfg)
    # Non-causal: patch the attention config through a causal=False pattern.
    x, _, _ = _stack_apply_noncausal(params["encoder"]["blocks"], enc_cfg_nc, x)
    return layers.norm(cfg.norm, params["encoder"]["ln_f"], x)


def _stack_apply_noncausal(blocks, cfg: ModelConfig, x):
    noncausal = dataclasses.replace(cfg, rope_theta=None)

    def body(carry, block):
        x, _ = carry
        h = layers.norm(noncausal.norm, block["ln1"], x)
        mix, _ = layers.attention_apply(
            block["attn"], noncausal.attn_cfg(causal=False), h)
        x = x + mix
        h2 = layers.norm(noncausal.norm, block["ln2"], x)
        x = x + layers.mlp_apply(block["mlp"], noncausal.mlp_cfg(), h2)
        return (x, carry[1]), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             blocks[0])
    return x, None, jnp.zeros((), jnp.float32)


def forward(params: Params, cfg: ModelConfig, tokens,
            frontend_embeds=None, caches=None, positions=None,
            cross_kv=None,
            unembed_fn=None) -> Tuple[Any, Optional[List[Any]], Any]:
    """Forward pass -> (logits, new_caches, aux_loss).

    ``frontend_embeds``: encoder input (whisper) or cross-attention source
    (vision); stubbed modality frontends provide it precomputed.
    ``cross_kv``: precomputed encoder output — serving passes it so decode
    steps do not re-run the encoder.
    ``unembed_fn``: override for the final logit matmul — the sharded
    serving engine routes it through the overlapped collective ring
    (``dist.collective_matmul.serve_unembed``); ``None`` keeps the plain
    ``layers.unembed``.
    """
    x = layers.embed(params["embed"], tokens, cfg.dtype)
    if cross_kv is not None:
        cross_kv = cross_kv.astype(cfg.dtype)
    elif cfg.encoder is not None:
        cross_kv = encode(params, cfg, frontend_embeds)
    elif cfg.n_frontend_tokens:
        cross_kv = frontend_embeds.astype(cfg.dtype)
    if cfg.rope_theta is None:
        # Sinusoidal absolute positions (whisper decoder), computed on the
        # fly so long-context decode does not embed a giant constant table.
        start = caches_index(caches) if caches is not None else 0
        idx = start + jnp.arange(tokens.shape[1])
        d = cfg.d_model
        dim = jnp.arange(d // 2, dtype=jnp.float32)
        angle = idx[:, None].astype(jnp.float32) / jnp.power(
            10000.0, 2 * dim / d)[None, :]
        pos = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
        x = x + pos[None].astype(x.dtype)
    x, new_caches, aux = _stack_apply(params["blocks"], cfg, x,
                                      cross_kv=cross_kv, caches=caches)
    x = layers.norm(cfg.norm, params["ln_f"], x)
    logits = (unembed_fn or layers.unembed)(params["unembed"], x)
    return logits, new_caches, aux


def caches_index(caches) -> Any:
    """Current decode position from any layer cache."""
    leaf = caches[0]
    if isinstance(leaf, dict) and "index" in leaf:
        idx = leaf["index"]
    else:
        idx = leaf["index"] if "index" in leaf else 0
    return idx.reshape(-1)[0] if hasattr(idx, "reshape") else idx


def cache_lengths(caches) -> Any:
    """Per-slot valid KV lengths, shape (batch,).

    With ``per_slot_index=True`` caches the index leaf is (periods, batch)
    and every period carries the same value; scalar-index caches
    ((periods,)-shaped leaf) broadcast their position over the batch read
    off a data leaf. This is the lengths vector the flash-decode kernel
    scalar-prefetches.
    """
    c0 = caches[0]
    idx = c0["index"]
    if idx.ndim == 2:
        return idx[0]
    batch = next(v for k, v in c0.items() if k != "index").shape[1]
    return jnp.full((batch,), idx[0], idx.dtype)


def set_cache_lengths(caches, lengths) -> List[Any]:
    """Overwrite every layer's write position (e.g. after a padded bucketed
    prefill, where the true prompt length is shorter than the bucket)."""
    out = []
    for c in caches:
        c = dict(c)
        c["index"] = jnp.broadcast_to(
            jnp.asarray(lengths, c["index"].dtype), c["index"].shape)
        out.append(c)
    return out


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=None, per_slot_index: bool = False) -> List[Any]:
    """Stacked decode caches aligned with pattern positions.

    ``per_slot_index=True`` gives each batch slot its own write position
    (continuous batching in ``serve.engine``)."""
    dtype = dtype or cfg.dtype
    idx0 = (jnp.zeros((batch,), jnp.int32) if per_slot_index
            else jnp.zeros((), jnp.int32))
    caches = []
    for pos, kind in enumerate(cfg.pattern):
        if kind in ("attn", "cross"):
            c = {
                "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.dhead),
                               dtype),
                "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.dhead),
                               dtype),
                "index": idx0,
            }
        elif kind == "mamba":
            c = mamba_mod.init_cache(cfg.mamba_cfg(), batch, dtype)
            c["index"] = idx0
        else:
            raise ValueError(kind)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.periods,) + a.shape),
            c)
        caches.append(stacked)
    return caches


def init_paged_caches(cfg: ModelConfig, batch: int, max_len: int,
                      page_size: int, n_pages: int, dtype=None,
                      mesh=None, pool_axis: str = "model") -> List[Any]:
    """Paged decode caches: per pattern position a shared KV page pool
    instead of per-slot ``max_len`` reservations (``serve.paged``).

    Leaves per attention position (stacked over periods like
    ``init_caches``):

    * ``kp``/``vp``: (n_pages, page_size, kvh, dhead) physical pool; page
      0 is the null page (absorbs writes from freed/idle slots).
    * ``pages``: (batch, max_pages) int32 per-slot page table, 0-filled —
      one *logical* table shared by every layer; each layer keeps its own
      physical pool under the same page ids.
    * ``index``: (batch,) per-slot write position, identical to the
      ``per_slot_index=True`` contiguous cache (``cache_lengths`` and the
      engine's length plumbing work unchanged).

    Only attention patterns page (SSM state is O(1) per slot — nothing to
    page); hybrid stacks must serve contiguous.

    With ``mesh`` the pools are placed page-sharded over ``pool_axis``
    (page tables and write indices replicated) — the device-sharded pool
    ``serve.dist`` walks; ``n_pages`` must divide the axis.
    """
    assert all(k in ("attn", "cross") for k in cfg.pattern), \
        ("paged KV caches require an attention-only pattern", cfg.pattern)
    assert n_pages >= 2, n_pages
    dtype = dtype or cfg.dtype
    max_pages = -(-max_len // page_size)
    caches = []
    for _ in cfg.pattern:
        c = {
            "kp": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.dhead),
                            dtype),
            "vp": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.dhead),
                            dtype),
            "pages": jnp.zeros((batch, max_pages), jnp.int32),
            "index": jnp.zeros((batch,), jnp.int32),
        }
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.periods,) + a.shape),
            c)
        caches.append(stacked)
    if mesh is not None:
        from repro.serve import dist as serve_dist
        caches = serve_dist.shard_caches(caches, mesh, pool_axis)
    return caches


def cache_hbm_rows(caches) -> int:
    """KV rows of HBM the caches hold: ``batch * max_len`` per contiguous
    layer, ``n_pages * page_size`` per paged pool (the reservation the
    paged layout shrinks)."""
    total = 0
    for c in caches:
        if "kp" in c:       # (periods, n_pages, page_size, kvh, d)
            total += int(np.prod(c["kp"].shape[:3]))
        elif "k" in c:      # (periods, batch, max_len, kvh, d)
            total += int(np.prod(c["k"].shape[:3]))
    return total


# ----------------------------------------------------------------------------
# Accounting (param counts, MODEL_FLOPS)
# ----------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))
    return sum(int(np.prod(l.shape))
               for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE: only top-k + shared experts)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    d, f = cfg.d_model, cfg.d_ff
    per_expert = 3 * d * f
    n_moe_layers = cfg.periods * len(cfg.moe_positions)
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


def model_flops(cfg: ModelConfig, batch: int, seq: int,
                mode: str = "train", cache_len: int = 0) -> float:
    """MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for inference,
    plus the attention O(s*ctx) term (ctx = cache length when decoding,
    half the sequence for causal prefill/train)."""
    n_active = active_param_count(cfg)
    tokens = batch * seq
    fwd_bwd = 3.0 if mode == "train" else 1.0
    total = 2.0 * fwd_bwd * n_active * tokens
    n_attn_layers = cfg.periods * sum(
        1 for k in cfg.pattern if k in ("attn", "cross"))
    ctx_eff = cache_len if cache_len else seq / 2.0
    attn = fwd_bwd * 4.0 * tokens * ctx_eff * cfg.n_heads * cfg.dhead \
        * n_attn_layers
    return total + attn
