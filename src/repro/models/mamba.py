"""Mamba-2 (SSD — state-space duality) blocks, pure JAX.

The chunked SSD algorithm follows the minimal reference of the Mamba-2 paper
(arXiv:2405.21060, Listing 1): the sequence is split into chunks; within a
chunk outputs are computed attention-like with a decay mask; chunk-boundary
states are carried by an associative recurrence. ``ssd_reference`` is the
O(L) sequential recurrence used as the correctness oracle (and as the
single-step decode path); ``tests/test_mamba.py`` checks they agree, and the
Pallas kernel (``kernels/ssd_scan.py``) is checked against both.

Simplifications vs the full Mamba-2 block (documented in DESIGN.md): single
B/C group (n_groups=1) and the short causal conv applies to x only.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding
from repro.models import layers

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 128          # N
    head_dim: int = 64          # P
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba_init(key, cfg: MambaConfig) -> Params:
    ks = jax.random.split(key, 8)
    d, h, p, n = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_state
    return {
        "w_x": layers._init(ks[0], (d, h, p)),
        "w_z": layers._init(ks[1], (d, h, p)),
        "w_B": layers._init(ks[2], (d, n)),
        "w_C": layers._init(ks[3], (d, n)),
        "w_dt": layers._init(ks[4], (d, h)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "conv_w": layers._init(ks[5], (cfg.d_conv, h, p), scale=0.5),
        "norm": layers.rmsnorm_init(h * p),
        "w_ssm_out": layers._init(ks[6], (h, p, d), scale=1.0 / np.sqrt(h * p)),
    }


def _segsum(a):
    """(..., l) -> (..., l, l): S[i, j] = sum_{j < m <= i} a[m], -inf above
    the diagonal."""
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    s = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(x, a_log, b, c, chunk: int,
                h0: Optional[Any] = None) -> Tuple[Any, Any]:
    """Chunked SSD.

    x: (bt, l, h, p) inputs (already dt-scaled)
    a_log: (bt, l, h) per-step log decay (dt * A, negative)
    b, c: (bt, l, n) input/output projections (single group)
    Returns (y: (bt, l, h, p), final_state: (bt, h, p, n)).
    """
    bt, l, h, p = x.shape
    n = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    xc = x.reshape(bt, nc, chunk, h, p)
    ac = a_log.reshape(bt, nc, chunk, h).transpose(0, 3, 1, 2)  # (bt,h,nc,q)
    bc = b.reshape(bt, nc, chunk, n)
    cc = c.reshape(bt, nc, chunk, n)

    a_cum = jnp.cumsum(ac, axis=-1)                             # (bt,h,nc,q)

    # 1. Intra-chunk (diagonal blocks): attention-like with decay mask.
    decay = jnp.exp(_segsum(ac))                                # (bt,h,nc,q,q)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cc, bc, decay, xc)

    # 2. Per-chunk final states.
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)             # (bt,h,nc,q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc, decay_states, xc)

    # 3. Inter-chunk recurrence over chunk states.
    chunk_decay = jnp.exp(a_cum[..., -1])                       # (bt,h,nc)
    if h0 is None:
        h0 = jnp.zeros((bt, h, p, n), x.dtype)

    def step(carry, inp):
        s, g = inp                                              # (bt,h,p,n), (bt,h)
        new = carry * g[..., None, None] + s
        return new, carry                                       # emit previous

    states_t = states.transpose(1, 0, 2, 3, 4)                  # (nc,bt,h,p,n)
    gs = chunk_decay.transpose(2, 0, 1)                         # (nc,bt,h)
    final, prev_states = jax.lax.scan(step, h0, (states_t, gs))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # (bt,nc,h,p,n)

    # 4. State -> output within each chunk.
    state_decay = jnp.exp(a_cum)                                # (bt,h,nc,q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bt, l, h, p)
    return y, final


def ssd_reference(x, a_log, b, c, h0=None):
    """O(L) sequential recurrence — the oracle."""
    bt, l, h, p = x.shape
    n = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bt, h, p, n), jnp.float32)

    def step(state, inp):
        xt, at, bt_, ct = inp
        state = state * jnp.exp(at)[..., None, None] + \
            jnp.einsum("bhp,bn->bhpn", xt, bt_)
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    xs = (x.transpose(1, 0, 2, 3), a_log.transpose(1, 0, 2),
          b.transpose(1, 0, 2), c.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3), final


# Mamba decode cache: plain dict pytree {"conv": (b, d_conv-1, h, p),
# "ssm": (b, h, p, n), "index": ()} so layer stacks scan over it.
MambaCache = Dict[str, Any]


def _causal_conv(x, w, cache_conv=None):
    """Depthwise causal conv along seq. x: (b,l,h,p), w: (k,h,p)."""
    k = w.shape[0]
    if cache_conv is None:
        pad = jnp.zeros((x.shape[0], k - 1) + x.shape[2:], x.dtype)
    else:
        pad = cache_conv.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(k))
    new_cache = xp[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out), new_cache


def mamba_apply(params: Params, cfg: MambaConfig, x,
                cache: Optional[MambaCache] = None,
                use_kernel: bool = False) -> Tuple[Any, Optional[MambaCache]]:
    """Mamba-2 mixer. x: (b, l, d_model) -> (b, l, d_model)."""
    b_, l, _ = x.shape
    h, p, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    xin = jnp.einsum("bld,dhp->blhp", x, params["w_x"].astype(x.dtype))
    z = jnp.einsum("bld,dhp->blhp", x, params["w_z"].astype(x.dtype))
    bmat = x @ params["w_B"].astype(x.dtype)                    # (b,l,n)
    cmat = x @ params["w_C"].astype(x.dtype)
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, params["w_dt"].astype(x.dtype))
        + params["dt_bias"].astype(x.dtype))                    # (b,l,h)
    a = -jnp.exp(params["A_log"]).astype(jnp.float32)           # (h,)
    xin = sharding.shard(xin, "batch", "seq", "ssm_heads", None)

    conv_cache = cache["conv"] if cache is not None else None
    xin, new_conv = _causal_conv(xin, params["conv_w"], conv_cache)

    a_log = dt.astype(jnp.float32) * a                          # (b,l,h)
    x_scaled = xin * dt[..., None].astype(xin.dtype)
    h0 = cache["ssm"] if cache is not None else None

    if cache is not None and l == 1:
        # Single-step decode: exact recurrence.
        y, hn = ssd_reference(x_scaled.astype(jnp.float32), a_log,
                              bmat.astype(jnp.float32),
                              cmat.astype(jnp.float32),
                              h0=h0)
        y = y.astype(x.dtype)
    elif use_kernel:
        from repro.kernels import ops as kernel_ops
        y, hn = kernel_ops.ssd_scan(x_scaled, a_log, bmat, cmat,
                                    chunk=cfg.chunk)
    else:
        chunk = min(cfg.chunk, l)
        while l % chunk:
            chunk //= 2
        y, hn = ssd_chunked(x_scaled.astype(jnp.float32), a_log,
                            bmat.astype(jnp.float32),
                            cmat.astype(jnp.float32), chunk,
                            h0=h0.astype(jnp.float32) if h0 is not None else None)
        y = y.astype(x.dtype)

    y = y + xin * params["D"].astype(x.dtype)[None, None, :, None]
    y = y * jax.nn.silu(z)
    y = layers.rmsnorm(params["norm"], y.reshape(b_, l, h * p))
    out = jnp.einsum("blhp,hpd->bld", y.reshape(b_, l, h, p),
                     params["w_ssm_out"].astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": hn.astype(cache["ssm"].dtype),
                     "index": cache["index"] + l}
    return sharding.shard(out, "batch", "seq", "embed"), new_cache


def init_cache(cfg: MambaConfig, batch: int, dtype=jnp.float32) -> MambaCache:
    h, p, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    return {"conv": jnp.zeros((batch, cfg.d_conv - 1, h, p), dtype),
            "ssm": jnp.zeros((batch, h, p, n), dtype),
            "index": jnp.zeros((), jnp.int32)}
