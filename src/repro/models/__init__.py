"""Model zoo substrate: pure-JAX layers, transformer stacks, MoE, SSM."""
