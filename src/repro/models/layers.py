"""Core neural layers, pure JAX (pytree params, explicit init/apply).

Conventions:
  * Params are nested dicts of jnp arrays; leaf names drive sharding rules
    (``dist/sharding._LEAF_NAMES``).
  * Attention weights stay 3D — (embed, heads, head_dim) — so the sharding
    divisibility fallback sees true head counts.
  * ``sharding.shard(x, *names)`` annotates activations; it is a no-op
    outside a mesh context.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding

Params = Dict[str, Any]


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * scale


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: Params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dtype)


def layernorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: Params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(dtype)


def norm_init(kind: str, d: int) -> Params:
    return rmsnorm_init(d) if kind == "rms" else layernorm_init(d)


def norm(kind: str, params: Params, x):
    return rmsnorm(params, x) if kind == "rms" else layernorm(params, x)


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(angles)[..., None, :]      # (..., s, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / qkv-bias / cross-attention / KV cache)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: Optional[float] = 10000.0     # None -> no RoPE (whisper)
    causal: bool = True
    expand_kv: bool = False    # broadcast KV to q heads pre-score (sharding)
    probs_fp32: bool = True    # fp32 score/prob tensors (faithful default)


def attention_init(key, cfg: AttnConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": _init(ks[0], (d, h, hd)),
        "wk": _init(ks[1], (d, kvh, hd)),
        "wv": _init(ks[2], (d, kvh, hd)),
        "wo": _init(ks[3], (h, hd, d), scale=1.0 / np.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h, hd), jnp.float32)
        p["b_k"] = jnp.zeros((kvh, hd), jnp.float32)
        p["b_v"] = jnp.zeros((kvh, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _project_qkv(params: Params, cfg: AttnConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["b_q"].astype(x.dtype)
        k = k + params["b_k"].astype(x.dtype)
        v = v + params["b_v"].astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = sharding.shard(q, "batch", "seq", "heads", "head_dim")
    k = sharding.shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = sharding.shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def sdpa(q, k, v, mask=None, kv_lengths=None, expand_kv: bool = False,
         probs_fp32: bool = True):
    """Scaled dot-product attention with GQA head broadcasting.

    q: (b, sq, h, d); k/v: (b, skv, kvh, d). ``mask`` is an additive mask
    broadcastable to (b, h, sq, skv); ``kv_lengths`` (b,) masks a KV cache.

    ``expand_kv``: broadcast K/V to the full query-head count before the
    score einsum. The grouped (kvh, group) reshape makes GSPMD shard the
    attention over *kv* heads — which replicates the whole computation when
    kv_heads doesn't divide the model axis (e.g. 8 kv heads on a 16-way
    axis). Expanding keeps the sharded q-head axis intact at the price of a
    kv-head broadcast (a §Perf hillclimb; see EXPERIMENTS.md).
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    if expand_kv and group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
        k = sharding.shard(k, "batch", None, "heads", "head_dim")
        v = sharding.shard(v, "batch", None, "heads", "head_dim")
        kvh, group = h, 1
    qg = q.reshape(b, sq, kvh, group, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(d)
    scores = scores.astype(jnp.float32 if probs_fp32 else q.dtype)
    if mask is not None:
        mask = mask.astype(scores.dtype)   # keep bf16 chains bf16
        scores = scores + mask[:, None, None] if mask.ndim == 3 else scores + mask
    if kv_lengths is not None:
        skv = k.shape[1]
        valid = jnp.arange(skv)[None, :] < kv_lengths[:, None]   # (b, skv)
        scores = jnp.where(valid[:, None, None, None, :], scores,
                           jnp.asarray(-1e30, scores.dtype))
    # Max-subtraction in fp32 for stability even when probs stay bf16.
    m = jnp.max(scores.astype(jnp.float32), axis=-1, keepdims=True)
    p = jnp.exp(scores - m.astype(scores.dtype))
    probs = (p / jnp.sum(p, axis=-1, keepdims=True)).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, d)


def causal_mask(sq: int, skv: Optional[int] = None, offset: int = 0):
    """Additive causal mask (sq, skv); query i attends keys <= i + offset."""
    skv = skv or sq
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    return jnp.where(kj <= qi, 0.0, -1e30).astype(jnp.float32)


def attention_apply(params: Params, cfg: AttnConfig, x, positions=None,
                    cache: Optional[Params] = None,
                    use_flash: bool = False) -> Tuple[Any, Optional[Params]]:
    """Self-attention; with ``cache`` runs one-step (or chunked) decoding.

    cache = {"k": (b, max_len, kvh, hd), "v": ..., "index": ()} — functional
    update, returns the new cache.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
        if cache is not None:
            idx = cache["index"]
            positions = positions + (idx[:, None] if idx.ndim == 1 else idx)
    q, k, v = _project_qkv(params, cfg, x, positions)
    if cache is not None and "kp" in cache:
        return _paged_apply(params, cfg, x, q, k, v, cache,
                            use_flash=use_flash)
    if cache is not None:
        idx = cache["index"]
        if idx.ndim == 1:
            # Per-slot positions (continuous batching): scatter rows.
            rows = jnp.arange(b)[:, None]
            cols = idx[:, None] + jnp.arange(s)[None, :]
            ck = cache["k"].at[rows, cols].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[rows, cols].set(v.astype(cache["v"].dtype))
            lengths = idx + s
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
            lengths = jnp.full((b,), idx + s)
        ck = sharding.shard(ck, "batch", "cache_seq", "kv_heads", "head_dim")
        cv = sharding.shard(cv, "batch", "cache_seq", "kv_heads", "head_dim")
        new_cache = {"k": ck, "v": cv, "index": idx + s}
        if use_flash and s == 1 and not cfg.expand_kv:
            # Flash decode: the single query at position idx attends exactly
            # the first idx+1 cache rows (causal and kv_lengths masks agree
            # at s == 1); per-slot lengths ride in as scalar prefetch so only
            # each slot's live K/V blocks stream from HBM.
            from repro.kernels import ops as kernel_ops
            lengths = (idx + 1 if idx.ndim == 1
                       else jnp.full((b,), idx + 1, jnp.int32))
            out = kernel_ops.flash_decode(
                q[:, 0], ck.astype(q.dtype), cv.astype(q.dtype),
                lengths)[:, None]
        elif cfg.causal:
            # Chunked prefill must stay causal *within* the chunk: query
            # idx+i may only see cache positions <= idx+i.
            skv = ck.shape[1]
            qi = jnp.arange(s)[None, :, None]
            kj = jnp.arange(skv)[None, None, :]
            off = idx[:, None, None] if idx.ndim == 1 else idx
            mask = jnp.where(kj <= off + qi, 0.0, -1e30).astype(jnp.float32)
            out = sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask=mask,
                       expand_kv=cfg.expand_kv, probs_fp32=cfg.probs_fp32)
        else:
            out = sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype),
                       kv_lengths=lengths, expand_kv=cfg.expand_kv,
                       probs_fp32=cfg.probs_fp32)
    else:
        new_cache = None
        if use_flash:
            from repro.kernels import ops as kernel_ops
            out = kernel_ops.flash_attention(q, k, v, causal=cfg.causal)
        else:
            mask = causal_mask(s) if cfg.causal else None
            out = sdpa(q, k, v, mask=mask, expand_kv=cfg.expand_kv,
                       probs_fp32=cfg.probs_fp32)
    out = sharding.shard(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return sharding.shard(y, "batch", "seq", "embed"), new_cache


def _paged_apply(params: Params, cfg: AttnConfig, x, q, k, v,
                 cache: Params, use_flash: bool):
    """Attention against a paged KV cache (``serve.paged``): single-token
    decode (s == 1) and in-place chunked prefill (s > 1) share one path.

    cache = {"kp"/"vp": (n_pages, page_size, kvh, hd) shared pool,
    "pages": (b, max_pages) per-slot page table (0 = null page),
    "index": (b,) per-slot write position}. The s new K/V rows scatter
    through the table (write-then-attend: the chunk attends its own
    prefix); freed/idle slots (zeroed table rows) and positions at/past
    the table's reach land in the null page, so they can never corrupt a
    live slot's pages.

    When the ambient ruleset shards the pool over a mesh axis
    (``serve.dist.active_pool_mesh``), the scatter and the page-table
    walk run as shard_map ops that resolve global page ids to each
    device's (device, local_page) block; attention then consumes the
    device-resolved contiguous view. Everything else — table, write
    positions, masking — is identical to the single-device walk.
    """
    b, s, _ = x.shape
    idx = cache["index"]                       # (b,) per-slot lengths
    page_size = cache["kp"].shape[1]
    max_pages = cache["pages"].shape[1]
    pos = idx[:, None] + jnp.arange(s)[None, :]          # (b, s) global
    pj = jnp.clip(pos // page_size, 0, max_pages - 1)
    page = jnp.take_along_axis(cache["pages"], pj, axis=1)   # (b, s)
    # A write position past the table's reach (a slot decoding beyond
    # max_len, or a freed slot drifting) must land in the null page — the
    # contiguous path drops the out-of-bounds scatter; clipping pj alone
    # would overwrite row 0 of the slot's *last* live page instead.
    page = jnp.where(pos < max_pages * page_size, page, 0)
    row = pos % page_size
    from repro.serve import dist as serve_dist
    pool_mesh = serve_dist.active_pool_mesh()
    if pool_mesh is not None:
        return _paged_apply_sharded(params, cfg, x, q, k, v, cache, page,
                                    row, pool_mesh, use_flash)
    kp = cache["kp"].at[page, row].set(k.astype(cache["kp"].dtype))
    vp = cache["vp"].at[page, row].set(v.astype(cache["vp"].dtype))
    lengths = idx + s
    new_cache = {"kp": kp, "vp": vp, "pages": cache["pages"],
                 "index": idx + s}
    if use_flash and s == 1 and not cfg.expand_kv:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_decode_paged(
            q[:, 0], kp.astype(q.dtype), vp.astype(q.dtype),
            cache["pages"], lengths)[:, None]
    elif use_flash and not cfg.expand_kv:
        # Chunked prefill: the chunk's rows are already in the pool, so
        # the paged causal kernel streams every previously-written page
        # plus the chunk itself (queries sit at positions idx + [0, s)).
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_attention_paged(
            q, kp.astype(q.dtype), vp.astype(q.dtype), cache["pages"], idx)
    else:
        # Reference path: materialize the contiguous view via a
        # page-table gather, then mask causally per slot (query idx+i may
        # only see positions <= idx+i; at s == 1 this is the kv_lengths
        # mask).
        from repro.serve import paged as paged_mod
        ck, cv = paged_mod.gather_kv(kp, vp, cache["pages"])
        skv = ck.shape[1]
        qi = jnp.arange(s)[None, :, None]
        kj = jnp.arange(skv)[None, None, :]
        mask = jnp.where(kj <= idx[:, None, None] + qi, 0.0,
                         -1e30).astype(jnp.float32)
        out = sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask=mask,
                   expand_kv=cfg.expand_kv, probs_fp32=cfg.probs_fp32)
    out = sharding.shard(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return sharding.shard(y, "batch", "seq", "embed"), new_cache


def _paged_apply_sharded(params, cfg: AttnConfig, x, q, k, v, cache,
                         page, row, pool_mesh, use_flash: bool):
    """Paged attention against a device-sharded pool (``serve.dist``).

    The scatter drops rows each device does not own; the gather is the
    distributed page-table walk (one psum assembles the contiguous view,
    exact because exactly one device contributes each row). The s == 1
    flash path hands the resolved view to the contiguous flash-decode
    kernel — per-slot lengths still bound what it streams.
    """
    from repro.serve import dist as serve_dist
    mesh, paxis = pool_mesh
    b, s, _ = x.shape
    idx = cache["index"]
    kp, vp = serve_dist.scatter_pages(cache["kp"], cache["vp"], k, v,
                                      page, row, mesh, paxis)
    new_cache = {"kp": kp, "vp": vp, "pages": cache["pages"],
                 "index": idx + s}
    ck, cv = serve_dist.gather_pages(kp, vp, cache["pages"], mesh, paxis)
    if use_flash and s == 1 and not cfg.expand_kv:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_decode(
            q[:, 0], ck.astype(q.dtype), cv.astype(q.dtype), idx + 1)[:, None]
    else:
        skv = ck.shape[1]
        qi = jnp.arange(s)[None, :, None]
        kj = jnp.arange(skv)[None, None, :]
        mask = jnp.where(kj <= idx[:, None, None] + qi, 0.0,
                         -1e30).astype(jnp.float32)
        out = sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask=mask,
                   expand_kv=cfg.expand_kv, probs_fp32=cfg.probs_fp32)
    out = sharding.shard(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return sharding.shard(y, "batch", "seq", "embed"), new_cache


def cross_attention_init(key, cfg: AttnConfig) -> Params:
    p = attention_init(key, cfg)
    p["gate"] = jnp.zeros((), jnp.float32)      # tanh-gated (llama-vision)
    return p


def cross_attention_apply(params: Params, cfg: AttnConfig, x, kv_src):
    """Cross-attention: queries from x, keys/values from ``kv_src``."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    out = sdpa(q, k, v, mask=None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    if "gate" in params:
        y = jnp.tanh(params["gate"]).astype(x.dtype) * y
    return sharding.shard(y, "batch", "seq", "embed")


# ----------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    activation: str = "swiglu"        # "swiglu" | "gelu"


def mlp_init(key, cfg: MLPConfig) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.activation == "swiglu":
        return {"w_gate": _init(ks[0], (d, f)),
                "w_up": _init(ks[1], (d, f)),
                "w_down": _init(ks[2], (f, d), scale=1.0 / np.sqrt(f))}
    return {"w_up": _init(ks[0], (d, f)),
            "b_up": jnp.zeros((f,), jnp.float32),
            "w_down": _init(ks[1], (f, d), scale=1.0 / np.sqrt(f))}


def mlp_apply(params: Params, cfg: MLPConfig, x):
    if cfg.activation == "swiglu":
        g = x @ params["w_gate"].astype(x.dtype)
        u = x @ params["w_up"].astype(x.dtype)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(x @ params["w_up"].astype(x.dtype)
                        + params["b_up"].astype(x.dtype))
    h = sharding.shard(h, "batch", "seq", "mlp")
    y = h @ params["w_down"].astype(x.dtype)
    return sharding.shard(y, "batch", "seq", "embed")


# ----------------------------------------------------------------------------
# Embeddings / unembedding
# ----------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int) -> Params:
    return {"embedding": _init(key, (vocab, d), scale=1.0)}


def embed(params: Params, tokens, dtype=jnp.float32):
    out = jnp.take(params["embedding"].astype(dtype), tokens, axis=0)
    return sharding.shard(out, "batch", "seq", "embed")


def unembed_init(key, d: int, vocab: int) -> Params:
    return {"lm_head": _init(key, (d, vocab))}


def unembed(params: Params, x):
    logits = x @ params["lm_head"].astype(x.dtype)
    return sharding.shard(logits, "batch", "seq", "vocab")


def sinusoidal_positions(n: int, d: int):
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * dim / d)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, jnp.float32)
