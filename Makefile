.PHONY: check check-all test

# Fast tier-1 gate: import-walk smoke + fast tests.
check:
	./scripts/check.sh

# Everything, including slow multi-device subprocess / compile tests.
check-all:
	./scripts/check.sh --all

test: check
