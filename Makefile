.PHONY: check check-all test bench-fast calibrate

# Fast tier-1 gate: import-walk smoke + fast tests.
check:
	./scripts/check.sh

# Serving fast-path bench: engine tokens/sec + modeled naive-vs-flash-decode
# speedup, then the breaking-point sweep + telemetry overhead/drift cells;
# both merge into the same json (read-modify-write), persisted for diffing
# across PRs.
bench-fast:
	PYTHONPATH=src python -m benchmarks.tpu_serving --out BENCH_serving.json
	PYTHONPATH=src python -m benchmarks.breaking_point --out BENCH_serving.json

# Microbenchmark calibration pass (core/calibrate.py): probe the
# serving-path cost constants on this backend and persist them under the
# tuning cache's calibrated: namespace; subsequent engines price their
# choose_* decisions from the measured set (REPRO_DEFAULT_CONSTANTS=1
# forces the hand-set defaults back).
calibrate:
	PYTHONPATH=src python -m repro.launch.calibrate

# Everything, including slow multi-device subprocess / compile tests.
check-all:
	./scripts/check.sh --all

test: check
