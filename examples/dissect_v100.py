"""The paper, end to end: dissect the V100 device model with black-box
probes and print the recovered Table 3.1 column + the Ch.1 optimization.

  PYTHONPATH=src python examples/dissect_v100.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import dissect, hwmodel, regbank, regremap


def main():
    print("== dissecting V100 device model (black-box probes only) ==")
    rep = dissect.dissect(hwmodel.V100)
    print(f"L1: {rep.l1.size//1024} KiB, {rep.l1.line} B lines, "
          f"{rep.l1.sets} sets, {rep.l1.policy}, {rep.l1.hit_latency} cyc")
    print(f"L2: {rep.l2.size//1024} KiB, {rep.l2.line} B lines, "
          f"{rep.l2.ways}-way, {rep.l2.hit_latency} cyc")
    print(f"latency classes: {rep.latency}")
    for i, t in enumerate(rep.tlbs, 1):
        print(f"L{i} TLB: {t.page_entry >> 20} MiB pages, "
              f"{t.coverage >> 20} MiB coverage")
    print(f"register file: {rep.reg_banks} banks x {rep.reg_bank_width} bit")
    print(f"matches vs published: {sum(rep.matches.values())}"
          f"/{len(rep.matches)}")

    print("\n== ch.1: conflict-aware register remapping ==")
    rf = hwmodel.V100.regfile
    nvcc = regbank.parse_listing(regbank.NVCC_LISTING)
    ours = regremap.remap_tile(rf, regbank.A_REGS, regbank.B_REGS,
                               list(range(16, 80)))
    g0 = regbank.gflops_per_sm(rf, nvcc, 1380.0)
    g1 = regbank.gflops_per_sm(rf, ours, 1380.0)
    print(f"NVCC mapping : {g0:6.2f} GFLOPS/SM (paper measured 132.05)")
    print(f"our remapping: {g1:6.2f} GFLOPS/SM (paper measured 152.43)")
    print(f"modeled gain : {g1/g0-1:+.1%} (paper +15.4%)")


if __name__ == "__main__":
    main()
