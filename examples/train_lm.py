"""End-to-end training driver: a ~100M-parameter decoder LM on the synthetic
pipeline with checkpoints, watchdog, and fault tolerance.

Default runs a scaled-down config so it finishes quickly on 1 CPU core; pass
--full-100m --steps 300 for the full run (same code path, bigger model).

  PYTHONPATH=src python examples/train_lm.py [--full-100m] [--steps N]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses

import jax

from repro.data import DataConfig, SyntheticLMData
from repro.models import transformer as T
from repro.optim import schedule
from repro.train import steps as train_steps
from repro.train.trainer import Trainer, TrainerConfig

SMALL = T.ModelConfig(name="lm-12m", n_layers=4, d_model=256, n_heads=4,
                      n_kv_heads=2, d_ff=1024, vocab=8192)
FULL_100M = T.ModelConfig(name="lm-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=4, d_ff=3072, vocab=32768)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    cfg = FULL_100M if args.full_100m else SMALL
    print(f"model={cfg.name} params={T.param_count(cfg)/1e6:.1f}M")
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch, seed=0))
    sched = schedule.ScheduleConfig(peak_lr=1e-3, warmup_steps=20,
                                    total_steps=args.steps)
    step = jax.jit(train_steps.make_train_step(cfg, sched=sched),
                   donate_argnums=(0,))
    init = lambda: train_steps.init_state(jax.random.PRNGKey(0), cfg).tree()
    trainer = Trainer(TrainerConfig(total_steps=args.steps,
                                    checkpoint_every=max(args.steps // 3, 10),
                                    checkpoint_dir="/tmp/repro_train_lm",
                                    log_every=10),
                      cfg, data, step, init)
    result = trainer.run()
    first, last = result["metrics"][0], result["metrics"][-1]
    print(f"loss {first['loss']:.3f} -> {last['loss']:.3f} over "
          f"{args.steps} steps; {len(result['stragglers'])} stragglers")
    assert last["loss"] < first["loss"], "training must make progress"


if __name__ == "__main__":
    main()
