"""Microbench-informed GEMM tiling: the hardware model picks BlockSpecs, the
Pallas kernel runs them (interpret mode on CPU), outputs validated vs jnp.

  PYTHONPATH=src python examples/autotune_gemm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune
from repro.kernels import ops


def main():
    for m, k, n in ((512, 512, 512), (1024, 4096, 1024)):
        p = autotune.GemmProblem(m=m, k=k, n=n)
        gain = autotune.tuning_gain(p)
        cfg = gain["tuned"]["config"]
        print(f"GEMM {m}x{k}x{n}: tuned block={cfg} "
              f"modeled speedup vs naive 128^3 = {gain['speedup']:.2f}x "
              f"(traffic {gain['naive']['traffic_bytes']/2**20:.0f} -> "
              f"{gain['tuned']['traffic_bytes']/2**20:.0f} MiB)")
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(256, 512), jnp.float32)
    y = jnp.asarray(rng.randn(512, 256), jnp.float32)
    out = ops.gemm(x, y)       # autotuned block, Pallas interpret on CPU
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ y),
                               rtol=1e-4, atol=1e-3)
    print("Pallas kernel with autotuned block == jnp reference: OK")


if __name__ == "__main__":
    main()
