"""Batched serving: continuous batching over mixed-length requests, checked
against per-request greedy generation.

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                greedy_generate)


def main():
    cfg = configs.get_smoke("granite-3-8b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, ServeConfig(max_len=64, batch=4,
                                                    eos_id=-1))
    rng = np.random.RandomState(0)
    prompts = {rid: rng.randint(2, cfg.vocab, size=rng.randint(3, 12))
               .astype(np.int32) for rid in range(10)}
    t0 = time.time()
    for rid, p in prompts.items():
        engine.submit(Request(rid=rid, prompt=p, max_new=12))
    done = engine.run_until_drained()
    dt = time.time() - t0
    tokens = sum(len(v) for v in done.values())
    print(f"{len(done)} requests, {tokens} tokens, {tokens/dt:.1f} tok/s "
          f"(4-slot continuous batching)")
    ref = greedy_generate(params, cfg, jnp.asarray(prompts[0])[None], 12,
                          max_len=64)
    assert done[0] == np.asarray(ref[0]).tolist(), "engine must match greedy"
    print("engine output == reference greedy decode for request 0")


if __name__ == "__main__":
    main()
