"""Quickstart: train a tiny LM for 40 steps, checkpoint, and generate.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import DataConfig, SyntheticLMData
from repro.models import transformer as T
from repro.serve.engine import greedy_generate
from repro.train import steps as train_steps
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = configs.get_smoke("qwen3-4b")
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=4, seed=0))
    step = jax.jit(train_steps.make_train_step(cfg), donate_argnums=(0,))
    init = lambda: train_steps.init_state(jax.random.PRNGKey(0), cfg).tree()
    trainer = Trainer(TrainerConfig(total_steps=40, checkpoint_every=20,
                                    checkpoint_dir="/tmp/repro_quickstart",
                                    log_every=10),
                      cfg, data, step, init)
    result = trainer.run()
    print("loss:", [f"{m['loss']:.3f}" for m in result["metrics"]])
    params = result["state"]["params"]
    prompt = jnp.asarray(np.array([[5, 9, 2, 7]], np.int32))
    out = greedy_generate(params, cfg, prompt, max_new=8, max_len=32)
    print("generated:", np.asarray(out[0]).tolist())


if __name__ == "__main__":
    main()
