"""Device-model behaviour: the simulator must faithfully exhibit the cache
phenomenology the paper measures (else the dissector proves nothing)."""

import numpy as np
import pytest

from repro.core import hwmodel, simulator
from repro.core.simulator import LatencyConfig, MemoryHierarchy, SetAssocCache, TLB

KiB = 1024


def test_lru_sequential_thrash():
    c = SetAssocCache(size=4 * KiB, line=64, sets=4, policy="lru")
    addrs = np.arange(0, 5 * KiB, 64)       # 125% of capacity
    for a in addrs:
        c.access(int(a))
    c.reset_stats()
    for a in addrs:
        c.access(int(a))
    assert c.hits == 0                       # classic LRU pathological scan


def test_lru_fits_all_hit():
    c = SetAssocCache(size=4 * KiB, line=64, sets=4, policy="lru")
    addrs = np.arange(0, 4 * KiB, 64)
    for a in addrs:
        c.access(int(a))
    c.reset_stats()
    for a in addrs:
        c.access(int(a))
    assert c.misses == 0


def test_associativity_conflicts():
    c = SetAssocCache(size=4 * KiB, line=64, sets=8, policy="lru")  # 8 ways
    ways = c.ways
    spacing = c.sets * c.line
    # ways addresses in one set: all hit on rescan.
    for k in (ways, ways + 1):
        c.flush()
        addrs = [i * spacing for i in range(k)]
        for a in addrs:
            c.access(a)
        c.reset_stats()
        for a in addrs:
            c.access(a)
        if k == ways:
            assert c.misses == 0
        else:
            assert c.misses == k             # LRU same-set thrash


def test_prio_bypass_effective_capacity():
    # Volta-like: reserved ways behave as transient -> detectable size short.
    c = SetAssocCache(size=8 * KiB, line=32, sets=4, policy="prio",
                      reserved_ways=16)
    protected_lines = (c.ways - 16) * 4
    addrs = np.arange(0, 8 * KiB, 32)
    for a in addrs:
        c.access(int(a))
    c.reset_stats()
    for a in addrs:
        c.access(int(a))
    assert c.hits == protected_lines
    assert c.misses == len(addrs) - protected_lines


def test_tlb_lru_and_coverage():
    t = TLB(coverage=8 * 2 * KiB, page_entry=2 * KiB)    # 8 entries
    for i in range(8):
        t.access(i * 2 * KiB)
    t.hits = t.misses = 0
    for i in range(8):
        t.access(i * 2 * KiB)
    assert t.misses == 0
    t.access(9 * 2 * KiB)                                 # evicts LRU
    t.hits = t.misses = 0
    t.access(0)
    assert t.misses == 1


def test_v100_latency_classes_fig_3_2():
    hier = simulator.build_hierarchy(hwmodel.V100)
    lat = hier.scan(np.arange(0, 256, 8))
    assert lat[0] == 1029        # cold: L2 + TLB miss
    assert 28 in lat             # L1 hit within line
    assert 193 in lat            # L1 miss, L2 hit (64B line)
    assert 375 in lat[2:]        # L2 miss, TLB hit


def test_virtual_indexed_l1_skips_tlb():
    hier = simulator.build_hierarchy(hwmodel.V100)
    addrs = np.arange(0, 4 * KiB, 32)
    hier.scan(addrs)
    before = hier.tlb_accesses
    hier.scan(addrs)             # all L1 hits now
    assert hier.tlb_accesses == before   # paper §3.8 claim


def test_smem_conflict_model_fig_3_9():
    v = hwmodel.V100
    assert simulator.smem_latency(v, 1) == v.smem_no_conflict_latency
    lat2 = simulator.smem_latency(v, 2)
    lat32 = simulator.smem_latency(v, 32)
    assert lat2 > v.smem_no_conflict_latency
    assert lat32 > lat2
    # Kepler's 8-byte banks forgive 2-way conflicts (paper).
    k = hwmodel.K80
    assert simulator.smem_latency(k, 2) == k.smem_no_conflict_latency


def test_constant_broadcast_fig_3_7():
    v = hwmodel.V100
    assert simulator.constant_latency(v, "l1", 1) == 27
    assert simulator.constant_latency(v, "l1", 4) == 4 * 27
    assert simulator.constant_latency(v, "l1.5", 1) == 89
