"""Prefix caching: admissions that share a cached prefix map existing
pages by refcount (zero data movement) and must emit streams bit-identical
to the uncached engine — across greedy, sampled, speculative and
preemption paths. Copy-on-write isolates the one admission case whose
write cursor lands inside a shared page; cached-idle pages are reclaimed
(LRU) before any live slot is preempted; and the hit/COW telemetry
reconciles exactly with the allocator's refcount totals (check.sh gates
this file in the serving subset)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                greedy_generate)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _pcfg(**kw):
    base = dict(max_len=64, batch=2, eos_id=-1, paged=True, page_size=8,
                chunk_size=8, prefix_cache=True)
    base.update(kw)
    return ServeConfig(**base)


def _ref(params, cfg, prompt, n, max_len=64):
    return np.asarray(greedy_generate(params, cfg,
                                      jnp.asarray(prompt)[None], n,
                                      max_len=max_len)[0]).tolist()


def _shared_prompts(cfg, rng, n=3, prefix_len=16):
    """n prompts sharing a page-aligned prefix, distinct short suffixes."""
    shared = rng.randint(2, cfg.vocab, prefix_len).astype(np.int32)
    return {rid: np.concatenate(
        [shared, rng.randint(2, cfg.vocab, 3 + rid)]).astype(np.int32)
        for rid in range(n)}


# ----------------------------------------------------------------------------
# Bit-parity: cached streams are the uncached engine's streams
# ----------------------------------------------------------------------------

def test_cached_admissions_stream_reference_tokens(model):
    """Sequential sharers: the first admission publishes the prefix pages,
    later ones map them (2 full pages each) — and every stream is exactly
    the contiguous greedy reference."""
    cfg, params = model
    rng = np.random.RandomState(0)
    prompts = _shared_prompts(cfg, rng)
    eng = ServingEngine(params, cfg, _pcfg(batch=1))
    got = {}
    for rid, p in prompts.items():
        eng.submit(Request(rid=rid, prompt=p, max_new=6))
        got.update(eng.run_until_drained())
    assert eng.prefix_hits == 2 and eng.prefix_misses == 1
    assert eng.prefix_hit_pages == 4          # 16-token prefix = 2 pages
    for rid, p in prompts.items():
        assert got[rid] == _ref(params, cfg, p, 6), rid
    # After drain only the cached-idle copies stay resident: one page run
    # per distinct prefix, nothing shared or slot-exclusive leaks.
    cls = eng.pool.page_classes()
    assert cls["pages_shared"] == 0 and cls["pages_exclusive"] == 0
    assert cls["pages_cached_idle"] == eng.pool.pages_in_use > 0
    eng.prefix.clear()
    assert eng.pool.pages_in_use == 0         # nothing leaked past the index


@pytest.mark.parametrize("kw", [
    dict(),                                   # greedy
    dict(temperature=0.8, seed=7),            # sampled
    dict(spec_k=2, draft="ngram"),            # speculative
])
def test_cached_vs_uncached_bit_parity(model, kw):
    """The same request sequence through prefix-cache on/off engines
    emits byte-identical token streams on every decode path."""
    cfg, params = model
    rng = np.random.RandomState(1)
    prompts = _shared_prompts(cfg, rng)
    streams = {}
    for on in (False, True):
        eng = ServingEngine(params, cfg,
                            _pcfg(batch=1, prefix_cache=on, **kw))
        got = {}
        for rid, p in prompts.items():
            eng.submit(Request(rid=rid, prompt=p.copy(), max_new=8))
            got.update(eng.run_until_drained())
        streams[on] = got
        if on:
            assert eng.prefix_hits >= 2, kw
    assert streams[True] == streams[False], kw


def test_preemption_with_shared_pages_keeps_streams_exact(model):
    """Pool exhaustion with live shared/retained pages still preempts the
    youngest slot cleanly: refcounted frees, re-admission (now possibly a
    cache hit on its own earlier prefix), reference streams throughout."""
    cfg, params = model
    rng = np.random.RandomState(2)
    shared = rng.randint(2, cfg.vocab, 8).astype(np.int32)
    pa = np.concatenate([shared,
                         rng.randint(2, cfg.vocab, 7)]).astype(np.int32)
    pb = np.concatenate([shared,
                         rng.randint(2, cfg.vocab, 6)]).astype(np.int32)
    eng = ServingEngine(params, cfg, _pcfg(n_pages=6))
    eng.submit(Request(rid=0, prompt=pa, max_new=9))
    eng.submit(Request(rid=1, prompt=pb, max_new=9))
    got = eng.run_until_drained()
    assert eng.preemptions >= 1
    for rid, pr in ((0, pa), (1, pb)):
        assert got[rid] == _ref(params, cfg, pr, 9), rid


@given(seed=st.integers(0, 200))
@settings(max_examples=8, deadline=None)
def test_random_shared_traffic_parity(seed):
    """Property: random shared-prefix mixes (varying prefix alignment,
    suffix lengths, arrival interleaving) — cached and uncached engines
    agree stream-for-stream."""
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(seed)
    shared = rng.randint(2, cfg.vocab, rng.randint(4, 20)).astype(np.int32)
    prompts = {}
    for rid in range(4):
        sfx = rng.randint(2, cfg.vocab, rng.randint(1, 9))
        prompts[rid] = np.concatenate([shared, sfx]).astype(np.int32)
    streams = {}
    for on in (False, True):
        eng = ServingEngine(params, cfg, _pcfg(prefix_cache=on))
        got = {}
        for rid, p in prompts.items():
            eng.submit(Request(rid=rid, prompt=p.copy(), max_new=5))
            if rid % 2:                       # interleave waves
                got.update(eng.run_until_drained())
        got.update(eng.run_until_drained())
        streams[on] = got
    assert streams[True] == streams[False]


# ----------------------------------------------------------------------------
# Copy-on-write
# ----------------------------------------------------------------------------

def test_full_coverage_hit_cows_the_cursor_page(model):
    """A page-aligned prompt fully covered by the index re-admits with
    its prefill cursor clamped *inside* the last shared page — that page
    must split (copy-on-write) at admission, because the batched decode
    step would otherwise scribble garbage rows into a page other holders
    read. One COW, identical streams, one cached copy per prefix."""
    cfg, params = model
    rng = np.random.RandomState(3)
    prompt = rng.randint(2, cfg.vocab, 16).astype(np.int32)  # 2 full pages
    eng = ServingEngine(params, cfg, _pcfg(batch=1))
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    a = eng.run_until_drained()
    eng.submit(Request(rid=1, prompt=prompt.copy(), max_new=6))
    b = eng.run_until_drained()
    assert eng.prefix_hits == 1 and eng.prefix_hit_pages == 2
    assert eng.cow_copies >= 1
    assert eng.cow_copies == eng.pool.cow_count
    ref = _ref(params, cfg, prompt, 6)
    assert a[0] == ref and b[1] == ref
    # One copy per distinct prefix: exactly the 2 published pages remain.
    assert len(eng.prefix) == 2
    assert eng.pool.page_classes()["pages_cached_idle"] == 2


# ----------------------------------------------------------------------------
# Eviction sits below preemption on the degradation ladder
# ----------------------------------------------------------------------------

def test_cached_idle_pages_evict_before_preemption(model):
    """Pool pressure from fresh admissions reclaims unreferenced cached
    prefixes (LRU) — no live slot is preempted while idle cache pages
    could be freed instead, and the new streams are exact."""
    cfg, params = model
    rng = np.random.RandomState(4)
    warm = rng.randint(2, cfg.vocab, 24).astype(np.int32)    # 3 full pages
    eng = ServingEngine(params, cfg, _pcfg(n_pages=9))       # 8 usable
    eng.submit(Request(rid=0, prompt=warm, max_new=4))
    eng.run_until_drained()
    assert eng.pool.page_classes()["pages_cached_idle"] == 3
    pa = rng.randint(2, cfg.vocab, 15).astype(np.int32)
    pb = rng.randint(2, cfg.vocab, 15).astype(np.int32)
    eng.submit(Request(rid=1, prompt=pa, max_new=9))
    eng.submit(Request(rid=2, prompt=pb, max_new=9))
    got = eng.run_until_drained()
    assert eng.prefix_evictions >= 1
    assert eng.preemptions == 0
    for rid, pr in ((1, pa), (2, pb)):
        assert got[rid] == _ref(params, cfg, pr, 9), rid


def test_slot_mapped_cached_pages_are_never_evicted(model):
    """Eviction only touches refcount-1 (index-only) pages: while a
    sharer is mid-stream its mapped prefix pages survive any pressure,
    so its stream can never be corrupted by reclaim."""
    cfg, params = model
    rng = np.random.RandomState(5)
    shared = rng.randint(2, cfg.vocab, 16).astype(np.int32)
    pa = np.concatenate([shared,
                         rng.randint(2, cfg.vocab, 3)]).astype(np.int32)
    eng = ServingEngine(params, cfg, _pcfg(batch=1))
    eng.submit(Request(rid=0, prompt=pa, max_new=4))
    eng.run_until_drained()
    eng.submit(Request(rid=1, prompt=pa.copy(), max_new=12))
    while eng.slots[0] is None:
        eng.tick()                            # admitted: prefix mapped
    assert eng.pool.page_classes()["pages_shared"] >= 1
    evicted_before = eng.prefix.evicted_pages
    eng.prefix.evict(64, now=eng.ticks)       # reclaim everything idle
    assert eng.pool.page_classes()["pages_shared"] >= 1   # survived
    got = eng.run_until_drained()
    assert got[1] == _ref(params, cfg, pa, 12)
    assert eng.prefix.evicted_pages >= evicted_before


# ----------------------------------------------------------------------------
# Admission pricing + telemetry reconciliation
# ----------------------------------------------------------------------------

def test_cached_admission_prices_only_the_suffix(model):
    """The admission bugfix: a re-admission of a cached long prompt
    reserves suffix pages only — fewer fresh allocations and an earlier
    first token than the cold engine on the identical request."""
    cfg, params = model
    rng = np.random.RandomState(6)
    long = rng.randint(2, cfg.vocab, 32).astype(np.int32)    # 4 chunks
    eng = ServingEngine(params, cfg, _pcfg(batch=1))
    eng.submit(Request(rid=0, prompt=long, max_new=4))
    eng.run_until_drained()
    cold_ttft = eng.first_token_tick[0]
    alloc0 = eng.pool.pages_allocated
    t0 = eng.ticks
    eng.submit(Request(rid=1, prompt=long.copy(), max_new=4))
    got = eng.run_until_drained()
    warm_ttft = eng.first_token_tick[1] - t0
    assert warm_ttft < cold_ttft              # suffix-only prefill
    # 4 prompt pages were mapped, not refilled: fresh takes are the COW
    # split plus decode growth only.
    assert eng.pool.pages_allocated - alloc0 < 4
    assert got[1] == _ref(params, cfg, long, 4)


def test_hit_and_cow_telemetry_reconciles_with_allocator(model):
    """Telemetry is derived truth: hit/COW/evict event sums equal the
    allocator's own refcount-transition counters, and the PR-8
    conservation law extends exactly — allocator allocations are the
    page_alloc events plus COW takes; frees are the page_free events
    plus index evictions."""
    cfg, params = model
    rng = np.random.RandomState(7)
    shared = rng.randint(2, cfg.vocab, 16).astype(np.int32)
    eng = ServingEngine(params, cfg, _pcfg(n_pages=12))
    rid = 0
    for wave in range(3):
        for _ in range(2):
            sfx = rng.randint(2, cfg.vocab, rng.randint(1, 9))
            eng.submit(Request(
                rid=rid, max_new=5,
                prompt=np.concatenate([shared, sfx]).astype(np.int32)))
            rid += 1
        eng.run_until_drained()
    eng.submit(Request(rid=rid, prompt=shared.copy(), max_new=5))
    eng.run_until_drained()                   # full-coverage: fires COW
    pool, tel = eng.pool, eng.telemetry
    assert eng.prefix_hits >= 3 and eng.cow_copies >= 1
    assert eng.prefix_hit_pages == pool.shared_mappings
    assert eng.prefix_hit_pages == sum(
        p["pages"] for _, _, _, p in tel.events_of("prefix_hit"))
    assert eng.cow_copies == pool.cow_count
    assert eng.prefix.evicted_pages == sum(
        p["n"] for _, _, _, p in tel.events_of("prefix_evict"))
    alloc_ev = sum(p["n"] for _, _, _, p in tel.events_of("page_alloc"))
    free_ev = sum(p["n"] for _, _, _, p in tel.events_of("page_free"))
    assert alloc_ev + pool.cow_count == pool.pages_allocated
    assert free_ev + eng.prefix.evicted_pages == pool.pages_freed
    assert pool.pages_allocated - pool.pages_freed == pool.pages_in_use


def test_prefix_cache_off_engine_is_untouched(model):
    """Default-off: no index, no hit/miss/COW events, and the drain-time
    pages_in_use == 0 invariant every pre-existing test relies on."""
    cfg, params = model
    rng = np.random.RandomState(8)
    prompts = _shared_prompts(cfg, rng)
    eng = ServingEngine(params, cfg, _pcfg(prefix_cache=False))
    for rid, p in prompts.items():
        eng.submit(Request(rid=rid, prompt=p, max_new=5))
    got = eng.run_until_drained()
    assert eng.prefix is None
    assert eng.prefix_hits == eng.prefix_misses == eng.cow_copies == 0
    assert eng.pool.pages_in_use == 0
    for rid, p in prompts.items():
        assert got[rid] == _ref(params, cfg, p, 5), rid
