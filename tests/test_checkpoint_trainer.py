"""Checkpoint roundtrip/async/GC + trainer fault tolerance."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticLMData
from repro.train import steps as train_steps
from repro.train.trainer import SimulatedPreemption, Trainer, TrainerConfig


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"data": {"step": 7, "seed": 0}})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    got, manifest = load_checkpoint(str(tmp_path), like)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))
    assert got["nested"]["b"].dtype == np.asarray(t["nested"]["b"]).dtype


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp-")]


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step-"))
    assert steps == ["step-00000003", "step-00000004"]
    assert mgr.latest_step() == 4


def _mk_trainer(tmp_path, fail_injector=None, steps=20):
    cfg = configs.get_smoke("qwen3-4b")
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=16,
                                      global_batch=2, seed=0))
    step = jax.jit(train_steps.make_train_step(cfg), donate_argnums=(0,))
    init = lambda: train_steps.init_state(jax.random.PRNGKey(0), cfg).tree()
    return Trainer(
        TrainerConfig(total_steps=steps, checkpoint_every=5,
                      checkpoint_dir=str(tmp_path), log_every=5,
                      async_checkpoint=False),
        cfg, data, step, init, fail_injector=fail_injector)


def test_trainer_loss_decreases(tmp_path):
    result = _mk_trainer(tmp_path / "a", steps=30).run()
    losses = [m["loss"] for m in result["metrics"]]
    assert losses[-1] < losses[0]
    assert result["recoveries"] == 0


def test_trainer_recovers_from_preemption(tmp_path):
    fired = {"done": False}

    def injector(step):
        if step == 12 and not fired["done"]:
            fired["done"] = True
            raise SimulatedPreemption("node lost")

    tr = _mk_trainer(tmp_path / "b", fail_injector=injector, steps=20)
    result = tr.run()
    assert result["recoveries"] == 1
    assert int(np.asarray(result["state"]["step"])) == 20
    # Restart resumed from the last checkpoint (10), not from scratch.
    assert tr.ckpt.latest_step() == 20


def test_trainer_restart_resumes_and_is_deterministic(tmp_path):
    d = tmp_path / "c"
    r1 = _mk_trainer(d, steps=10).run()
    # Second run continues to 20 from the step-10 checkpoint.
    r2 = _mk_trainer(d, steps=20).run()
    assert int(np.asarray(r2["state"]["step"])) == 20
    # Fresh run straight to 20 gives the same final loss (determinism).
    r3 = _mk_trainer(tmp_path / "d", steps=20).run()
    assert r2["metrics"][-1]["loss"] == pytest.approx(
        r3["metrics"][-1]["loss"], rel=1e-4)


def test_watchdog_flags_stragglers(tmp_path):
    tr = _mk_trainer(tmp_path / "e", steps=15)
    orig = tr.step_fn

    def slow_step(state, batch):
        if int(np.asarray(state["step"])) == 12:
            time.sleep(0.6)
        return orig(state, batch)

    tr.step_fn = slow_step
    result = tr.run()
    assert 12 in result["stragglers"]
