"""Property: the Ch.1 remapping algorithm produces conflict-free, fully
covering schedules for arbitrary register slices (Volta-class banks)."""

from hypothesis import given, settings, strategies as st

from repro.core import hwmodel, regbank, regremap

V = hwmodel.V100.regfile


@st.composite
def tile_problem(draw):
    # Disjoint A, B, C register ranges with random offsets/parities.
    a0 = draw(st.integers(2, 20))
    b0 = a0 + 8 + draw(st.integers(0, 8))
    c0 = b0 + 8 + draw(st.integers(0, 8))
    rows = draw(st.sampled_from([4, 8]))
    cols = draw(st.sampled_from([4, 8]))
    a = tuple(range(a0, a0 + rows))
    b = tuple(range(b0, b0 + cols))
    c_pool = tuple(range(c0, c0 + 2 * rows * cols))
    return a, b, c_pool


@given(tile_problem())
@settings(max_examples=25)
def test_remap_is_conflict_free_and_covers(problem):
    a, b, c_pool = problem
    instrs = regremap.remap_tile(V, a, b, c_pool)
    assert len(instrs) == len(a) * len(b)
    assert regremap.conflict_free(V, instrs)
    # Every product covered exactly once with a unique accumulator.
    seen = set()
    accs = set()
    for ins in instrs:
        ops = set(ins.srcs)
        pa = ops & set(a)
        pb = ops & set(b)
        assert len(pa) == 1 and len(pb) == 1
        seen.add((pa.pop(), pb.pop()))
        accs.add(ins.dst)
    assert len(seen) == len(a) * len(b)
    assert len(accs) == len(instrs)


def test_remap_matches_paper_tile():
    instrs = regremap.remap_tile(V, regbank.A_REGS, regbank.B_REGS,
                                 list(range(16, 80)))
    assert regbank.tile_coverage(instrs)
    assert regremap.conflict_free(V, instrs)
    # Reuse flags actually save bank reads vs a flagless schedule.
    flagless = [regbank.FFMA(i.dst, i.srcs, (False,) * 3) for i in instrs]
    c_with, _ = regbank.instruction_cycles(V, instrs, "pair")
    c_without, stalls_without = regbank.instruction_cycles(V, flagless, "pair")
    assert c_with <= c_without
