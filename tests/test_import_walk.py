"""Import-surface smoke: every repro.* module must import cleanly on a
single CPU device.  A missing subsystem (like the repro.dist regression
this guards against) fails here in milliseconds instead of surfacing as a
wall of collection errors."""

import importlib
import os
import pkgutil


def _walk(package_name):
    pkg = importlib.import_module(package_name)
    names = [package_name]
    for info in pkgutil.walk_packages(pkg.__path__,
                                      prefix=package_name + "."):
        names.append(info.name)
    return names


def test_every_repro_module_imports():
    names = _walk("repro")
    assert len(names) > 50, f"suspiciously few modules found: {len(names)}"
    failures = {}
    # launch.dryrun sets XLA_FLAGS (subprocess entry point); importing it
    # here is safe since the backend is already initialized, but the env
    # mutation must not leak into later subprocess-spawning tests.
    xla_flags = os.environ.get("XLA_FLAGS")
    try:
        for name in sorted(names):
            try:
                importlib.import_module(name)
            except Exception as e:  # noqa: BLE001 — collect all, report once
                failures[name] = f"{type(e).__name__}: {e}"
    finally:
        if xla_flags is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = xla_flags
    assert not failures, "\n".join(f"{k}: {v}" for k, v in failures.items())


def test_dist_package_exports():
    from repro.dist import (collective_matmul, compression, pipeline,
                            sharding)

    assert callable(sharding.param_spec)
    assert callable(sharding.use_ruleset)
    assert callable(compression.int8_roundtrip)
    assert callable(collective_matmul.ag_matmul)
    assert callable(pipeline.gpipe)
