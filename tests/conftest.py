import os
import sys

# Tests run against 1 CPU device; the 512-device dry-run sets its own flags
# in-process (launch/dryrun.py) and is exercised here via subprocesses only.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # Offline image without hypothesis: install the deterministic local
    # fallback so the property-test modules still collect and run.
    import _minihypothesis
    _minihypothesis.install()

from hypothesis import settings

settings.register_profile("ci", max_examples=20, deadline=None,
                          derandomize=True)
settings.load_profile("ci")
