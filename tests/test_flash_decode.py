"""Flash-decode kernel vs the pure-jnp oracle: ragged per-slot lengths,
GQA group sizes, block-size invariance, zero-length slots."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode as decode_raw


def _case(rng, b, h, kvh, d, max_len):
    q = jnp.asarray(rng.randn(b, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, max_len, kvh, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, max_len, kvh, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (8, 1), (2, 2)])
def test_flash_decode_gqa_ragged(h, kvh):
    rng = np.random.RandomState(0)
    b, d, max_len = 4, 16, 64
    q, k, v = _case(rng, b, h, kvh, d, max_len)
    lengths = jnp.asarray([1, 17, 64, 33], jnp.int32)
    out = decode_raw(q, k, v, lengths, block_k=16, interpret=True)
    expect = ref.flash_decode(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_zero_length_slot_is_zeros_not_nan():
    rng = np.random.RandomState(1)
    q, k, v = _case(rng, 3, 4, 2, 8, 32)
    lengths = jnp.asarray([0, 5, 32], jnp.int32)
    out = np.asarray(decode_raw(q, k, v, lengths, block_k=8, interpret=True))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[0], np.zeros_like(out[0]))
    expect = np.asarray(ref.flash_decode(q, k, v, lengths))
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 50), block_k=st.sampled_from([8, 16, 32, 64]),
       kvh=st.sampled_from([1, 2, 4]))
@settings(max_examples=10, deadline=None)
def test_flash_decode_block_and_length_invariance(seed, block_k, kvh):
    """Any block size and any ragged length vector gives the oracle."""
    rng = np.random.RandomState(seed)
    b, d, max_len = 3, 8, 64
    h = kvh * int(rng.randint(1, 4))
    q, k, v = _case(rng, b, h, kvh, d, max_len)
    lengths = jnp.asarray(rng.randint(1, max_len + 1, size=b), jnp.int32)
    out = decode_raw(q, k, v, lengths, block_k=block_k, interpret=True)
    expect = ref.flash_decode(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_bf16():
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(2, 4, 16), jnp.bfloat16)
    k = jnp.asarray(rng.randn(2, 32, 2, 16), jnp.bfloat16)
    v = jnp.asarray(rng.randn(2, 32, 2, 16), jnp.bfloat16)
    lengths = jnp.asarray([7, 32], jnp.int32)
    out = decode_raw(q, k, v, lengths, block_k=8, interpret=True)
    expect = ref.flash_decode(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ops_flash_decode_autotunes_block():
    rng = np.random.RandomState(3)
    q, k, v = _case(rng, 2, 8, 2, 16, 48)
    lengths = jnp.asarray([11, 48], jnp.int32)
    out = ops.flash_decode(q, k, v, lengths)
    expect = ref.flash_decode(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_matches_full_attention_at_full_length():
    """lengths == max_len degenerates to ordinary causal decode."""
    rng = np.random.RandomState(4)
    b, h, kvh, d, max_len = 2, 4, 2, 16, 32
    q, k, v = _case(rng, b, h, kvh, d, max_len)
    lengths = jnp.full((b,), max_len, jnp.int32)
    out = decode_raw(q, k, v, lengths, block_k=16, interpret=True)
    # Full-length ragged == last row of the (sq=1, skv=max_len) oracle.
    expect = ref.flash_attention(q[:, None], k, v, causal=True)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)
