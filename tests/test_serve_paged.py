"""ServingEngine slot lifecycle under paging: token parity with the
contiguous oracle, page reuse across free/re-admit, clean pool-exhaustion
rejection, and decode-time lazy allocation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import layers, transformer as T
from repro.serve import paged
from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                greedy_generate)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _paged_cfg(**kw):
    base = dict(max_len=32, batch=2, eos_id=-1, paged=True, page_size=8)
    base.update(kw)
    return ServeConfig(**base)


def test_paged_engine_matches_reference(model):
    """Paged decode (gather path) reproduces the contiguous reference
    streams across slot reuse and mixed prompt lengths."""
    cfg, params = model
    rng = np.random.RandomState(0)
    prompts = {rid: rng.randint(2, cfg.vocab, size=n).astype(np.int32)
               for rid, n in enumerate((3, 6, 7, 11))}
    eng = ServingEngine(params, cfg, _paged_cfg())
    for rid, pr in prompts.items():
        eng.submit(Request(rid=rid, prompt=pr, max_new=5))
    got = eng.run_until_drained()
    for rid, pr in prompts.items():
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], 5,
                              max_len=32)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid
    assert eng.pool.pages_in_use == 0         # everything returned


def test_paged_engine_flash_kernel_matches_reference(model):
    """use_flash threads the *paged* flash-decode kernel; streams must
    stay identical."""
    cfg, params = model
    fcfg = dataclasses.replace(cfg, use_flash=True)
    rng = np.random.RandomState(1)
    prompts = {0: rng.randint(2, cfg.vocab, 4).astype(np.int32),
               1: rng.randint(2, cfg.vocab, 9).astype(np.int32)}
    eng = ServingEngine(params, fcfg, _paged_cfg())
    for rid, pr in prompts.items():
        eng.submit(Request(rid=rid, prompt=pr, max_new=4))
    got = eng.run_until_drained()
    for rid, pr in prompts.items():
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], 4,
                              max_len=32)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid


def test_free_then_readmit_reuses_returned_pages(model):
    cfg, params = model
    rng = np.random.RandomState(2)
    eng = ServingEngine(params, cfg, _paged_cfg(batch=1))
    eng.submit(Request(rid=0, prompt=rng.randint(2, cfg.vocab, 9)
                       .astype(np.int32), max_new=3))
    eng.tick()
    pages_a = list(eng.pool.slot_pages[0])
    eng.run_until_drained()
    # rid=0 returned its pages; re-admission must draw the same ones back
    # (LIFO free list: freshly freed pages are reused first).
    assert eng.pool.pages_in_use == 0
    eng.submit(Request(rid=1, prompt=rng.randint(2, cfg.vocab, 9)
                       .astype(np.int32), max_new=3))
    eng.tick()
    pages_b = list(eng.pool.slot_pages[0])
    assert pages_b == pages_a
    got = eng.run_until_drained()
    assert set(got) == {0, 1}


def test_pool_exhaustion_rejects_admission_cleanly(model):
    """A request the pool can't hold stays queued (no partial allocation,
    no crash) and admits once a finished slot returns its pages."""
    cfg, params = model
    rng = np.random.RandomState(3)
    # 4 pages of 8 rows: one 17-row prompt takes 3; two can't fit at once
    # (each also lazily takes a 4th page as decode crosses a boundary...
    # keep max_new tiny so growth stays inside the prompt's last page).
    # chunk_size=32 pins whole-prompt chunks so the first-chunk admission
    # reserve equals the full prompt here, whatever the autotune default.
    scfg = _paged_cfg(n_pages=5, page_size=8, batch=2, chunk_size=32)
    eng = ServingEngine(params, cfg, scfg)
    p0 = rng.randint(2, cfg.vocab, 17).astype(np.int32)
    p1 = rng.randint(2, cfg.vocab, 17).astype(np.int32)
    eng.submit(Request(rid=0, prompt=p0, max_new=3))
    eng.submit(Request(rid=1, prompt=p1, max_new=3))
    eng.tick()
    # Slot 0 admitted (3 pages + 1 lazy); slot 1 held back, still queued.
    assert eng.slots[0] is not None and eng.slots[1] is None
    assert len(eng.queue) == 1
    assert eng.admission_rejections >= 1
    got = eng.run_until_drained()
    assert set(got) == {0, 1}                 # both finished eventually
    for rid, pr in ((0, p0), (1, p1)):
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], 3,
                              max_len=32)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid


@pytest.mark.parametrize("n_pages,prompt_len", [
    (3, 25),    # prompt alone needs 4 pages > 2-page capacity
    (4, 24),    # page-aligned prompt fits exactly, but the first decode
                # write needs a 4th page the pool can never supply
])
def test_never_admittable_request_raises_instead_of_silent_drop(
        model, n_pages, prompt_len):
    """A request the pool can *never* hold (prompt pages + the first
    decode write) must fail loudly at admission, not sit in the queue
    until run_until_drained gives up and silently loses it."""
    cfg, params = model
    rng = np.random.RandomState(6)
    eng = ServingEngine(params, cfg,
                        _paged_cfg(n_pages=n_pages, page_size=8))
    eng.submit(Request(rid=0, prompt=rng.randint(2, cfg.vocab, prompt_len)
                       .astype(np.int32), max_new=2))
    with pytest.raises(paged.PagePoolExhausted):
        eng.tick()


def test_freed_slot_zeroes_table_and_length(model):
    cfg, params = model
    rng = np.random.RandomState(4)
    eng = ServingEngine(params, cfg, _paged_cfg())
    eng.submit(Request(rid=0, prompt=rng.randint(2, cfg.vocab, 6)
                       .astype(np.int32), max_new=2))
    eng.submit(Request(rid=1, prompt=rng.randint(2, cfg.vocab, 4)
                       .astype(np.int32), max_new=8))
    eng.tick()      # rid=0 hits max_new and frees
    assert 0 in eng.finished and eng.slots[0] is None
    np.testing.assert_array_equal(eng.context_lengths(), [0, 5])
    for c in eng.caches:
        assert int(np.asarray(c["pages"][0, 0]).sum()) == 0
    assert 0 not in eng.pool.slot_pages
    eng.tick()      # freed slot drifts through the null page, harmlessly
    np.testing.assert_array_equal(eng.context_lengths(), [1, 6])


def test_decode_growth_allocates_pages_lazily(model):
    """Admission reserves only the prompt's pages; crossing a page
    boundary during decode takes exactly one more page per crossing."""
    cfg, params = model
    rng = np.random.RandomState(5)
    eng = ServingEngine(params, cfg, _paged_cfg(batch=1, page_size=8))
    eng.submit(Request(rid=0, prompt=rng.randint(2, cfg.vocab, 7)
                       .astype(np.int32), max_new=12))
    eng.tick()      # prefill (1 page) + lazy page for position 7's token
    assert len(eng.pool.slot_pages[0]) == 1
    counts = []
    while eng.slots[0] is not None:
        eng.tick()
        counts.append(len(eng.pool.slot_pages.get(0, [])))
    # Lengths run 7 -> 18: pages grow 1 -> 3, one boundary at a time,
    # and everything returns to the pool when the slot frees.
    assert 2 in counts and max(counts) == 3
    assert counts[-1] == 0


def test_paged_cache_hbm_rows_smaller_than_contiguous(model):
    cfg, params = model
    contig = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=4,
                                                    eos_id=-1))
    small = ServingEngine(params, cfg,
                          _paged_cfg(batch=4, n_pages=9, page_size=8))
    assert T.cache_hbm_rows(small.caches) < T.cache_hbm_rows(contig.caches)


@pytest.mark.parametrize("use_flash", [False, True])
def test_paged_write_past_max_len_lands_in_null_page(use_flash):
    """Regression: a slot whose write position reaches max_len (table
    fully populated) must spill into the null page — clipping the page
    index alone would overwrite row 0 of the slot's *last* live page."""
    rng = np.random.RandomState(0)
    b, max_len, ps, d_model, h = 1, 8, 4, 8, 2
    acfg = layers.AttnConfig(d_model=d_model, n_heads=h, n_kv_heads=h,
                             head_dim=d_model // h)
    params = layers.attention_init(jax.random.PRNGKey(0), acfg)
    x = jnp.asarray(rng.randn(b, 1, d_model), jnp.float32)
    kp = jnp.asarray(rng.randn(3, ps, h, d_model // h), jnp.float32)
    vp = jnp.asarray(rng.randn(3, ps, h, d_model // h), jnp.float32)
    cache = {"kp": kp, "vp": vp,
             "pages": jnp.asarray([[1, 2]], jnp.int32),
             "index": jnp.asarray([max_len], jnp.int32)}
    out, new = layers.attention_apply(params, acfg, x, cache=cache,
                                      use_flash=use_flash)
    assert np.isfinite(np.asarray(out)).all()
    # Live pages 1 and 2 untouched; only the null page absorbed the write.
    np.testing.assert_array_equal(np.asarray(new["kp"][1:]),
                                  np.asarray(kp[1:]))
    assert not np.array_equal(np.asarray(new["kp"][0]), np.asarray(kp[0]))


@pytest.mark.parametrize("use_flash", [False, True])
def test_paged_multirow_write_straddles_table_reach(use_flash):
    """s > 1 at the exact page-table-reach boundary (the speculative
    verify's write shape): a 3-row write starting 2 rows before max_len
    must land its in-reach rows in the slot's last live page and spill
    only the out-of-reach row to the null page — and the attention output
    must match the contiguous cache, which simply drops the out-of-bounds
    scatter."""
    rng = np.random.RandomState(0)
    b, ps, max_pages, d_model, h = 1, 4, 2, 8, 2
    max_len = ps * max_pages                      # 8
    hd = d_model // h
    acfg = layers.AttnConfig(d_model=d_model, n_heads=h, n_kv_heads=h,
                             head_dim=hd)
    params = layers.attention_init(jax.random.PRNGKey(0), acfg)
    s, idx = 3, max_len - 2                       # rows 6, 7 live; 8 spills
    x = jnp.asarray(rng.randn(b, s, d_model), jnp.float32)
    k0 = rng.randn(b, max_len, h, hd).astype(np.float32)
    v0 = rng.randn(b, max_len, h, hd).astype(np.float32)
    k0[:, idx:], v0[:, idx:] = 0, 0               # only idx rows live
    contig = {"k": jnp.asarray(k0), "v": jnp.asarray(v0),
              "index": jnp.asarray([idx], jnp.int32)}
    kp = np.zeros((1 + max_pages, ps, h, hd), np.float32)
    vp = np.zeros_like(kp)
    kp[1:] = k0[0].reshape(max_pages, ps, h, hd)
    vp[1:] = v0[0].reshape(max_pages, ps, h, hd)
    pcache = {"kp": jnp.asarray(kp), "vp": jnp.asarray(vp),
              "pages": jnp.asarray([[1, 2]], jnp.int32),
              "index": jnp.asarray([idx], jnp.int32)}

    out_c, new_c = layers.attention_apply(params, acfg, x, cache=contig)
    out_p, new_p = layers.attention_apply(params, acfg, x, cache=pcache,
                                          use_flash=use_flash)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_c),
                               rtol=2e-4, atol=2e-4)
    # In-reach rows (positions 6, 7 = last page rows 2, 3) got the new
    # K/V; their page's earlier rows and the whole first page untouched.
    np.testing.assert_array_equal(np.asarray(new_p["kp"][2, 2:]),
                                  np.asarray(new_c["k"][0, idx:]))
    np.testing.assert_array_equal(np.asarray(new_p["kp"][2, :2]),
                                  kp[2, :2])
    np.testing.assert_array_equal(np.asarray(new_p["kp"][1]), kp[1])
    np.testing.assert_array_equal(np.asarray(new_p["vp"][1]), vp[1])
    # The out-of-reach row (position 8) spilled into the null page only.
    assert not np.array_equal(np.asarray(new_p["kp"][0]), kp[0])
    np.testing.assert_array_equal(np.asarray(new_p["index"]), [idx + s])


@given(seed=st.integers(0, 100), kvh=st.sampled_from([1, 2, 4]),
       use_flash=st.booleans())
@settings(max_examples=8, deadline=None)
def test_paged_attention_apply_matches_contiguous(seed, kvh, use_flash):
    """Property: one decode step through ``layers.attention_apply`` gives
    the same output and the same effective cache row whether the KV cache
    is contiguous or paged — over ragged lengths, GQA groups and freed
    (zero-length) slots."""
    rng = np.random.RandomState(seed)
    b, max_len, ps, d_model = 3, 32, 8, 16
    h = kvh * int(rng.randint(1, 3))
    hd = d_model // h if d_model % h == 0 else 4
    acfg = layers.AttnConfig(d_model=d_model, n_heads=h, n_kv_heads=kvh,
                             head_dim=hd)
    params = layers.attention_init(jax.random.PRNGKey(seed), acfg)
    x = jnp.asarray(rng.randn(b, 1, d_model), jnp.float32)
    # Lengths >= 1: engine-freed slots (length 0) share the null page, so
    # their (discarded) outputs may collide — covered by the engine tests
    # and the kernel's zero-length test instead.
    lengths = rng.randint(1, max_len - 1, size=b).astype(np.int32)

    k0 = rng.randn(b, max_len, kvh, hd).astype(np.float32)
    v0 = rng.randn(b, max_len, kvh, hd).astype(np.float32)
    mask = (np.arange(max_len)[None, :, None, None]
            < lengths[:, None, None, None])
    k0, v0 = k0 * mask, v0 * mask             # live rows only
    contig = {"k": jnp.asarray(k0), "v": jnp.asarray(v0),
              "index": jnp.asarray(lengths)}

    n_pages = 1 + b * (max_len // ps)
    kp = np.zeros((n_pages, ps, kvh, hd), np.float32)
    vp = np.zeros_like(kp)
    table = np.zeros((b, max_len // ps), np.int32)
    nxt = 1
    for i in range(b):
        # +1: the decode token's write position must be page-backed too
        # (the engine's _ensure_decode_pages allocates it before a tick).
        for j in range(paged.pages_for(int(lengths[i]) + 1, ps)):
            table[i, j] = nxt
            kp[nxt] = k0[i, j * ps:(j + 1) * ps]
            vp[nxt] = v0[i, j * ps:(j + 1) * ps]
            nxt += 1
    pcache = {"kp": jnp.asarray(kp), "vp": jnp.asarray(vp),
              "pages": jnp.asarray(table), "index": jnp.asarray(lengths)}

    out_c, _ = layers.attention_apply(params, acfg, x, cache=contig,
                                      use_flash=use_flash)
    out_p, new_p = layers.attention_apply(params, acfg, x, cache=pcache,
                                          use_flash=use_flash)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_c),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(np.asarray(new_p["index"]), lengths + 1)
