"""Correctness of the §Perf hillclimb knobs: every optimization must be a
no-op (or bounded perturbation) on the math."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.train import steps as train_steps


@pytest.fixture(scope="module")
def base():
    cfg = configs.get_smoke("qwen3-4b")      # GQA: kv < heads
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    logits, _, _ = T.forward(params, cfg, tokens)
    return cfg, params, tokens, logits


def test_expand_kv_is_exact(base):
    cfg, params, tokens, logits = base
    cfg2 = dataclasses.replace(cfg, expand_kv=True)
    logits2, _, _ = T.forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(logits),
                               rtol=1e-5, atol=1e-5)


def test_expand_kv_decode_is_exact(base):
    cfg, params, tokens, _ = base
    cfg2 = dataclasses.replace(cfg, expand_kv=True)
    caches = T.init_caches(cfg2, 2, 16)
    from repro.serve.engine import prefill
    last, caches = prefill(params, cfg2, tokens[:, :-1], caches)
    lg, _, _ = T.forward(params, cfg2, tokens[:, -1:], caches=caches)
    full, _, _ = T.forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_bf16_probs_bounded_perturbation(base):
    cfg, params, tokens, logits = base
    cfg2 = dataclasses.replace(cfg, attn_probs_fp32=False)
    logits2, _, _ = T.forward(params, cfg2, tokens)
    # Not exact (bf16 softmax), but probabilities must stay close.
    p1 = jax.nn.softmax(logits.astype(jnp.float32), -1)
    p2 = jax.nn.softmax(logits2.astype(jnp.float32), -1)
    assert float(jnp.abs(p1 - p2).max()) < 0.05


def test_remat_policies_give_same_gradients():
    cfg = configs.get_smoke("granite-3-8b")
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                     cfg.vocab),
    }
    grads = {}
    for name, kw in (("none", dict(remat=False)),
                     ("full", dict(remat=True, remat_policy="full")),
                     ("dots", dict(remat=True, remat_policy="dots"))):
        c = dataclasses.replace(cfg, **kw)
        params = T.init_params(jax.random.PRNGKey(0), c)
        g = jax.grad(lambda p: train_steps.loss_fn(p, c, batch)[0])(params)
        grads[name] = g
    for name in ("full", "dots"):
        diffs = jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()),
            grads["none"], grads[name])
        assert max(jax.tree.leaves(diffs)) < 1e-4, name


def test_moe_capacity_factor_plumbs_through():
    cfg = configs.get_smoke("dbrx-132b")
    cfg = dataclasses.replace(cfg, moe_capacity_factor=2.0)
    assert cfg.moe_cfg().capacity_factor == 2.0


def test_int8_kv_cache_decode_close():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (1, 6), 0, cfg.vocab)
    full, _, _ = T.forward(params, cfg, tokens)
    # int8 cache: prefill + decode; logits should rank-match bf16 closely.
    caches = T.init_caches(cfg, 1, 8, dtype=jnp.float32)
    from repro.serve.engine import prefill
    _, caches = prefill(params, cfg, tokens[:, :-1], caches)
    lg, _, _ = T.forward(params, cfg, tokens[:, -1:], caches=caches)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)
