"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU), plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.gemm import gemm as gemm_raw
from repro.kernels.flash_attention import flash_attention as flash_raw


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (384, 256, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sweep(m, k, n, dtype):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(m, k), dtype)
    y = jnp.asarray(rng.randn(k, n), dtype)
    out = gemm_raw(x, y, bm=128, bk=128, bn=128, interpret=True)
    expect = ref.gemm(x.astype(jnp.float32), y.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect), rtol=tol, atol=tol * k)


@given(bm=st.sampled_from([64, 128, 256]), bk=st.sampled_from([64, 128]),
       bn=st.sampled_from([64, 128]))
@settings(max_examples=6, deadline=None)
def test_gemm_block_shape_invariance(bm, bk, bn):
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(256, 256), jnp.float32)
    y = jnp.asarray(rng.randn(256, 256), jnp.float32)
    out = gemm_raw(x, y, bm=bm, bk=bk, bn=bn, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ y),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_gqa_sweep(h, kvh, causal):
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(2, 128, h, 32), jnp.float32)
    k = jnp.asarray(rng.randn(2, 128, kvh, 32), jnp.float32)
    v = jnp.asarray(rng.randn(2, 128, kvh, 32), jnp.float32)
    out = flash_raw(q, k, v, causal=causal, block_q=64, block_k=32,
                    interpret=True)
    expect = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_causal_cross_length():
    """sq != skv causal (chunked prefill shape): the skipped-load grid must
    honor the skv-sq diagonal offset."""
    rng = np.random.RandomState(6)
    q = jnp.asarray(rng.randn(1, 32, 4, 16), jnp.float32)
    k = jnp.asarray(rng.randn(1, 96, 2, 16), jnp.float32)
    v = jnp.asarray(rng.randn(1, 96, 2, 16), jnp.float32)
    out = flash_raw(q, k, v, causal=True, block_q=16, block_k=16,
                    interpret=True)
    expect = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("sq", [256, 192, 96])
def test_flash_attention_autotuned_blocks(sq):
    """Default (None) blocks resolve through the attention cost model and
    snap to dividing sizes for lengths the 128-aligned candidates miss."""
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(1, sq, 4, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, sq, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, sq, 2, 32), jnp.float32)
    out = flash_raw(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(1, 64, 2, 16), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 64, 2, 16), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 64, 2, 16), jnp.bfloat16)
    out = flash_raw(q, k, v, causal=True, block_q=32, block_k=32,
                    interpret=True)
    expect = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=5e-2, atol=5e-2)


@given(seed=st.integers(0, 100), chunk=st.sampled_from([8, 16, 32]))
@settings(max_examples=8, deadline=None)
def test_ssd_kernel_vs_reference(seed, chunk):
    rng = np.random.RandomState(seed)
    b, l, h, p, n = 2, 64, 2, 8, 4
    x = jnp.asarray(rng.randn(b, l, h, p), jnp.float32) * 0.5
    a = -jnp.abs(jnp.asarray(rng.randn(b, l, h), jnp.float32)) * 0.4
    bm = jnp.asarray(rng.randn(b, l, n), jnp.float32) * 0.5
    cm = jnp.asarray(rng.randn(b, l, n), jnp.float32) * 0.5
    from repro.kernels.ssd_scan import ssd_scan
    y, hf = ssd_scan(x, a, bm, cm, chunk=chunk, interpret=True)
    yr, hr = ref.ssd_scan(x, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                               rtol=2e-3, atol=2e-3)


def test_pchase_kernel_follows_chain():
    rng = np.random.RandomState(4)
    perm = rng.permutation(128).astype(np.int32)
    chain = np.empty(128, np.int32)
    chain[perm] = np.roll(perm, -1)
    out = ops.pchase(jnp.asarray(chain), 64)
    np.testing.assert_array_equal(np.asarray(out), ref.pchase(chain, 64))


def test_ops_autotuned_gemm_dispatches():
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(256, 512), jnp.float32)
    y = jnp.asarray(rng.randn(512, 384), jnp.float32)
    out = ops.gemm(x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ y),
                               rtol=1e-4, atol=1e-3)
