"""Speculative decoding: for any forced accept/reject pattern, draft
source and spec_k, the speculative engine's token stream and paged cache
contents must be bit-identical to the non-speculative greedy engine —
including across mid-stream preemption and at temperature > 0 (per-position
sampling keys). Zero-accept ticks must degrade to plain decode, and the
verify path must trace exactly one executable (check.sh gates this file in
the serving subset)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import transformer as T
from repro.serve import paged, spec
from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                greedy_generate)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _spec_cfg(**kw):
    base = dict(max_len=64, batch=2, eos_id=-1, paged=True, page_size=8,
                chunk_size=8, spec_k=2, draft="ngram")
    base.update(kw)
    return ServeConfig(**base)


def _ref(params, cfg, prompt, n, max_len=64):
    return np.asarray(greedy_generate(params, cfg,
                                      jnp.asarray(prompt)[None], n,
                                      max_len=max_len)[0]).tolist()


# ----------------------------------------------------------------------------
# Oracle: forced accept/reject patterns == plain greedy engine
# ----------------------------------------------------------------------------

@given(seed=st.integers(0, 50), spec_k=st.sampled_from([1, 2, 4]),
       pattern_bits=st.integers(0, 255))
@settings(max_examples=10, deadline=None)
def test_spec_stream_matches_reference_any_accept_pattern(seed, spec_k,
                                                          pattern_bits):
    """Property: whatever the draft gets right or wrong (all 8-bit
    accept/reject patterns, spec_k in {1,2,4}), the emitted stream is
    exactly the plain greedy engine's."""
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(seed)
    prompt = rng.randint(2, cfg.vocab, rng.randint(3, 12)).astype(np.int32)
    ref = _ref(params, cfg, prompt, 10)
    pattern = [(pattern_bits >> b) & 1 for b in range(8)]
    draft = spec.ScriptedDraft(len(prompt), ref, pattern, cfg.vocab)
    eng = ServingEngine(params, cfg, _spec_cfg(batch=1, spec_k=spec_k,
                                               draft=draft))
    eng.submit(Request(rid=0, prompt=prompt, max_new=10))
    got = eng.run_until_drained()
    assert got[0] == ref
    assert eng.verify_traces == 1
    assert eng.pool.pages_in_use == 0


def test_spec_cache_bit_identical_to_plain_engine(model):
    """Mid-stream, the speculative slot's live K/V rows and write position
    are bit-for-bit the plain engine's: rejected rows rolled back, the
    null page having absorbed writes past the table's reach."""
    cfg, params = model
    rng = np.random.RandomState(1)
    prompt = rng.randint(2, cfg.vocab, 7).astype(np.int32)
    ref = _ref(params, cfg, prompt, 24)
    # Accept-some pattern so verify ticks both accept and reject.
    draft = spec.ScriptedDraft(len(prompt), ref, [1, 1, 0, 1], cfg.vocab)
    se = ServingEngine(params, cfg, _spec_cfg(batch=1, spec_k=4,
                                              draft=draft))
    se.submit(Request(rid=0, prompt=prompt.copy(), max_new=24))
    for _ in range(4):
        se.tick()
    n_emitted = len(se.slots[0].generated)
    assert n_emitted > 4                      # speculation actually ran

    pe = ServingEngine(params, cfg, _spec_cfg(batch=1, spec_k=0))
    pe.submit(Request(rid=0, prompt=prompt.copy(), max_new=24))
    while pe.slots[0] is None or len(pe.slots[0].generated) < n_emitted:
        pe.tick()                             # plain: one token per tick
    assert se.slots[0].generated == pe.slots[0].generated

    live = len(prompt) + n_emitted - 1        # last token not yet written
    for cs, cp in zip(se.caches, pe.caches):
        np.testing.assert_array_equal(np.asarray(cs["index"]),
                                      np.asarray(cp["index"]))
        for period in range(cs["kp"].shape[0]):
            ks_s, vs_s = paged.gather_kv(cs["kp"][period], cs["vp"][period],
                                         cs["pages"][period])
            ks_p, vs_p = paged.gather_kv(cp["kp"][period], cp["vp"][period],
                                         cp["pages"][period])
            np.testing.assert_array_equal(np.asarray(ks_s[:, :live]),
                                          np.asarray(ks_p[:, :live]))
            np.testing.assert_array_equal(np.asarray(vs_s[:, :live]),
                                          np.asarray(vs_p[:, :live]))


def test_spec_ngram_engine_matches_reference_multislot(model):
    """Slot churn + mixed prompt lengths + the real n-gram drafter still
    reproduce every reference stream exactly."""
    cfg, params = model
    rng = np.random.RandomState(0)
    prompts = {rid: rng.randint(2, cfg.vocab, size=n).astype(np.int32)
               for rid, n in enumerate((5, 16, 17, 27))}
    eng = ServingEngine(params, cfg, _spec_cfg())
    for rid, pr in prompts.items():
        eng.submit(Request(rid=rid, prompt=pr, max_new=6))
    got = eng.run_until_drained()
    for rid, pr in prompts.items():
        assert got[rid] == _ref(params, cfg, pr, 6), rid
    assert eng.pool.pages_in_use == 0
    assert eng.verify_traces == 1


@pytest.mark.parametrize("use_flash", [False, True])
def test_spec_flash_verify_matches_reference(model, use_flash):
    """The verify executable runs the paged s>1 *flash* path under
    use_flash; streams must stay identical to the sdpa reference."""
    cfg, params = model
    if use_flash:
        cfg = dataclasses.replace(cfg, use_flash=True)
    rng = np.random.RandomState(2)
    prompts = {0: rng.randint(2, cfg.vocab, 5).astype(np.int32),
               1: rng.randint(2, cfg.vocab, 11).astype(np.int32)}
    eng = ServingEngine(params, cfg, _spec_cfg(spec_k=3))
    for rid, pr in prompts.items():
        eng.submit(Request(rid=rid, prompt=pr, max_new=5))
    got = eng.run_until_drained()
    for rid, pr in prompts.items():
        assert got[rid] == _ref(params, model[0], pr, 5), rid


# ----------------------------------------------------------------------------
# Degradation, preemption, sampling parity
# ----------------------------------------------------------------------------

def test_zero_accept_ticks_degrade_to_plain_decode(model):
    """An always-wrong draft must cost nothing but the verify width: every
    verify tick emits exactly one (corrected) token and the stream is the
    plain engine's."""
    cfg, params = model
    rng = np.random.RandomState(3)
    prompt = rng.randint(2, cfg.vocab, 6).astype(np.int32)
    ref = _ref(params, cfg, prompt, 8)
    draft = spec.ScriptedDraft(len(prompt), ref, [0], cfg.vocab)  # reject all
    eng = ServingEngine(params, cfg, _spec_cfg(batch=1, spec_k=4,
                                               draft=draft))
    eng.submit(Request(rid=0, prompt=prompt, max_new=8))
    eng.tick()                                # prefill + first token
    while eng.slots[0] is not None:
        before = len(eng.slots[0].generated)
        eng.tick()
        after = (len(eng.slots[0].generated) if eng.slots[0] is not None
                 else len(eng.finished[0]))
        assert after == before + 1            # exactly plain-decode pace
    assert eng.finished[0] == ref
    assert eng.spec_accepted == 0
    assert eng.spec_emitted == eng.spec_ticks


def test_spec_preemption_parity(model):
    """Pool exhaustion mid-speculation preempts the youngest slot; both
    streams still finish bit-identical to the reference (the preempted
    stream re-prefills prompt + generated and continues)."""
    cfg, params = model
    rng = np.random.RandomState(4)
    pa = rng.randint(2, cfg.vocab, 15).astype(np.int32)
    pb = rng.randint(2, cfg.vocab, 15).astype(np.int32)
    eng = ServingEngine(params, cfg, _spec_cfg(n_pages=6))
    eng.submit(Request(rid=0, prompt=pa, max_new=9))
    eng.submit(Request(rid=1, prompt=pb, max_new=9))
    got = eng.run_until_drained()
    assert eng.preemptions >= 1
    for rid, pr in ((0, pa), (1, pb)):
        assert got[rid] == _ref(params, cfg, pr, 9), rid
    assert eng.pool.pages_in_use == 0


def test_spec_sampling_matches_plain_sampling(model):
    """Temperature > 0: per-(request, position) sampling keys make the
    speculative engine consume exactly the keys sequential decode would,
    so the sampled streams are identical, not just same-distribution."""
    cfg, params = model
    rng = np.random.RandomState(5)
    prompt = rng.randint(2, cfg.vocab, 7).astype(np.int32)
    base = dict(temperature=0.7, seed=11, batch=1)
    plain = ServingEngine(params, cfg, _spec_cfg(spec_k=0, **base))
    plain.submit(Request(rid=0, prompt=prompt.copy(), max_new=10))
    ref = plain.run_until_drained()[0]
    for spec_k in (1, 3):
        eng = ServingEngine(params, cfg, _spec_cfg(spec_k=spec_k, **base))
        eng.submit(Request(rid=0, prompt=prompt.copy(), max_new=10))
        assert eng.run_until_drained()[0] == ref, spec_k


def test_preempted_stream_replays_sampling_rng(model):
    """Satellite: requeue-at-head preemption preserves the slot's sampling
    key stream — a preempted temperature-sampled request replays exactly
    the tokens it would have produced uncontended."""
    cfg, params = model
    rng = np.random.RandomState(6)
    pa = rng.randint(2, cfg.vocab, 15).astype(np.int32)
    pb = rng.randint(2, cfg.vocab, 15).astype(np.int32)
    base = dict(temperature=0.8, seed=7)
    solo = ServingEngine(params, cfg, _spec_cfg(batch=1, spec_k=0, **base))
    solo.submit(Request(rid=0, prompt=pa.copy(), max_new=9))
    ref = solo.run_until_drained()[0]
    for spec_k in (0, 2):                     # plain and speculative
        eng = ServingEngine(params, cfg,
                            _spec_cfg(n_pages=6, spec_k=spec_k, **base))
        eng.submit(Request(rid=0, prompt=pa.copy(), max_new=9))
        for _ in range(3):
            eng.tick()
        eng.submit(Request(rid=1, prompt=pb.copy(), max_new=9))
        got = eng.run_until_drained()
        assert eng.preemptions >= 1, spec_k
        assert got[0] == ref, spec_k


# ----------------------------------------------------------------------------
# Trace gates + accounting
# ----------------------------------------------------------------------------

def test_spec_verify_single_trace_any_prompt_mix(model):
    """One verify executable and one chunk executable, no matter the
    prompt-length mix; the plain decode step is never traced in spec
    mode (the verify IS the decode tick)."""
    cfg, params = model
    rng = np.random.RandomState(7)
    eng = ServingEngine(params, cfg, _spec_cfg())
    for rid, n in enumerate((3, 7, 9, 16, 17, 25, 31)):
        eng.submit(Request(rid=rid, prompt=rng.randint(2, cfg.vocab, n)
                           .astype(np.int32), max_new=4))
    eng.run_until_drained()
    assert eng.verify_traces == 1
    assert set(eng.prefill_traces) == {eng.chunk}
    assert eng.prefill_traces[eng.chunk] == 1
    assert eng.decode_traces == 0


def test_spec_accounting_consistent(model):
    """spec_emitted = spec_accepted + one bonus per verify tick, minus
    tokens truncated by max_new — and generated streams account for every
    emitted token."""
    cfg, params = model
    rng = np.random.RandomState(8)
    prompt = rng.randint(2, cfg.vocab, 6).astype(np.int32)
    ref = _ref(params, cfg, prompt, 12)
    draft = spec.ScriptedDraft(len(prompt), ref, [1], cfg.vocab)  # accept all
    eng = ServingEngine(params, cfg, _spec_cfg(batch=1, spec_k=2,
                                               draft=draft))
    eng.submit(Request(rid=0, prompt=prompt, max_new=12))
    eng.run_until_drained()
    assert eng.finished[0] == ref
    assert eng.spec_emitted <= eng.spec_accepted + eng.spec_ticks
    # All-accepted drafts: every full tick emits spec_k + 1 tokens.
    assert eng.spec_emitted == 11             # 12 minus the prefill token


# ----------------------------------------------------------------------------
# Draft sources
# ----------------------------------------------------------------------------

def test_ngram_draft_lookup_and_backoff():
    d = spec.NgramDraft(n=3)
    h = np.asarray([5, 6, 7, 9, 5, 6, 7], np.int32)
    np.testing.assert_array_equal(d.propose(h, 1), [9])    # 3-gram hit
    h2 = np.asarray([1, 2, 3, 4, 9, 9, 2], np.int32)
    np.testing.assert_array_equal(d.propose(h2, 1), [3])   # backoff to 1
    assert d.propose(np.asarray([1, 2, 3], np.int32), 2).size == 0


def test_ngram_draft_extends_cyclically_at_tail():
    """A periodic tail must draft k full tokens, not the one or two left
    before the end of history — that is where the accept wins live."""
    d = spec.NgramDraft(n=3)
    h = np.asarray([9, 8] + [4, 4, 4, 4, 4], np.int32)
    np.testing.assert_array_equal(d.propose(h, 4), [4, 4, 4, 4])
    h2 = np.asarray([1, 7, 0, 7, 0, 7, 0], np.int32)
    np.testing.assert_array_equal(d.propose(h2, 4), [7, 0, 7, 0])


def test_model_draft_self_speculation_matches_greedy(model):
    """ModelDraft with the target model and a window covering the whole
    context proposes exactly the greedy continuation (the rollout is the
    bucketed-prefill + greedy-decode pattern)."""
    cfg, params = model
    rng = np.random.RandomState(9)
    prompt = rng.randint(2, cfg.vocab, 9).astype(np.int32)
    ref = _ref(params, cfg, prompt, 3, max_len=16)
    d = spec.ModelDraft(params, cfg, window=16)
    np.testing.assert_array_equal(d.propose(prompt, 3), ref)


def test_resolve_draft_variants(model):
    cfg, params = model
    assert isinstance(spec.resolve_draft(None, cfg, params),
                      spec.NgramDraft)
    assert isinstance(spec.resolve_draft("ngram", cfg, params),
                      spec.NgramDraft)
    md = spec.resolve_draft("self", cfg, params)
    assert isinstance(md, spec.ModelDraft) and md.params is params
    custom = spec.NgramDraft(n=2)
    assert spec.resolve_draft(custom, cfg, params) is custom


def test_longest_accept_bookkeeping():
    assert spec.longest_accept([3, 4], [3, 4, 9]) == (2, [3, 4, 9])
    assert spec.longest_accept([3, 5], [3, 4, 9]) == (1, [3, 4])
    assert spec.longest_accept([7], [3, 1]) == (0, [3])
    assert spec.longest_accept([], [6]) == (0, [6])
