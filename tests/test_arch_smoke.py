"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, output shapes + no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.train import steps as train_steps


def _frontend(cfg, b):
    if cfg.n_frontend_tokens:
        return jax.random.normal(
            jax.random.PRNGKey(99),
            (b, cfg.n_frontend_tokens, cfg.d_model)).astype(cfg.dtype)
    return None


@pytest.mark.parametrize("arch", configs.list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    fe = _frontend(cfg, b)
    logits, _, aux = T.forward(params, cfg, tokens, frontend_embeds=fe)
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    assert not bool(jnp.isnan(aux)), arch

    state = train_steps.init_state(jax.random.PRNGKey(2), cfg)
    step = train_steps.make_train_step(cfg)
    batch = {"tokens": tokens, "labels": tokens}
    if fe is not None:
        batch["frontend"] = fe
    new_tree, metrics = step(state.tree(), batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(np.asarray(new_tree["step"])) == 1
    # Params actually changed.
    delta = jax.tree.map(lambda a, b_: float(jnp.abs(a - b_).max()),
                         state.params, new_tree["params"])
    assert max(jax.tree.leaves(delta)) > 0, arch


@pytest.mark.parametrize("arch", configs.list_archs())
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b = 2
    fe = _frontend(cfg, b)
    caches = T.init_caches(cfg, b, 8)
    tok = jnp.ones((b, 1), jnp.int32)
    logits, caches, _ = T.forward(params, cfg, tok, frontend_embeds=fe,
                                  caches=caches)
    assert logits.shape == (b, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), arch


def test_full_configs_match_assignment_table():
    spot = {
        "qwen3-4b": dict(n_layers=36, d_model=2560, n_heads=32,
                         n_kv_heads=8, d_ff=9728, vocab=151936),
        "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48,
                          n_kv_heads=8, d_ff=10752, vocab=100352,
                          n_experts=16, top_k=4),
        "llama4-maverick-400b-a17b": dict(n_layers=48, d_model=5120,
                                          n_heads=40, n_experts=128,
                                          top_k=1, vocab=202048),
        "jamba-v0.1-52b": dict(n_layers=32, d_model=4096, d_ff=14336,
                               vocab=65536, n_experts=16, top_k=2),
        "llama-3.2-vision-90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=28672, vocab=128256),
        "mamba2-370m": dict(n_layers=48, d_model=1024, d_ff=0, vocab=50280,
                            mamba_d_state=128),
    }
    for arch, fields in spot.items():
        cfg = configs.get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k)


def test_jamba_pattern_is_1_to_7():
    cfg = configs.get_config("jamba-v0.1-52b")
    assert len(cfg.pattern) == 8
    assert cfg.pattern.count("attn") == 1
    assert cfg.pattern.count("mamba") == 7


def test_vision_pattern_cross_every_5():
    cfg = configs.get_config("llama-3.2-vision-90b")
    assert len(cfg.pattern) == 5 and cfg.pattern.count("cross") == 1


def test_param_counts_near_published():
    expect = {"qwen3-4b": (4.06e9, 0.08), "dbrx-132b": (132e9, 0.05),
              "jamba-v0.1-52b": (52e9, 0.05),
              "llama-3.2-vision-90b": (90e9, 0.05),
              "mamba2-370m": (0.42e9, 0.2)}
    for arch, (n, tol) in expect.items():
        got = T.param_count(configs.get_config(arch))
        assert abs(got - n) / n < tol, (arch, got)
