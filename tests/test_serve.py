"""Serving: decode==forward consistency, engine vs reference generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                greedy_generate, prefill)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_prefill_then_decode_matches_full_forward(model):
    cfg, params = model
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab)
    logits_full, _, _ = T.forward(params, cfg, tokens)
    caches = T.init_caches(cfg, 2, 16)
    last, caches = prefill(params, cfg, tokens[:, :-1], caches)
    logits_dec, _, _ = T.forward(params, cfg, tokens[:, -1:], caches=caches)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_engine_matches_reference_generation(model):
    cfg, params = model
    rng = np.random.RandomState(0)
    prompts = [rng.randint(2, cfg.vocab, size=6).astype(np.int32)
               for _ in range(4)]
    max_new = 6
    # Reference: per-prompt greedy loop.
    expect = {}
    for rid, pr in enumerate(prompts):
        out = greedy_generate(params, cfg, jnp.asarray(pr)[None], max_new,
                              max_len=32)
        expect[rid] = np.asarray(out[0]).tolist()
    # Engine with 2 slots over 4 requests (forces slot reuse).
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=-1))
    for rid, pr in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=pr, max_new=max_new))
    got = eng.run_until_drained()
    assert set(got) == set(expect)
    for rid in expect:
        assert got[rid] == expect[rid], rid


def test_engine_mixed_prompt_lengths(model):
    cfg, params = model
    rng = np.random.RandomState(1)
    prompts = {0: rng.randint(2, cfg.vocab, 3).astype(np.int32),
               1: rng.randint(2, cfg.vocab, 11).astype(np.int32)}
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=-1))
    for rid, pr in prompts.items():
        eng.submit(Request(rid=rid, prompt=pr, max_new=4))
    got = eng.run_until_drained()
    for rid, pr in prompts.items():
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], 4,
                              max_len=32)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid


def test_engine_staggered_admission_matches_reference(model):
    """Requests arriving mid-stream (slot churn + mixed buckets) produce
    exactly the reference token streams."""
    cfg, params = model
    rng = np.random.RandomState(7)
    prompts = {rid: rng.randint(2, cfg.vocab, size=n).astype(np.int32)
               for rid, n in enumerate((3, 6, 7, 11))}
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=-1))
    eng.submit(Request(rid=0, prompt=prompts[0], max_new=5))
    eng.submit(Request(rid=1, prompt=prompts[1], max_new=5))
    eng.tick()
    eng.tick()
    eng.submit(Request(rid=2, prompt=prompts[2], max_new=5))
    eng.tick()
    eng.submit(Request(rid=3, prompt=prompts[3], max_new=5))
    got = eng.run_until_drained()
    for rid, pr in prompts.items():
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], 5,
                              max_len=32)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid


def test_engine_compiles_one_prefill_executable_per_bucket(model):
    """Prompts pad to power-of-two buckets; every bucket traces exactly
    once no matter how many prompt lengths map into it."""
    cfg, params = model
    rng = np.random.RandomState(8)
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=-1))
    for rid, n in enumerate((3, 4, 5, 6, 7, 8, 9, 11, 13, 15)):
        eng.submit(Request(rid=rid,
                           prompt=rng.randint(2, cfg.vocab, n)
                           .astype(np.int32), max_new=3))
    eng.run_until_drained()
    # 10 distinct prompt lengths, two buckets (8 and 16), one trace each.
    assert set(eng.prefill_traces) == {8, 16}
    assert all(n == 1 for n in eng.prefill_traces.values()), \
        eng.prefill_traces
    assert eng.decode_traces == 1


def test_engine_bucket_for_powers_of_two(model):
    cfg, params = model
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=1))
    assert [eng.bucket_for(n) for n in (1, 8, 9, 16, 17, 30)] == \
        [8, 8, 16, 16, 32, 32]


def test_engine_eos_frees_slot_and_clears_last_tok(model):
    """A finished slot must not feed its stale token back into decode —
    and a stale token equal to eos_id must not re-finish anything."""
    cfg, params = model
    rng = np.random.RandomState(9)
    prompt = rng.randint(2, cfg.vocab, 5).astype(np.int32)
    ref = np.asarray(greedy_generate(params, cfg, jnp.asarray(prompt)[None],
                                     6, max_len=32)[0]).tolist()
    eos = ref[2]                   # force EOS three tokens in
    long_prompt = rng.randint(2, cfg.vocab, 6).astype(np.int32)
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=eos))
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    eng.submit(Request(rid=1, prompt=long_prompt, max_new=10))
    got = eng.run_until_drained()
    assert got[0] == ref[:3]       # truncated at the EOS token
    assert int(np.asarray(eng.last_tok)[0]) == 0   # freed slot parked at 0
    assert len(got[1]) == 10       # neighbor unaffected by the stale slot


def test_engine_tracks_per_slot_context_lengths(model):
    """cache_lengths threads the per-slot write positions out of the
    stacked caches: prompt length + tokens decoded so far, per slot."""
    cfg, params = model
    rng = np.random.RandomState(12)
    p0 = rng.randint(2, cfg.vocab, 4).astype(np.int32)
    p1 = rng.randint(2, cfg.vocab, 9).astype(np.int32)
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=-1))
    eng.submit(Request(rid=0, prompt=p0, max_new=5))
    eng.submit(Request(rid=1, prompt=p1, max_new=5))
    eng.tick()     # prefill both + 1 decoded token
    np.testing.assert_array_equal(eng.context_lengths(), [5, 10])
    eng.tick()
    np.testing.assert_array_equal(eng.context_lengths(), [6, 11])


def test_cache_lengths_shapes_for_both_index_kinds(model):
    cfg, params = model
    per_slot = T.init_caches(cfg, 3, 8, per_slot_index=True)
    assert T.cache_lengths(per_slot).shape == (3,)
    scalar = T.init_caches(cfg, 3, 8)
    got = np.asarray(T.cache_lengths(scalar))
    np.testing.assert_array_equal(got, [0, 0, 0])


def test_engine_freed_slot_resets_cache_length(model):
    """A finished slot's per-slot write position resets, so flash decode
    stops streaming the dead context (length then drifts by one per tick
    until re-admission, never back to the stale value)."""
    cfg, params = model
    rng = np.random.RandomState(13)
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=-1))
    eng.submit(Request(rid=0, prompt=rng.randint(2, cfg.vocab, 6)
                       .astype(np.int32), max_new=2))
    eng.submit(Request(rid=1, prompt=rng.randint(2, cfg.vocab, 4)
                       .astype(np.int32), max_new=8))
    eng.tick()     # rid=0 hits max_new and frees; rid=1 keeps going
    assert 0 in eng.finished and eng.slots[0] is None
    np.testing.assert_array_equal(eng.context_lengths(), [0, 5])
    eng.tick()
    np.testing.assert_array_equal(eng.context_lengths(), [1, 6])


def test_engine_temperature_sampling_smoke(model):
    cfg, params = model
    rng = np.random.RandomState(10)
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=-1, temperature=0.7,
                                                 seed=3))
    for rid in range(3):
        eng.submit(Request(rid=rid,
                           prompt=rng.randint(2, cfg.vocab, 5)
                           .astype(np.int32), max_new=4))
    got = eng.run_until_drained()
    assert set(got) == {0, 1, 2}
    for toks in got.values():
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab for t in toks)


def test_engine_flash_decode_path_matches_reference(model):
    """use_flash threads the flash-decode kernel through engine decode;
    token streams must stay identical to the sdpa reference."""
    import dataclasses

    cfg, params = model
    fcfg = dataclasses.replace(cfg, use_flash=True)
    rng = np.random.RandomState(11)
    prompts = {0: rng.randint(2, cfg.vocab, 4).astype(np.int32),
               1: rng.randint(2, cfg.vocab, 9).astype(np.int32)}
    eng = ServingEngine(params, fcfg, ServeConfig(max_len=32, batch=2,
                                                  eos_id=-1))
    for rid, pr in prompts.items():
        eng.submit(Request(rid=rid, prompt=pr, max_new=4))
    got = eng.run_until_drained()
    for rid, pr in prompts.items():
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], 4,
                              max_len=32)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid


def test_mamba_generation_consistency():
    cfg = configs.get_smoke("mamba2-370m")
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab)
    out1 = greedy_generate(params, cfg, prompt, 5, max_len=16)
    # Teacher-forced check: feeding generated tokens reproduces argmax chain.
    seq = jnp.concatenate([prompt, out1[:, :-1]], axis=1)
    logits, _, _ = T.forward(params, cfg, seq)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(logits[:, prompt.shape[1] - 1:], -1)),
        np.asarray(out1))
