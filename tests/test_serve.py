"""Serving: decode==forward consistency, engine vs reference generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                greedy_generate, prefill)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_prefill_then_decode_matches_full_forward(model):
    cfg, params = model
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab)
    logits_full, _, _ = T.forward(params, cfg, tokens)
    caches = T.init_caches(cfg, 2, 16)
    last, caches = prefill(params, cfg, tokens[:, :-1], caches)
    logits_dec, _, _ = T.forward(params, cfg, tokens[:, -1:], caches=caches)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_engine_matches_reference_generation(model):
    cfg, params = model
    rng = np.random.RandomState(0)
    prompts = [rng.randint(2, cfg.vocab, size=6).astype(np.int32)
               for _ in range(4)]
    max_new = 6
    # Reference: per-prompt greedy loop.
    expect = {}
    for rid, pr in enumerate(prompts):
        out = greedy_generate(params, cfg, jnp.asarray(pr)[None], max_new,
                              max_len=32)
        expect[rid] = np.asarray(out[0]).tolist()
    # Engine with 2 slots over 4 requests (forces slot reuse).
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=-1))
    for rid, pr in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=pr, max_new=max_new))
    got = eng.run_until_drained()
    assert set(got) == set(expect)
    for rid in expect:
        assert got[rid] == expect[rid], rid


def test_engine_mixed_prompt_lengths(model):
    cfg, params = model
    rng = np.random.RandomState(1)
    prompts = {0: rng.randint(2, cfg.vocab, 3).astype(np.int32),
               1: rng.randint(2, cfg.vocab, 11).astype(np.int32)}
    eng = ServingEngine(params, cfg, ServeConfig(max_len=32, batch=2,
                                                 eos_id=-1))
    for rid, pr in prompts.items():
        eng.submit(Request(rid=rid, prompt=pr, max_new=4))
    got = eng.run_until_drained()
    for rid, pr in prompts.items():
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], 4,
                              max_len=32)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid


def test_mamba_generation_consistency():
    cfg = configs.get_smoke("mamba2-370m")
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab)
    out1 = greedy_generate(params, cfg, prompt, 5, max_len=16)
    # Teacher-forced check: feeding generated tokens reproduces argmax chain.
    seq = jnp.concatenate([prompt, out1[:, :-1]], axis=1)
    logits, _, _ = T.forward(params, cfg, seq)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(logits[:, prompt.shape[1] - 1:], -1)),
        np.asarray(out1))
