"""Integration: the 512-device dry-run lowers+compiles real cells (run in a
subprocess so the test session keeps its single CPU device)."""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_dryrun(tmp_path, *args):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = tmp_path / "cells.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--out", str(out),
         *args],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-3000:]
    return json.loads(out.read_text()), proc.stdout


@pytest.mark.slow
def test_dryrun_single_pod_cell(tmp_path):
    cells, stdout = _run_dryrun(tmp_path, "--arch", "mamba2-370m",
                                "--shape", "decode_32k", "--mesh", "single")
    (cell,) = cells
    assert cell["ok"] and not cell["skipped"]
    assert cell["mesh"] == "data=16xmodel=16"
    assert cell["cost"]["flops"] > 0
    assert cell["memory"]["argument_bytes"] > 0
    assert cell["roofline"]["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_dryrun_multi_pod_train_cell(tmp_path):
    cells, _ = _run_dryrun(tmp_path, "--arch", "qwen2-0.5b",
                           "--shape", "train_4k", "--mesh", "multi")
    (cell,) = cells
    assert cell["ok"]
    assert cell["mesh"] == "pod=2xdata=16xmodel=16"
    assert cell["collective_bytes"] > 0       # pod axis actually shards


def test_long_500k_skip_policy(tmp_path):
    from repro.configs import shapes

    ok, why = shapes.runnable("qwen3-4b", "long_500k")
    assert not ok and "quadratic" in why
    for arch in shapes.SUBQUADRATIC:
        ok, _ = shapes.runnable(arch, "long_500k")
        assert ok


def test_baseline_artifact_covers_all_cells():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "artifacts", "dryrun_baseline.json")
    if not os.path.exists(path):
        pytest.skip("baseline sweep artifact not generated yet")
    cells = json.load(open(path))
    by_mesh = {}
    for c in cells:
        by_mesh.setdefault(c["mesh"], []).append(c)
    assert set(by_mesh) == {"data=16xmodel=16", "pod=2xdata=16xmodel=16"}
    for mesh, items in by_mesh.items():
        assert len(items) == 40
        assert all(c["ok"] for c in items)
        assert sum(c["skipped"] for c in items) == 8   # long_500k skips
