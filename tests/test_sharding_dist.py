"""Sharding rules (divisibility fallback), compression, and multi-device
shard_map paths (collective matmul, pipeline, elastic checkpoints) — the
multi-device parts run in one subprocess with 8 host devices."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import compression, sharding as shd
from jax.sharding import PartitionSpec as P


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_divisibility_fallback():
    rs = shd.Ruleset(mesh=FakeMesh({"data": 16, "model": 16}))
    # 14 heads don't divide 16 -> replicated; 32 heads do -> sharded.
    assert rs.spec(["heads"], [14]) == P(None)
    assert rs.spec(["heads"], [32]) == P("model")
    assert rs.spec(["batch", None], [256, 4096]) == P(("pod", "data"), None) \
        or rs.spec(["batch", None], [256, 4096]) == P("data", None)


def test_batch_composes_pod_and_data():
    rs = shd.Ruleset(mesh=FakeMesh({"pod": 2, "data": 16, "model": 16}))
    assert rs.spec(["batch"], [256]) == P(("pod", "data"))
    # batch=1 cannot shard.
    assert rs.spec(["batch"], [1]) == P(None)


def test_param_specs_by_leaf_name():
    rs = shd.Ruleset(mesh=FakeMesh({"data": 16, "model": 16}))
    assert shd.param_spec(("blocks", "attn", "wq"), (24, 896, 32, 64), rs) \
        == P(None, None, "model", None)
    # qwen2: 14 heads replicate.
    assert shd.param_spec(("blocks", "attn", "wq"), (24, 896, 14, 64), rs) \
        == P(None, None, None, None)
    assert shd.param_spec(("mlp", "w_gate"), (4096, 12800), rs) \
        == P(None, "model")
    assert shd.param_spec(("moe", "expert_gate"), (16, 4096, 10752), rs) \
        == P("model", None, None)


def test_fsdp_shards_largest_free_dim():
    rs = shd.Ruleset(mesh=FakeMesh({"data": 16, "model": 16}), fsdp=True)
    spec = shd.param_spec(("mlp", "w_gate"), (4096, 12800), rs)
    assert spec == P("data", "model")


def test_shard_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert shd.shard(x, "batch", None) is x


def test_int8_compression_error_bound():
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(1000), jnp.float32)}
    out = compression.int8_roundtrip(g)
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).max() / 127
    assert err.max() <= scale * 1.01


def test_error_feedback_accumulates():
    g = {"w": jnp.full((256,), 0.004, jnp.float32) +
         jnp.linspace(0, 1e-4, 256)}
    res = compression.ErrorFeedback.init(g)
    comp, res = compression.ErrorFeedback.compress(g, res)
    # Residual is exactly the quantization error.
    np.testing.assert_allclose(
        np.asarray(res["w"]),
        np.asarray(g["w"]) - np.asarray(comp["w"]), atol=1e-7)


def test_error_feedback_threads_through_train_step():
    """EF-SGD end to end: the residual lives in TrainState, the jitted
    step consumes and refreshes it, and plain states keep the old pytree
    (no ``ef`` leaf — checkpoints and sharding derivations unchanged)."""
    from repro import configs
    from repro.data import DataConfig, SyntheticLMData
    from repro.train import steps as train_steps

    cfg = configs.get_smoke("qwen3-4b")
    plain = train_steps.init_state(jax.random.PRNGKey(0), cfg)
    assert "ef" not in plain.tree()
    state = train_steps.init_state(jax.random.PRNGKey(0), cfg,
                                   error_feedback=True).tree()
    assert "ef" in state
    assert all(float(jnp.abs(l).max()) == 0.0
               for l in jax.tree.leaves(state["ef"]))

    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=16,
                                      global_batch=2, seed=0))
    step = jax.jit(train_steps.make_train_step(
        cfg, compress_grads=True, error_feedback=True), donate_argnums=(0,))
    for i in range(2):
        tokens, labels = data.batch_at(i)
        state, metrics = step(state, {"tokens": jnp.asarray(tokens),
                                      "labels": jnp.asarray(labels)})
    assert np.isfinite(float(metrics["loss"]))
    # The residual is the quantization error — nonzero for real gradients.
    assert any(float(jnp.abs(l).max()) > 0.0
               for l in jax.tree.leaves(state["ef"]))
    # Round-trips through TrainState (checkpoint restore path).
    rt = train_steps.TrainState.from_tree(state)
    assert rt.ef is not None and int(np.asarray(rt.step)) == 2


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.launch import mesh as mesh_mod
from repro.dist import collective_matmul, pipeline, sharding as shd
from repro.checkpoint import CheckpointManager

results = {}

# 1. Collective (overlapped all-gather) matmul == dense matmul.
mesh = mesh_mod.make_mesh((2, 4), ("data", "model"))
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(16, 32), jnp.float32)
w = jnp.asarray(rng.randn(32, 24), jnp.float32)
out = collective_matmul.ag_matmul(x, w, mesh, axis="model")
np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), rtol=1e-4,
                           atol=1e-4)
hlo = jax.jit(lambda x, w: collective_matmul.ag_matmul(x, w, mesh,
              "model")).lower(x, w).compile().as_text()
assert "collective-permute" in hlo and "all-gather" not in hlo.split(
    "ENTRY")[-1], "overlap should replace the big all-gather"
results["collective_matmul"] = "ok"

# 2. GPipe pipeline == sequential stack.
pmesh = mesh_mod.make_mesh((4,), ("stage",))
def layer(wb, x):
    return jnp.tanh(x @ wb["w"] + wb["b"])
ws = {"w": jnp.asarray(rng.randn(4, 8, 8) * 0.5, jnp.float32),
      "b": jnp.asarray(rng.randn(4, 8) * 0.1, jnp.float32)}
micro = jnp.asarray(rng.randn(6, 5, 8), jnp.float32)
piped = pipeline.gpipe(layer, pmesh, axis="stage")(ws, micro)
seq = micro
for i in range(4):
    seq = layer({"w": ws["w"][i], "b": ws["b"][i]}, seq)
np.testing.assert_allclose(np.asarray(piped), np.asarray(seq), rtol=1e-4,
                           atol=1e-4)
assert abs(pipeline.bubble_fraction(4, 6) - 3/9) < 1e-9
results["pipeline"] = "ok"

# 3. Elastic checkpoint: save unsharded, restore sharded onto a mesh, then
#    back onto a differently-shaped mesh.
import tempfile
d = tempfile.mkdtemp()
tree = {"mlp": {"w_gate": jnp.asarray(rng.randn(32, 64), jnp.float32)}}
mgr = CheckpointManager(d)
mgr.save(3, tree)
mgr.wait()
for shape, axes in (((2, 4), ("data", "model")), ((4, 2), ("data", "model"))):
    m = mesh_mod.make_mesh(shape, axes)
    rs = shd.Ruleset(mesh=m, fsdp=True)
    got, _ = mgr.restore(jax.tree.map(jnp.zeros_like, tree), ruleset=rs)
    np.testing.assert_allclose(np.asarray(got["mlp"]["w_gate"]),
                               np.asarray(tree["mlp"]["w_gate"]))
    assert len(got["mlp"]["w_gate"].sharding.device_set) > 1
results["elastic"] = "ok"

print("MULTIDEV_RESULTS:" + ",".join(f"{k}={v}" for k, v in results.items()))
"""


@pytest.mark.slow
def test_multidevice_shard_map_paths(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "multidev.py"
    script.write_text(MULTIDEV_SCRIPT)
    proc = subprocess.run([sys.executable, str(script), src],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "collective_matmul=ok" in proc.stdout
    assert "pipeline=ok" in proc.stdout
    assert "elastic=ok" in proc.stdout
