"""Attention cost model: monotonicity + feasibility properties, and the
persistent tuning cache round-trip."""

import dataclasses
import json
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import autotune, hwmodel


def _problem(sq, skv, causal=True, heads=8, d=128, batch=1):
    return autotune.AttnProblem(sq=sq, skv=skv, n_heads=heads, head_dim=d,
                                batch=batch, causal=causal)


@given(skv=st.sampled_from([512, 1024, 2048, 4096, 8192]),
       causal=st.sampled_from([True, False]))
@settings(max_examples=10, deadline=None)
def test_attn_cost_monotone_in_kv_length(skv, causal):
    c = autotune.AttnBlock(128, 128)
    t1, _ = autotune.attn_cost(_problem(512, skv, causal), c)
    t2, _ = autotune.attn_cost(_problem(512, 2 * skv, causal), c)
    assert t2 > t1


@given(sq=st.sampled_from([256, 512, 1024, 2048]))
@settings(max_examples=8, deadline=None)
def test_attn_cost_monotone_in_query_length(sq):
    c = autotune.AttnBlock(128, 128)
    t1, _ = autotune.attn_cost(_problem(sq, 4096), c)
    t2, _ = autotune.attn_cost(_problem(2 * sq, 4096), c)
    assert t2 > t1


@given(batch=st.integers(1, 8), heads=st.sampled_from([4, 8, 16]))
@settings(max_examples=8, deadline=None)
def test_attn_cost_monotone_in_rows(batch, heads):
    c = autotune.AttnBlock(128, 128)
    p = _problem(512, 2048, heads=heads, batch=batch)
    t1, _ = autotune.attn_cost(p, c)
    t2, _ = autotune.attn_cost(dataclasses.replace(p, batch=2 * batch), c)
    assert t2 > t1


@given(sq=st.sampled_from([1024, 2048, 4096]),
       bk=st.sampled_from([128, 256, 512]))
@settings(max_examples=8, deadline=None)
def test_causal_skips_work_and_traffic(sq, bk):
    """The skipped-load grid visits ~half the blocks of the full grid."""
    c = autotune.AttnBlock(128, bk)
    _, terms_c = autotune.attn_cost(_problem(sq, sq, causal=True), c)
    _, terms_f = autotune.attn_cost(_problem(sq, sq, causal=False), c)
    assert terms_c["visited_blocks"] < terms_f["visited_blocks"]
    assert terms_c["traffic_bytes"] < terms_f["traffic_bytes"]
    # Block-granular triangle: between half and half-plus-one-diagonal.
    frac = terms_c["visited_blocks"] / terms_f["visited_blocks"]
    assert 0.5 <= frac <= 0.5 + bk / sq + 1e-9


@given(sq=st.sampled_from([256, 1024, 4096, 16384]),
       causal=st.sampled_from([True, False]))
@settings(max_examples=10, deadline=None)
def test_choose_attn_block_beats_or_ties_naive(sq, causal):
    p = _problem(sq, sq, causal)
    cfg, terms = autotune.choose_attn_block(p, use_cache=False)
    t_naive, _ = autotune.attn_cost(p, autotune.NAIVE_ATTN_BLOCK)
    assert terms["time_s"] <= t_naive + 1e-12
    budget = hwmodel.DEFAULT_TPU.vmem_bytes * 0.5
    assert cfg.vmem_bytes(p) <= budget


def test_candidates_respect_vmem_budget():
    p = _problem(8192, 8192)
    for c in autotune.candidate_attn_blocks(p):
        assert c.vmem_bytes(p) <= hwmodel.DEFAULT_TPU.vmem_bytes * 0.5


def test_decode_speedup_gt_one_for_ragged_contexts():
    out = autotune.decode_attn_speedup(
        32768, [512, 4096, 16384, 32768], n_heads=32, n_kv_heads=8,
        head_dim=128)
    assert out["speedup"] > 1.0
    full = autotune.decode_attn_speedup(
        32768, [32768, 32768], n_heads=32, n_kv_heads=8, head_dim=128)
    assert full["speedup"] == pytest.approx(1.0)


@pytest.mark.parametrize("garbage", [
    '{"tpu_v5e:sq=1024', " ", "\x00\x01binary", "null", "[1, 2, 3]", '"str"',
])
def test_tuning_cache_recovers_from_corrupt_file(tmp_path, monkeypatch,
                                                 garbage):
    """A torn concurrent write (truncated / binary / non-object JSON)
    must not crash the cache: the bad file is discarded and the next
    write-through rebuilds it."""
    path = tmp_path / "cache.json"
    path.write_text(garbage)
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH", str(path))
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    p = _problem(1024, 1024)
    cfg, terms = autotune.choose_attn_block(p)
    assert "cached" not in terms              # recovered, not served stale
    analytic, _ = autotune.choose_attn_block(p, use_cache=False)
    assert cfg == analytic
    rebuilt = json.load(open(path))           # rebuilt clean by the store
    assert isinstance(rebuilt, dict) and len(rebuilt) == 1


def test_tuning_cache_tolerates_malformed_entry(tmp_path, monkeypatch):
    """A structurally-broken entry (file parses, entry torn) is a miss and
    gets overwritten with a good one."""
    path = tmp_path / "cache.json"
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH", str(path))
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    p = _problem(1024, 1024)
    key = autotune._cache_key(p, hwmodel.DEFAULT_TPU)
    for bad in ({"block_q": 128}, "torn", {"block_q": "x", "block_k": 1,
                                           "terms": {}, "time_s": 0.0}):
        path.write_text(json.dumps({key: bad}))
        monkeypatch.setattr(autotune, "_tuning_cache", None)
        cfg, terms = autotune.choose_attn_block(p)
        assert "cached" not in terms, bad
        assert cfg == autotune.choose_attn_block(p, use_cache=False)[0]
        stored = json.load(open(path))[key]
        assert stored["block_q"] == cfg.block_q   # overwritten in place


def test_tuning_cache_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH", str(path))
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    p = _problem(1024, 1024)
    cfg, terms = autotune.choose_attn_block(p)
    assert "cached" not in terms
    assert os.path.exists(path)
    stored = json.load(open(path))
    assert len(stored) == 1
    # Second call (fresh in-memory cache) serves the persisted entry.
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    cfg2, terms2 = autotune.choose_attn_block(p)
    assert cfg2 == cfg
    assert terms2["cached"] is True
    assert terms2["time_s"] == pytest.approx(terms["time_s"])


# ----------------------------------------------------------------------------
# Chunked-prefill cost model
# ----------------------------------------------------------------------------

def test_prefill_chunk_model_terms():
    """The chunk-size trade's two ends: the whole-prompt 'chunk' has the
    worst interleave latency (one chunk = the whole prefill), small chunks
    pay more dispatches; the lookup term scales with visited blocks."""
    dims = dict(n_heads=32, n_kv_heads=8, head_dim=128, page_size=256)
    small = autotune.prefill_chunk_model(8192, 256, **dims)
    whole = autotune.prefill_chunk_model(8192, 8192, **dims)
    assert small["n_chunks"] == 32 and whole["n_chunks"] == 1
    assert small["interleave_latency_s"] < whole["interleave_latency_s"]
    assert small["dispatch_s"] > whole["dispatch_s"]
    assert whole["interleave_latency_s"] == pytest.approx(
        whole["prefill_s"])
    for terms in (small, whole):
        assert terms["prefill_s"] == pytest.approx(
            terms["attn_s"] + terms["lookup_s"] + terms["dispatch_s"])
        assert terms["lookup_s"] > 0


def test_choose_prefill_chunk_is_page_aligned_and_bounded():
    chunk, terms = autotune.choose_prefill_chunk(
        32768, n_heads=32, n_kv_heads=8, head_dim=128, page_size=256)
    assert chunk % 256 == 0 and 256 <= chunk <= 32768
    assert terms["score_s"] >= terms["prefill_s"]
    # A chunk far below max_len must win once latency is priced at all:
    # whole-prompt prefill stalls every decode slot for the full prompt.
    assert chunk < 32768
