"""Attention cost model: monotonicity + feasibility properties, and the
persistent tuning cache round-trip."""

import dataclasses
import json
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import autotune, hwmodel


def _problem(sq, skv, causal=True, heads=8, d=128, batch=1):
    return autotune.AttnProblem(sq=sq, skv=skv, n_heads=heads, head_dim=d,
                                batch=batch, causal=causal)


@given(skv=st.sampled_from([512, 1024, 2048, 4096, 8192]),
       causal=st.sampled_from([True, False]))
@settings(max_examples=10, deadline=None)
def test_attn_cost_monotone_in_kv_length(skv, causal):
    c = autotune.AttnBlock(128, 128)
    t1, _ = autotune.attn_cost(_problem(512, skv, causal), c)
    t2, _ = autotune.attn_cost(_problem(512, 2 * skv, causal), c)
    assert t2 > t1


@given(sq=st.sampled_from([256, 512, 1024, 2048]))
@settings(max_examples=8, deadline=None)
def test_attn_cost_monotone_in_query_length(sq):
    c = autotune.AttnBlock(128, 128)
    t1, _ = autotune.attn_cost(_problem(sq, 4096), c)
    t2, _ = autotune.attn_cost(_problem(2 * sq, 4096), c)
    assert t2 > t1


@given(batch=st.integers(1, 8), heads=st.sampled_from([4, 8, 16]))
@settings(max_examples=8, deadline=None)
def test_attn_cost_monotone_in_rows(batch, heads):
    c = autotune.AttnBlock(128, 128)
    p = _problem(512, 2048, heads=heads, batch=batch)
    t1, _ = autotune.attn_cost(p, c)
    t2, _ = autotune.attn_cost(dataclasses.replace(p, batch=2 * batch), c)
    assert t2 > t1


@given(sq=st.sampled_from([1024, 2048, 4096]),
       bk=st.sampled_from([128, 256, 512]))
@settings(max_examples=8, deadline=None)
def test_causal_skips_work_and_traffic(sq, bk):
    """The skipped-load grid visits ~half the blocks of the full grid."""
    c = autotune.AttnBlock(128, bk)
    _, terms_c = autotune.attn_cost(_problem(sq, sq, causal=True), c)
    _, terms_f = autotune.attn_cost(_problem(sq, sq, causal=False), c)
    assert terms_c["visited_blocks"] < terms_f["visited_blocks"]
    assert terms_c["traffic_bytes"] < terms_f["traffic_bytes"]
    # Block-granular triangle: between half and half-plus-one-diagonal.
    frac = terms_c["visited_blocks"] / terms_f["visited_blocks"]
    assert 0.5 <= frac <= 0.5 + bk / sq + 1e-9


@given(sq=st.sampled_from([256, 1024, 4096, 16384]),
       causal=st.sampled_from([True, False]))
@settings(max_examples=10, deadline=None)
def test_choose_attn_block_beats_or_ties_naive(sq, causal):
    p = _problem(sq, sq, causal)
    cfg, terms = autotune.choose_attn_block(p, use_cache=False)
    t_naive, _ = autotune.attn_cost(p, autotune.NAIVE_ATTN_BLOCK)
    assert terms["time_s"] <= t_naive + 1e-12
    budget = hwmodel.DEFAULT_TPU.vmem_bytes * 0.5
    assert cfg.vmem_bytes(p) <= budget


def test_candidates_respect_vmem_budget():
    p = _problem(8192, 8192)
    for c in autotune.candidate_attn_blocks(p):
        assert c.vmem_bytes(p) <= hwmodel.DEFAULT_TPU.vmem_bytes * 0.5


def test_decode_speedup_gt_one_for_ragged_contexts():
    out = autotune.decode_attn_speedup(
        32768, [512, 4096, 16384, 32768], n_heads=32, n_kv_heads=8,
        head_dim=128)
    assert out["speedup"] > 1.0
    full = autotune.decode_attn_speedup(
        32768, [32768, 32768], n_heads=32, n_kv_heads=8, head_dim=128)
    assert full["speedup"] == pytest.approx(1.0)


@pytest.mark.parametrize("garbage", [
    '{"tpu_v5e:sq=1024', " ", "\x00\x01binary", "null", "[1, 2, 3]", '"str"',
])
def test_tuning_cache_recovers_from_corrupt_file(tmp_path, monkeypatch,
                                                 garbage):
    """A torn concurrent write (truncated / binary / non-object JSON)
    must not crash the cache: the bad file is discarded and the next
    write-through rebuilds it."""
    path = tmp_path / "cache.json"
    path.write_text(garbage)
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH", str(path))
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    p = _problem(1024, 1024)
    cfg, terms = autotune.choose_attn_block(p)
    assert "cached" not in terms              # recovered, not served stale
    analytic, _ = autotune.choose_attn_block(p, use_cache=False)
    assert cfg == analytic
    rebuilt = json.load(open(path))           # rebuilt clean by the store
    assert isinstance(rebuilt, dict) and len(rebuilt) == 1


def test_tuning_cache_tolerates_malformed_entry(tmp_path, monkeypatch):
    """A structurally-broken entry (file parses, entry torn) is a miss and
    gets overwritten with a good one."""
    path = tmp_path / "cache.json"
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH", str(path))
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    p = _problem(1024, 1024)
    key = autotune._cache_key(p, hwmodel.DEFAULT_TPU)
    for bad in ({"block_q": 128}, "torn", {"block_q": "x", "block_k": 1,
                                           "terms": {}, "time_s": 0.0}):
        path.write_text(json.dumps({key: bad}))
        monkeypatch.setattr(autotune, "_tuning_cache", None)
        cfg, terms = autotune.choose_attn_block(p)
        assert "cached" not in terms, bad
        assert cfg == autotune.choose_attn_block(p, use_cache=False)[0]
        stored = json.load(open(path))[key]
        assert stored["block_q"] == cfg.block_q   # overwritten in place


def test_tuning_cache_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH", str(path))
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    p = _problem(1024, 1024)
    cfg, terms = autotune.choose_attn_block(p)
    assert "cached" not in terms
    assert os.path.exists(path)
    stored = json.load(open(path))
    assert len(stored) == 1
    # Second call (fresh in-memory cache) serves the persisted entry.
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    cfg2, terms2 = autotune.choose_attn_block(p)
    assert cfg2 == cfg
    assert terms2["cached"] is True
    assert terms2["time_s"] == pytest.approx(terms["time_s"])


# ----------------------------------------------------------------------------
# Chunked-prefill cost model
# ----------------------------------------------------------------------------

def test_prefill_chunk_model_terms():
    """The chunk-size trade's two ends: the whole-prompt 'chunk' has the
    worst interleave latency (one chunk = the whole prefill), small chunks
    pay more dispatches; the lookup term scales with visited blocks."""
    dims = dict(n_heads=32, n_kv_heads=8, head_dim=128, page_size=256)
    small = autotune.prefill_chunk_model(8192, 256, **dims)
    whole = autotune.prefill_chunk_model(8192, 8192, **dims)
    assert small["n_chunks"] == 32 and whole["n_chunks"] == 1
    assert small["interleave_latency_s"] < whole["interleave_latency_s"]
    assert small["dispatch_s"] > whole["dispatch_s"]
    assert whole["interleave_latency_s"] == pytest.approx(
        whole["prefill_s"])
    for terms in (small, whole):
        assert terms["prefill_s"] == pytest.approx(
            terms["attn_s"] + terms["lookup_s"] + terms["dispatch_s"])
        assert terms["lookup_s"] > 0


def test_choose_prefill_chunk_is_page_aligned_and_bounded():
    chunk, terms = autotune.choose_prefill_chunk(
        32768, n_heads=32, n_kv_heads=8, head_dim=128, page_size=256)
    assert chunk % 256 == 0 and 256 <= chunk <= 32768
    assert terms["score_s"] >= terms["prefill_s"]
    # A chunk far below max_len must win once latency is priced at all:
    # whole-prompt prefill stalls every decode slot for the full prompt.
    assert chunk < 32768


# ----------------------------------------------------------------------------
# Speculative-decode cost model
# ----------------------------------------------------------------------------

SPEC_DIMS = dict(n_heads=32, n_kv_heads=8, head_dim=128, page_size=256,
                 param_bytes=8e9)
SPEC_LENS = [512, 2048, 8192, 32768]


def test_expected_spec_tokens_bounds():
    """E[tokens/tick] = sum a^i: 1 at k=0 or a=0, k+1 at a=1, monotone in
    both arguments."""
    assert autotune.expected_spec_tokens(0, 0.9) == 1.0
    assert autotune.expected_spec_tokens(4, 0.0) == 1.0
    assert autotune.expected_spec_tokens(4, 1.0) == pytest.approx(5.0)
    e2 = autotune.expected_spec_tokens(2, 0.6)
    e4 = autotune.expected_spec_tokens(4, 0.6)
    assert 1.0 < e2 < e4 < 5.0
    assert autotune.expected_spec_tokens(2, 0.8) > e2


def test_spec_decode_model_terms():
    """The verify-width trade: a wider tick costs more than a plain tick
    (the overhead an accept rate must beat) but amortizes the fixed
    weight stream — at a healthy accept rate the tokens/sec win."""
    out = autotune.spec_decode_model(SPEC_LENS, k=4,
                                     accept_rate=0.8, **SPEC_DIMS)
    assert out["spec_tick_s"] > out["plain_tick_s"]
    assert out["verify_overhead_frac"] > 0
    assert out["weight_stream_s"] > 0
    assert out["expected_tokens_per_tick"] == pytest.approx(
        autotune.expected_spec_tokens(4, 0.8))
    assert out["speedup"] == pytest.approx(
        out["tokens_per_s_spec"] / out["tokens_per_s_plain"])
    assert out["speedup"] > 1.0
    # Zero accepts: pure overhead, strictly worse than plain decode.
    zero = autotune.spec_decode_model(SPEC_LENS, k=4,
                                      accept_rate=0.0, **SPEC_DIMS)
    assert zero["speedup"] < 1.0


def test_spec_speedup_monotone_in_accept_rate():
    prev = 0.0
    for a in (0.1, 0.4, 0.7, 0.95):
        out = autotune.spec_decode_model(SPEC_LENS, k=4,
                                         accept_rate=a, **SPEC_DIMS)
        assert out["speedup"] > prev
        prev = out["speedup"]


def test_choose_spec_k_disables_when_speculation_loses():
    """k=0 is a real answer: a low accept rate plus an expensive serial
    model draft must disable speculation, while the free n-gram drafter
    at a healthy accept rate picks k >= 1 with a real speedup."""
    k, terms = autotune.choose_spec_k(SPEC_LENS, accept_rate=0.05,
                                      draft_bytes=1e9, **SPEC_DIMS)
    assert k == 0 and terms["speedup"] <= 1.0
    k2, terms2 = autotune.choose_spec_k(SPEC_LENS, accept_rate=0.7,
                                        **SPEC_DIMS)
    assert k2 >= 1 and terms2["speedup"] > 1.0 and terms2["chosen_k"] == k2


def test_choose_spec_k_grows_with_accept_rate():
    klo, _ = autotune.choose_spec_k(SPEC_LENS, accept_rate=0.3,
                                    **SPEC_DIMS)
    khi, _ = autotune.choose_spec_k(SPEC_LENS, accept_rate=0.95,
                                    **SPEC_DIMS)
    assert khi >= klo
