"""Control-word codec roundtrip (ch.2) + latency measurement method (§4.1)."""

import pytest
from hypothesis import given, strategies as st

from repro.core import hwmodel, isa, latency


@given(stall=st.integers(0, 15), yf=st.integers(0, 1),
       wb=st.integers(0, 7), rb=st.integers(0, 7),
       wm=st.integers(0, 63), reuse=st.integers(0, 15))
def test_control_roundtrip(stall, yf, wb, rb, wm, reuse):
    ci = isa.ControlInfo(stall=stall, yield_flag=yf, write_bar=wb,
                         read_bar=rb, wait_mask=wm, reuse=reuse)
    assert isa.decode_control(ci.encode()) == ci


@given(instr=st.integers(0, 2 ** 90 - 1), stall=st.integers(0, 15))
def test_volta_word_roundtrip(instr, stall):
    ci = isa.ControlInfo(stall=stall)
    word = isa.pack_volta(instr, ci)
    assert word < 2 ** 128
    got_instr, got_ci = isa.unpack_volta(word)
    assert got_instr == instr and got_ci == ci


def test_pascal_control_word_packs_three_sections():
    sections = [isa.ControlInfo(stall=i, reuse=i) for i in (1, 2, 3)]
    word = isa.pack_pascal_control_word(sections)
    assert word < 2 ** 63                     # MSB zero (paper)
    assert isa.unpack_pascal_control_word(word) == sections


def test_opcode_lengths_match_paper_claim():
    hist = isa.opcode_length_histogram()
    assert min(hist) >= 10 and max(hist) <= 13   # "10 to 13 bits"


def test_volta_pascal_encoding_facts():
    f = isa.ENCODING_FACTS
    assert f["word_bits"] == 128
    assert f["min_instruction_bits"] >= 91
    assert f["min_control_bits"] >= 23


@pytest.mark.parametrize("table,name", [
    (hwmodel.VOLTA_INSTR_LATENCY, "volta"),
    (hwmodel.PASCAL_INSTR_LATENCY, "pascal"),
])
def test_latency_measurement_recovers_table(table, name):
    board = latency.Scoreboard(table)
    for op, true_lat in table.items():
        if true_lat <= 1:
            continue
        assert latency.measure_fixed_latency(board, op, max_stall=100) \
            == true_lat, op


def test_dependent_chain_scales_linearly():
    board = latency.Scoreboard(hwmodel.VOLTA_INSTR_LATENCY)
    c10 = latency.dependent_chain_cycles(board, "FFMA", 10)
    c20 = latency.dependent_chain_cycles(board, "FFMA", 20)
    assert c20 - c10 == 10 * hwmodel.VOLTA_INSTR_LATENCY["FFMA"]


def test_volta_key_latencies_from_paper():
    t = hwmodel.VOLTA_INSTR_LATENCY
    assert t["FFMA"] == 4 and t["DFMA"] == 8 and t["HFMA2"] == 6
    p = hwmodel.PASCAL_INSTR_LATENCY
    assert p["FFMA"] == 6 and p["IMAD"] == 86


def test_cpu_wallclock_harness_runs():
    import jax.numpy as jnp

    ns = latency.measure_op_chain(lambda x: x + 1.0,
                                  jnp.zeros((8,), jnp.float32), n=64,
                                  repeats=2)
    assert ns > 0
