"""Paged KV subsystem: allocator invariants (property-tested), the paged
flash-decode kernel vs the contiguous oracle, and the page-table gather."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import autotune
from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_paged
from repro.serve import paged


# ----------------------------------------------------------------------------
# Allocator
# ----------------------------------------------------------------------------

def test_alloc_free_roundtrip():
    al = paged.PageAllocator(n_pages=8, page_size=4)
    a = al.alloc(0, 3)
    b = al.alloc(1, 2)
    assert len(set(a) | set(b)) == 5          # all distinct
    assert paged.NULL_PAGE not in a + b
    assert al.pages_in_use == 5 and al.free_pages == 2
    freed = al.free_slot(0)
    assert sorted(freed) == sorted(a)
    assert al.pages_in_use == 2 and al.free_pages == 5
    al.reset()
    assert al.pages_in_use == 0 and al.free_pages == 7


def test_freed_pages_are_reused_first():
    """LIFO free list: a freed slot's pages are the next ones handed out
    (warm-page reuse on re-admission)."""
    al = paged.PageAllocator(n_pages=16, page_size=4)
    a = al.alloc(0, 4)
    al.alloc(1, 4)
    al.free_slot(0)
    assert al.alloc(2, 4) == a


def test_exhaustion_raises_and_allocates_nothing():
    al = paged.PageAllocator(n_pages=4, page_size=4)
    al.alloc(0, 2)
    with pytest.raises(paged.PagePoolExhausted):
        al.alloc(1, 2)
    assert al.pages_in_use == 2               # failed alloc took nothing
    assert 1 not in al.slot_pages


def test_occupancy_and_fragmentation_accounting():
    al = paged.PageAllocator(n_pages=9, page_size=8)
    al.alloc(0, 2)                            # 16 rows allocated
    al.alloc(1, 1)                            # 8 rows allocated
    occ = al.occupancy({0: 9, 1: 8})
    assert occ["pages_in_use"] == 3
    assert occ["rows_resident"] == 4 * 8      # + null page
    assert occ["fragmentation_rows"] == 24 - 17
    assert occ["high_water"] == 3
    assert occ["utilization"] == pytest.approx(3 / 8)


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_allocator_never_double_assigns_live_pages(seed):
    """Random alloc/free interleavings: every live page is owned by exactly
    one slot and the null page is never handed out."""
    rng = np.random.RandomState(seed)
    al = paged.PageAllocator(n_pages=int(rng.randint(3, 20)),
                             page_size=int(rng.randint(1, 9)))
    for _ in range(50):
        slot = int(rng.randint(0, 6))
        if rng.rand() < 0.6:
            n = int(rng.randint(1, 4))
            try:
                al.alloc(slot, n)
            except paged.PagePoolExhausted:
                pass
        else:
            al.free_slot(slot)
        owned = [p for ps in al.slot_pages.values() for p in ps]
        assert len(owned) == len(set(owned)), "page owned twice"
        assert paged.NULL_PAGE not in owned
        assert len(owned) + al.free_pages == al.n_pages - 1


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_refcount_churn_never_double_frees_or_leaks(seed):
    """Property: random share/retain/COW/release churn layered on
    alloc/free keeps every reference accounted for — a live page's
    refcount equals its slot-table occurrences plus its index hold, live
    and free pages partition the pool, and the conservation counters
    (allocated - freed == in-use) balance after every operation."""
    rng = np.random.RandomState(seed)
    al = paged.PageAllocator(n_pages=int(rng.randint(6, 24)),
                             page_size=int(rng.randint(1, 5)))
    held = set()                              # mirror of the index hold
    for _ in range(80):
        op = rng.rand()
        slots = sorted(s for s, ps in al.slot_pages.items() if ps)
        if op < 0.35:
            try:
                al.alloc(int(rng.randint(0, 6)), int(rng.randint(1, 4)))
            except paged.PagePoolExhausted:
                pass
        elif op < 0.50 and slots:             # prefix-hit path
            src = slots[rng.randint(len(slots))]
            k = int(rng.randint(1, len(al.slot_pages[src]) + 1))
            al.share(int(rng.randint(0, 6)), al.slot_pages[src][:k])
        elif op < 0.60 and slots:             # publish path
            run = al.slot_pages[slots[rng.randint(len(slots))]]
            p = run[rng.randint(len(run))]
            if p not in held:
                al.retain(p)
                held.add(p)
        elif op < 0.70 and held:              # evict path
            p = sorted(held)[rng.randint(len(held))]
            held.discard(p)
            al.release(p)
        elif op < 0.85 and slots:             # COW a shared page
            src = slots[rng.randint(len(slots))]
            pos = int(rng.randint(len(al.slot_pages[src])))
            if al.refcount(al.slot_pages[src][pos]) >= 2:
                try:
                    al.cow(src, pos)
                except paged.PagePoolExhausted:
                    pass
        else:
            al.free_slot(int(rng.randint(0, 6)))
        counts = {}
        for ps in al.slot_pages.values():
            for p in ps:
                counts[p] = counts.get(p, 0) + 1
        for p in held:
            counts[p] = counts.get(p, 0) + 1
        assert paged.NULL_PAGE not in counts
        assert counts == {p: al.refcount(p) for p in counts}, "ref drift"
        assert len(counts) == al.pages_in_use
        assert al.pages_in_use + al.free_pages == al.n_pages - 1
        assert al.pages_allocated - al.pages_freed == al.pages_in_use
        cls = al.page_classes()
        assert sum(cls.values()) == al.pages_in_use


def test_pages_for():
    assert [paged.pages_for(n, 8) for n in (0, 1, 8, 9, 16)] == \
        [0, 1, 1, 2, 2]


# ----------------------------------------------------------------------------
# Paged kernel vs contiguous oracle
# ----------------------------------------------------------------------------

def _paginate(k, v, lengths, page_size, n_pages, rng):
    """Scatter contiguous (b, max_len, kvh, d) K/V into a shuffled page
    pool + per-slot tables (live entries drawn from pages 1..n_pages-1)."""
    b, max_len, kvh, d = k.shape
    max_pages = max_len // page_size
    ids = rng.permutation(np.arange(1, n_pages))
    kp = np.zeros((n_pages, page_size, kvh, d), np.asarray(k).dtype)
    vp = np.zeros_like(kp)
    table = np.zeros((b, max_pages), np.int32)
    nxt = 0
    for i in range(b):
        for j in range(paged.pages_for(int(lengths[i]), page_size)):
            pid = ids[nxt]
            nxt += 1
            table[i, j] = pid
            kp[pid] = np.asarray(k[i, j * page_size:(j + 1) * page_size])
            vp[pid] = np.asarray(v[i, j * page_size:(j + 1) * page_size])
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(table)


def _case(rng, b, h, kvh, d, max_len):
    q = jnp.asarray(rng.randn(b, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, max_len, kvh, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, max_len, kvh, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (8, 1)])
def test_paged_decode_matches_contiguous_oracle(h, kvh):
    rng = np.random.RandomState(0)
    b, d, max_len, ps = 4, 16, 64, 16
    q, k, v = _case(rng, b, h, kvh, d, max_len)
    lengths = jnp.asarray([1, 17, 64, 33], jnp.int32)
    kp, vp, table = _paginate(k, v, lengths, ps, 24, rng)
    out = flash_decode_paged(q, kp, vp, table, lengths, block_k=8,
                             interpret=True)
    expect = ref.flash_decode(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_paged_decode_zero_length_slot_is_zeros_not_nan():
    """A freed slot (length 0, null table row) gives zeros — and reading
    through the null page never touches a live page."""
    rng = np.random.RandomState(1)
    q, k, v = _case(rng, 3, 4, 2, 8, 32)
    lengths = jnp.asarray([0, 5, 32], jnp.int32)
    kp, vp, table = _paginate(k, v, lengths, 8, 16, rng)
    assert int(table[0].sum()) == 0           # freed slot: all-null row
    out = np.asarray(flash_decode_paged(q, kp, vp, table, lengths,
                                        block_k=8, interpret=True))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[0], np.zeros_like(out[0]))
    expect = np.asarray(ref.flash_decode(q, k, v, lengths))
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 50), block_k=st.sampled_from([4, 8, 16]),
       kvh=st.sampled_from([1, 2, 4]))
@settings(max_examples=10, deadline=None)
def test_paged_decode_block_and_length_invariance(seed, block_k, kvh):
    """Property: any dividing block size, GQA group, ragged length vector
    and page shuffle reproduces the contiguous oracle bit-for-bit (within
    fp tolerance)."""
    rng = np.random.RandomState(seed)
    b, d, max_len, ps = 3, 8, 64, 16
    h = kvh * int(rng.randint(1, 4))
    q, k, v = _case(rng, b, h, kvh, d, max_len)
    lengths = jnp.asarray(rng.randint(0, max_len + 1, size=b), jnp.int32)
    kp, vp, table = _paginate(k, v, lengths, ps, 20, rng)
    out = flash_decode_paged(q, kp, vp, table, lengths, block_k=block_k,
                             interpret=True)
    expect = ref.flash_decode(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_gather_kv_reconstructs_contiguous_view():
    rng = np.random.RandomState(2)
    _, k, v = _case(rng, 2, 4, 2, 8, 32)
    lengths = jnp.asarray([32, 9], jnp.int32)
    kp, vp, table = _paginate(k, v, lengths, 8, 12, rng)
    kc, vc = paged.gather_kv(kp, vp, table)
    assert kc.shape == (2, 32, 2, 8)
    np.testing.assert_array_equal(np.asarray(kc[0]), np.asarray(k[0]))
    np.testing.assert_array_equal(np.asarray(vc[1][:8]), np.asarray(v[1][:8]))


def test_reservation_model():
    out = paged.reservation([100, 200, 0], max_len=1024, page_size=64)
    assert out["rows_resident"] == (2 + 4 + 0 + 1) * 64
    assert out["rows_reserved_contig"] == 3 * 1024
    assert 0 < out["reservation_ratio"] < 0.5


def test_paged_decode_model_prices_lookup_and_reservation():
    lengths = [512, 4096, 16384, 32768]
    out = autotune.paged_decode_model(32768, lengths, n_heads=32,
                                      n_kv_heads=8, head_dim=128,
                                      page_size=256)
    assert out["paged_s"] > out["contig_s"]           # lookups aren't free
    assert out["lookup_overhead_frac"] < 0.5          # but nearly so
    assert out["tokens_per_s_paged"] < out["tokens_per_s_contig"]
    assert out["reservation_ratio"] < 0.5             # the HBM win
    # Zero overhead -> identical time (same FLOPs, same blocks).
    free = autotune.paged_decode_model(32768, lengths, n_heads=32,
                                       n_kv_heads=8, head_dim=128,
                                       page_size=256, page_lookup_s=0.0)
    assert free["paged_s"] == pytest.approx(free["contig_s"])
