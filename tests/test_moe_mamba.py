"""MoE dispatch paths + Mamba/SSD properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import mamba, moe


def _moe_pair(top_k=2, n_experts=4, cap=8.0):
    kw = dict(d_model=16, d_ff=32, n_experts=n_experts, top_k=top_k,
              capacity_factor=cap)
    return (moe.MoEConfig(impl="dense_mask", **kw),
            moe.MoEConfig(impl="capacity", **kw))


@given(top_k=st.sampled_from([1, 2, 4]), seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_moe_paths_agree_with_generous_capacity(top_k, seed):
    cfg_d, cfg_c = _moe_pair(top_k=top_k)
    p = moe.moe_init(jax.random.PRNGKey(seed), cfg_d)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 6, 16))
    y1, a1 = moe.moe_apply(p, cfg_d, x)
    y2, a2 = moe.moe_apply(p, cfg_c, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    assert a1 == pytest.approx(a2, rel=1e-4)


def test_moe_capacity_drops_overflow():
    cfg = moe.MoEConfig(d_model=8, d_ff=16, n_experts=2, top_k=1,
                        capacity_factor=0.25, impl="capacity")
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))
    y, _ = moe.moe_apply(p, cfg, x)
    # Some tokens dropped -> zero output rows exist.
    norms = np.asarray(jnp.linalg.norm(y[0], axis=-1))
    assert (norms < 1e-6).any()
    assert (norms > 1e-6).any()


def test_moe_aux_loss_balanced_is_one():
    # Uniform routing -> aux ~= 1 (Switch normalization).
    cfg = moe.MoEConfig(d_model=8, d_ff=16, n_experts=4, top_k=1)
    p = moe.moe_init(jax.random.PRNGKey(2), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 8))
    _, aux = moe.moe_apply(p, cfg, x)
    assert float(aux) == pytest.approx(1.0, rel=0.05)


def test_shared_expert_adds_signal():
    kw = dict(d_model=8, d_ff=16, n_experts=2, top_k=1)
    cfg0 = moe.MoEConfig(n_shared=0, **kw)
    cfg1 = moe.MoEConfig(n_shared=1, **kw)
    p = moe.moe_init(jax.random.PRNGKey(4), cfg1)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, 8))
    y0, _ = moe.moe_apply({k: v for k, v in p.items() if k != "shared"},
                          cfg0, x)
    y1, _ = moe.moe_apply(p, cfg1, x)
    assert float(jnp.abs(y1 - y0).max()) > 1e-6


@given(seed=st.integers(0, 30), chunk=st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_equals_reference(seed, chunk):
    rng = np.random.RandomState(seed)
    b, l, h, p, n = 2, 32, 2, 4, 8
    x = jnp.asarray(rng.randn(b, l, h, p), jnp.float32) * 0.5
    a = -jnp.abs(jnp.asarray(rng.randn(b, l, h), jnp.float32)) * 0.5
    bm = jnp.asarray(rng.randn(b, l, n), jnp.float32) * 0.5
    cm = jnp.asarray(rng.randn(b, l, n), jnp.float32) * 0.5
    y1, h1 = mamba.ssd_reference(x, a, bm, cm)
    y2, h2 = mamba.ssd_chunked(x, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4,
                               atol=2e-4)


def test_ssd_state_threading_across_calls():
    # Running two halves with carried state == running the whole sequence.
    rng = np.random.RandomState(7)
    b, l, h, p, n = 1, 16, 2, 4, 8
    x = jnp.asarray(rng.randn(b, l, h, p), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.randn(b, l, h), jnp.float32)) * 0.3
    bm = jnp.asarray(rng.randn(b, l, n), jnp.float32)
    cm = jnp.asarray(rng.randn(b, l, n), jnp.float32)
    y_full, _ = mamba.ssd_chunked(x, a, bm, cm, chunk=8)
    y1, h1 = mamba.ssd_chunked(x[:, :8], a[:, :8], bm[:, :8], cm[:, :8],
                               chunk=8)
    y2, _ = mamba.ssd_chunked(x[:, 8:], a[:, 8:], bm[:, 8:], cm[:, 8:],
                              chunk=8, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=2e-4, atol=2e-4)


def test_mamba_block_decode_matches_full():
    cfg = mamba.MambaConfig(d_model=16, d_state=8, head_dim=4, expand=2,
                            chunk=8)
    p = mamba.mamba_init(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 12, 16))
    y_full, _ = mamba.mamba_apply(p, cfg, x)
    cache = mamba.init_cache(cfg, 2)
    outs = []
    for t in range(12):
        yt, cache = mamba.mamba_apply(p, cfg, x[:, t:t + 1], cache=cache)
        outs.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=3e-4, atol=3e-4)
