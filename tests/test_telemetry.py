"""Observability contract for the serving engine (serve/telemetry.py):
tracing is *observational* — the traced engine's token streams are
bit-identical to an untraced engine's on every path (greedy, sampled,
speculative, faulted, preempting) — the event trace reconciles exactly
against the legacy counter views and the page pool's conservation law,
ring eviction bounds memory without corrupting aggregates, compile
detection is exact, the exporters emit valid JSON, and the
model-vs-measured drift gate records finite positive ratios."""

import json

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import autotune
from repro.models import transformer as T
from repro.serve import telemetry, traffic
from repro.serve.engine import Request, ServeConfig, ServingEngine, SLOClass
from repro.serve.faults import FaultInjector, canonical_schedule
from repro.serve.paged import PageAllocator


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scfg(**kw):
    base = dict(max_len=64, batch=2, eos_id=-1, paged=True, page_size=8,
                chunk_size=8)
    base.update(kw)
    return ServeConfig(**base)


def _tcfg(**kw):
    base = dict(rate=2.0, n_requests=24, seed=7, vocab=128,
                classes=(traffic.TrafficClass(
                    "default", prompt_lo=4, prompt_hi=20,
                    out_lo=2, out_hi=6),))
    base.update(kw)
    return traffic.TrafficConfig(**base)


def _overload_kw():
    """Engine knobs that exercise shed, preemption and degradation."""
    return dict(n_pages=17,
                classes=(SLOClass("default", ttft_slo=8, tpot_slo=4.0),),
                max_queue=4, max_preemptions=3, degrade=True)


def _run(model, scfg_kw, tcfg_kw, injector_fn=None):
    cfg, params = model
    eng = ServingEngine(params, cfg, _scfg(**scfg_kw))
    arr = traffic.TrafficGenerator(_tcfg(**tcfg_kw)).arrivals()
    inj = injector_fn() if injector_fn else None
    res = traffic.run_open_loop(eng, arr, max_ticks=2000, injector=inj)
    if inj is not None:
        inj.finish(eng)
    assert res["unresolved"] == []
    return eng, arr


# ----------------------------------------------------------------------------
# Parity: the traced engine's streams are bit-identical to the untraced's
# ----------------------------------------------------------------------------

def _assert_parity(model, scfg_kw, tcfg_kw, injector_fn=None):
    traced, _ = _run(model, dict(scfg_kw, telemetry=True), tcfg_kw,
                     injector_fn)
    plain, _ = _run(model, dict(scfg_kw, telemetry=False), tcfg_kw,
                    injector_fn)
    assert traced.outcome == plain.outcome
    assert traced.finished == plain.finished
    assert traced.ticks == plain.ticks
    return traced, plain


def test_traced_is_bit_identical_greedy_overload(model):
    """Greedy decoding through shed + preemption + degradation: tracing
    must not move a single token or terminal outcome."""
    traced, _ = _assert_parity(
        model, _overload_kw(), dict(rate=3.0, n_requests=24))
    # The workload actually exercised the interesting paths. (Preemption
    # needs a pool squeeze — conservative admission never over-commits —
    # so the faulted test below covers it.)
    assert traced.telemetry.counters.get("shed", 0) >= 1
    assert traced.telemetry.counters.get("degrade_enter", 0) >= 1


def test_traced_is_bit_identical_sampled(model):
    """Temperature sampling: the per-(rid, index) sampling keys make the
    stream deterministic, so tracing must preserve it exactly."""
    _assert_parity(model, dict(_overload_kw(), temperature=0.7, seed=3),
                   dict(rate=2.0, n_requests=16))


def test_traced_is_bit_identical_spec_plus_faults(model):
    """Speculative decoding under the canonical fault schedule — the
    worst-case interleaving of spans and events."""
    spec_kw = dict(_overload_kw(), spec_k=2, draft="ngram",
                   spec_adapt_every=4, spec_probe_every=4)
    inj = lambda: FaultInjector(canonical_schedule(t0=4, dwell=8, gap=6))
    traced, _ = _assert_parity(
        model, spec_kw, dict(rate=1.5, n_requests=24), inj)
    assert traced.telemetry.counters.get("spec_verify", 0) >= 1
    assert traced.telemetry.counters.get("preempt", 0) >= 1


# ----------------------------------------------------------------------------
# Reconciliation: the trace IS the bookkeeping (counters are views)
# ----------------------------------------------------------------------------

def test_outcome_accounting_reconciles_with_trace(model):
    """Every submitted rid reaches exactly one terminal event, and the
    legacy counter views agree with the ring event-by-event (capacity
    large enough that nothing evicts). Runs the canonical fault schedule
    so shed, preemption *and* admission holds all appear."""
    eng, arr = _run(
        model,
        dict(_scfg_kw_spec(), spec_adapt_every=4, spec_probe_every=4,
             trace_capacity=65536),
        dict(rate=1.5, n_requests=24),
        lambda: FaultInjector(canonical_schedule(t0=4, dwell=8, gap=6)))
    assert eng.preemptions >= 1 and eng.admission_rejections >= 1
    tel = eng.telemetry
    assert tel.dropped_events == 0

    # One submit event per offered request.
    submits = tel.events_of("submit")
    assert len(submits) == len(arr)

    # Exactly one terminal event (shed | finish) per rid.
    terminal = {}
    for _, _, kind, p in tel.events_of("shed") + tel.events_of("finish"):
        assert p["rid"] not in terminal, f"double terminal for {p['rid']}"
        terminal[p["rid"]] = kind
    assert set(terminal) == {a.rid for a in arr}

    # Counter views == ring counts == legacy structures.
    assert len(tel.events_of("shed")) == eng.telemetry.counters["shed"] \
        == sum(eng.shed_by_class.values())
    preempts = tel.events_of("preempt")
    assert len(preempts) == eng.preemptions == len(eng.preemption_log)
    for (_, _, _, p), (rid, rclass, n_gen) in zip(preempts,
                                                  eng.preemption_log):
        assert (p["rid"], p["rclass"], p["n_generated"]) == \
            (rid, rclass, n_gen)
    assert len(tel.events_of("admit_hold")) == eng.admission_rejections
    # Degradation transitions pair up (possibly still degraded at drain).
    ent, ext = tel.events_of("degrade_enter"), tel.events_of("degrade_exit")
    assert len(ent) - len(ext) in (0, 1)
    assert eng.downshifts == len(ent)


def test_page_events_reconcile_with_pool_conservation(model):
    """Sum of page_alloc/page_free event sizes == the allocator's
    cumulative counters (every engine alloc/free is traced), and the
    conservation law holds after drain."""
    eng, _ = _run(model, dict(_overload_kw(), trace_capacity=65536),
                  dict(rate=3.0, n_requests=24))
    tel = eng.telemetry
    allocd = sum(p["n"] for _, _, _, p in tel.events_of("page_alloc"))
    freed = sum(p["n"] for _, _, _, p in tel.events_of("page_free"))
    assert allocd == eng.pool.pages_allocated
    assert freed == eng.pool.pages_freed
    assert eng.pool.pages_allocated - eng.pool.pages_freed \
        == eng.pool.pages_in_use == 0
    occ = eng.pool.occupancy()
    assert occ["pages_allocated"] == allocd
    assert occ["pages_freed"] == freed
    assert occ["high_water"] >= 1


def test_spec_verify_events_reconcile(model):
    eng, _ = _run(model, dict(_scfg_kw_spec(), trace_capacity=65536),
                  dict(rate=1.5, n_requests=16))
    tel = eng.telemetry
    ev = tel.events_of("spec_verify")
    assert len(ev) >= 1
    assert sum(p["proposed"] for _, _, _, p in ev) == \
        tel.counters["spec_proposed"]
    assert sum(p["accepted"] for _, _, _, p in ev) == eng.spec_accepted
    assert sum(p["emitted"] for _, _, _, p in ev) == eng.spec_emitted
    assert len(ev) == eng.spec_ticks


def _scfg_kw_spec():
    return dict(_overload_kw(), spec_k=2, draft="ngram")


# ----------------------------------------------------------------------------
# Ring bounds memory; aggregates stay exact through eviction
# ----------------------------------------------------------------------------

def test_ring_eviction_keeps_aggregates_exact(model):
    small, _ = _run(model, dict(_overload_kw(), trace_capacity=16),
                    dict(rate=3.0, n_requests=24))
    big, _ = _run(model, dict(_overload_kw(), trace_capacity=65536),
                  dict(rate=3.0, n_requests=24))
    assert small.telemetry.dropped_events > 0
    assert len(small.telemetry.events) == 16
    assert small.telemetry.counters == big.telemetry.counters
    assert small.shed_by_class == big.shed_by_class
    assert small.preemption_log == big.preemption_log


def test_disabled_telemetry_keeps_counters_exact(model):
    """telemetry=False drops the rings and the clocks, never the
    aggregates: the legacy counter views must still be exact."""
    off, _ = _run(model, dict(_overload_kw(), telemetry=False),
                  dict(rate=3.0, n_requests=24))
    on, _ = _run(model, _overload_kw(), dict(rate=3.0, n_requests=24))
    assert len(off.telemetry.events) == 0
    assert len(off.telemetry.spans) == 0
    assert off.telemetry.tick_stats()["n"] == 0
    assert off.telemetry.counters == on.telemetry.counters
    assert off.admission_rejections == on.admission_rejections
    assert off.shed_by_class == on.shed_by_class


# ----------------------------------------------------------------------------
# Spans: exact compile detection + per-tick histogram
# ----------------------------------------------------------------------------

def test_compile_flags_and_tick_histogram(model):
    """One decode executable and one chunk executable -> exactly one
    compile-flagged span each; the tick histogram counts every tick."""
    cfg, params = model
    eng = ServingEngine(params, cfg, _scfg(n_pages=17))
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=np.arange(
            3, 3 + 9 + rid, dtype=np.int32), max_new=4))
    eng.run_until_drained()
    st = eng.telemetry.span_stats()
    assert st["decode"]["compile_n"] == 1 == eng.decode_traces
    assert st["prefill_chunk"]["compile_n"] == 1
    assert sum(eng.prefill_traces.values()) == 1
    assert st["decode"]["execute_n"] == st["decode"]["n"] - 1
    assert st["decode"]["execute_mean_s"] > 0
    ts = eng.telemetry.tick_stats()
    assert ts["n"] == eng.ticks
    assert ts["p99_s"] >= ts["p50_s"] > 0
    assert ts["total_s"] == pytest.approx(
        ts["mean_s"] * ts["n"])


# ----------------------------------------------------------------------------
# Exporters: Perfetto JSON + flat metrics + wall-clock summary fields
# ----------------------------------------------------------------------------

def test_chrome_trace_is_valid_json_with_tracks(model):
    eng, _ = _run(model, _overload_kw(), dict(rate=2.0, n_requests=12))
    tr = eng.telemetry.chrome_trace()
    blob = json.dumps(tr)            # numpy leakage would raise here
    back = json.loads(blob)
    assert back["otherData"]["schema_version"] == \
        telemetry.TRACE_SCHEMA_VERSION
    evs = back["traceEvents"]
    assert evs
    phases = {e["tid"] for e in evs if e["ph"] == "X"}
    assert "phase:decode" in phases
    assert any(t.startswith("slot:") for t in phases)   # prefill chunks
    # Counter tracks ride along as ph="C" events: pool occupancy and
    # queue depth are always emitted on a paged overload run.
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert {"pool_pages", "queue_depth"} <= counters
    for e in evs:
        assert e["ph"] in ("X", "i", "C")
        assert isinstance(e["ts"], float)
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        if e["ph"] == "C":
            (val,) = e["args"].values()   # one series per counter event
            assert isinstance(val, int) and val >= 0


def test_metrics_flat_and_summary_wall_clock(model):
    tcls = (traffic.TrafficClass("default", prompt_lo=4, prompt_hi=20,
                                 out_lo=2, out_hi=6,
                                 ttft_ms=1e6, tpot_ms=1e6),)
    eng, arr = _run(model, _overload_kw(),
                    dict(rate=2.0, n_requests=12, classes=tcls))
    m = eng.telemetry.metrics()
    assert m["schema_version"] == telemetry.TRACE_SCHEMA_VERSION
    assert m["enabled"] is True
    assert m["count_admit"] >= 1
    assert m["span_decode_n"] >= 1
    for v in m.values():              # flat: scalars only
        assert isinstance(v, (bool, int, float, str)), v
    s = traffic.summarize(eng, arr, classes=tcls)
    assert s["tick_wall_s_mean"] > 0
    assert s["tick_wall_s_p99"] >= s["tick_wall_s_p50"]
    d = s["by_class"]["default"]
    assert d["ttft_ms_p50"] == pytest.approx(
        d["ttft_p50"] * s["tick_wall_s_mean"] * 1e3)
    # Absurdly loose ms targets -> full attainment (plumbing check).
    assert d["ttft_ms_slo_attainment"] == 1.0
    assert d["tpot_ms_slo_attainment"] == 1.0


def test_traffic_class_rejects_nonpositive_ms_targets():
    with pytest.raises(AssertionError):
        traffic.TrafficClass("x", ttft_ms=0.0)
    with pytest.raises(AssertionError):
        traffic.TrafficClass("x", tpot_ms=-1.0)


# ----------------------------------------------------------------------------
# Drift gate: model vs measured, persisted under serve_measured:
# ----------------------------------------------------------------------------

def test_drift_report_finite_and_persisted(model, tmp_path, monkeypatch):
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH",
                        str(tmp_path / "cache.json"))
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    eng, _ = _run(model, _scfg_kw_spec(), dict(rate=1.5, n_requests=16))
    rep = telemetry.drift_report(eng, persist=True)
    assert rep["schema_version"] == telemetry.TRACE_SCHEMA_VERSION
    assert "decode" in rep or "spec_verify" in rep
    assert "prefill_chunk" in rep
    for comp in ("decode", "prefill_chunk", "spec_verify"):
        row = rep.get(comp)
        if row is None:
            continue
        assert row["measured_s"] > 0
        assert row["modeled_s"] > 0
        assert row["ratio"] == pytest.approx(
            row["measured_s"] / row["modeled_s"])
        assert row["n_spans"] >= 1
    with open(autotune.TUNING_CACHE_PATH) as f:
        cache = json.load(f)
    keys = [k for k in cache if k.startswith(autotune.SERVE_MEASURED_PREFIX)]
    assert keys
    for k in keys:
        assert cache[k]["time_s"] > 0


def test_drift_ratio_sentinel():
    assert autotune.drift_ratio(1.0, 2.0) == 0.5
    assert autotune.drift_ratio(0.0, 2.0) == 0.0
    assert autotune.drift_ratio(1.0, 0.0) == 0.0
    assert autotune.drift_ratio(float("nan"), 2.0) == 0.0
    assert autotune.drift_ratio(float("inf"), 2.0) == 0.0


# ----------------------------------------------------------------------------
# Telemetry core unit behavior + allocator counters (no model)
# ----------------------------------------------------------------------------

def test_emit_rejects_unknown_kind():
    tel = telemetry.Telemetry()
    with pytest.raises(AssertionError):
        tel.emit(0, "not_a_kind", rid=1)


def test_reset_clears_rings_and_aggregates():
    tel = telemetry.Telemetry(capacity=4)
    for i in range(6):
        tel.emit(i, "admit", rid=i, rclass="default")
    with tel.span("decode", 0):
        pass
    tel.tick_done(0, tel.clock())
    assert tel.dropped_events == 2
    tel.reset()
    assert len(tel.events) == 0 and len(tel.spans) == 0
    assert tel.dropped_events == 0
    assert tel.counters == {} and tel.tick_stats()["n"] == 0


def test_page_allocator_cumulative_counters():
    pool = PageAllocator(n_pages=9, page_size=8)
    pool.alloc(0, 3)
    pool.alloc(1, 2)
    pool.free_slot(0)
    pool.alloc(2, 4)
    assert pool.pages_allocated == 9
    assert pool.pages_freed == 3
    assert pool.pages_allocated - pool.pages_freed == pool.pages_in_use == 6
    assert pool.occupancy()["pages_allocated"] == 9
    pool.reset()
    assert pool.pages_allocated == pool.pages_freed == 0
