"""HMMA fragment maps (paper Figs 4.2-4.7) + emulation exactness."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import tensorcore as tc


def test_fragment_map_spot_values_from_paper():
    # Fig 4.2 (A, column-major byte addresses -> thread pairs).
    assert tc.a_fragment_threads(0, 0) == (0, 8)       # addr 0
    assert tc.a_fragment_threads(4, 0) == (16, 24)     # addr 8
    assert tc.a_fragment_threads(8, 0) == (4, 12)      # addr 16
    assert tc.a_fragment_threads(12, 0) == (20, 28)    # addr 24
    assert tc.a_fragment_threads(0, 1) == (1, 9)       # addr 32
    assert tc.a_fragment_threads(0, 4) == (0, 8)       # addr 128 wraps
    # Fig 4.3 (B).
    assert tc.b_fragment_threads(0, 0) == (0, 4)
    assert tc.b_fragment_threads(0, 4) == (16, 20)     # addr 128
    assert tc.b_fragment_threads(0, 8) == (8, 12)      # addr 256
    assert tc.b_fragment_threads(0, 12) == (24, 28)    # addr 384
    # Fig 4.7 (C, fp32).
    assert tc.c_fragment_thread(0, 0) == 0
    assert tc.c_fragment_thread(1, 0) == 1
    assert tc.c_fragment_thread(4, 0) == 16            # addr 16
    assert tc.c_fragment_thread(8, 0) == 4             # addr 32
    assert tc.c_fragment_thread(15, 15) == 31          # addr 1020
    assert tc.c_fragment_thread(0, 8) == 8             # addr 512


def test_loads_per_thread_match_paper():
    # Paper: every thread loads 16 elements of A and 16 of B.
    assert set(tc.loads_per_thread("A").tolist()) == {16}
    assert set(tc.loads_per_thread("B").tolist()) == {16}
    assert set(tc.loads_per_thread("C").tolist()) == {8}


def test_group_blocks_partition_c():
    seen = np.zeros((16, 16), int)
    for g in range(8):
        rs, cs = tc.group_block(g)
        seen[rs, cs] += 1
        block = np.zeros((16, 16), bool)
        block[rs, cs] = True
        owners = {tc.c_group(r, c) for r in range(16) for c in range(16)
                  if block[r, c]}
        assert owners == {g}
    assert (seen == 1).all()


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10)
def test_emulation_equals_matmul(seed):
    rng = np.random.RandomState(seed)
    a = rng.randint(-4, 5, (16, 16)).astype(np.float16)
    b = rng.randint(-4, 5, (16, 16)).astype(np.float16)
    c = rng.randint(-4, 5, (16, 16)).astype(np.float32)
    out = tc.emulate_mma_sync(a, b, c)
    ref = a.astype(np.float32) @ b.astype(np.float32) + c
    assert np.array_equal(out, ref)
