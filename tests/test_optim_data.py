"""Optimizer vs numpy oracle; schedules; data pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, SyntheticLMData
from repro.optim import (AdamWConfig, ScheduleConfig, adamw_init,
                         adamw_update, clip_by_global_norm, learning_rate)


def _np_adamw(g, m, v, p, lr, t, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_adamw_matches_numpy_oracle(seed):
    rng = np.random.RandomState(seed)
    p = {"w": jnp.asarray(rng.randn(4, 3), jnp.float32)}
    g = {"w": jnp.asarray(rng.randn(4, 3), jnp.float32)}
    cfg = AdamWConfig()
    state = adamw_init(p)
    new_p, state = adamw_update(g, state, p, lr=0.01, cfg=cfg)
    expect = _np_adamw(np.asarray(g["w"]), np.zeros((4, 3)),
                       np.zeros((4, 3)), np.asarray(p["w"]), 0.01, 1, cfg)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5,
                               atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90.0))
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-4)
    # No-op below the threshold.
    clipped2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]),
                               np.asarray(g["a"]))


def test_schedule_warmup_and_decay():
    cfg = ScheduleConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(learning_rate(0, cfg)) == pytest.approx(0.1)
    assert float(learning_rate(9, cfg)) == pytest.approx(1.0)
    assert float(learning_rate(99, cfg)) == pytest.approx(0.1, abs=0.01)
    mid = float(learning_rate(55, cfg))
    assert 0.1 < mid < 1.0


def test_data_determinism_and_resume():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=4, seed=3)
    d1 = SyntheticLMData(cfg)
    d2 = SyntheticLMData(cfg, start_step=0)
    a = d1.batch_at(5)
    b = d2.batch_at(5)
    np.testing.assert_array_equal(a[0], b[0])
    # Resume from a state dict.
    d1.step = 7
    d3 = SyntheticLMData(cfg)
    d3.load_state_dict(d1.state_dict())
    np.testing.assert_array_equal(next(d3)[0], d1.batch_at(7)[0])


def test_data_shard_invariance():
    # Global sample content is independent of dp_size partitioning.
    cfg = DataConfig(vocab=61, seq_len=8, global_batch=8, seed=1)
    whole = SyntheticLMData(cfg, dp_rank=0, dp_size=1).batch_at(2)[0]
    halves = [SyntheticLMData(cfg, dp_rank=r, dp_size=2).batch_at(2)[0]
              for r in (0, 1)]
    np.testing.assert_array_equal(whole, np.concatenate(halves, 0))


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=31, seq_len=12, global_batch=2, seed=0)
    tokens, labels = SyntheticLMData(cfg).batch_at(0)
    np.testing.assert_array_equal(tokens[:, 1:], labels[:, :-1])


def test_prefetch_matches_sync():
    cfg = DataConfig(vocab=31, seq_len=8, global_batch=2, seed=5)
    d = SyntheticLMData(cfg)
    sync = d.batch_at(0)
    d2 = SyntheticLMData(cfg)
    d2.start_prefetch()
    pre = d2.next_prefetched()
    d2.stop()
    np.testing.assert_array_equal(sync[0], pre[0])
