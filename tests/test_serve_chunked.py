"""Chunked paged prefill: chunk-by-chunk page-table writes must reproduce
the whole-prompt contiguous oracle exactly (cache contents bit-for-bit,
outputs numerically), one chunk executable must serve every prompt-length
mix, decode ticks must keep moving while a long prompt is mid-prefill, and
pool exhaustion must preempt the youngest slot instead of raising."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import layers, transformer as T
from repro.serve import paged
from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                greedy_generate)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _chunked_cfg(**kw):
    base = dict(max_len=64, batch=2, eos_id=-1, paged=True, page_size=8,
                chunk_size=8)
    base.update(kw)
    return ServeConfig(**base)


# ----------------------------------------------------------------------------
# Layer-level property: chunked == whole-prompt oracle
# ----------------------------------------------------------------------------

@given(seed=st.integers(0, 50), kvh=st.sampled_from([1, 2, 4]),
       chunk_pages=st.sampled_from([1, 2, 3]),   # 1 page, 2 pages, odd
       use_flash=st.booleans())
@settings(max_examples=10, deadline=None)
def test_chunked_prefill_matches_whole_prompt_oracle(seed, kvh, chunk_pages,
                                                     use_flash):
    """Property: prefilling a prompt through ``attention_apply`` in
    page-table chunks gives the whole-prompt contiguous oracle's outputs,
    and the K/V rows landing in the pages are **bit-for-bit** the oracle's
    cache rows — across GQA ratios, chunk sizes of 1/2/odd pages, and
    prompt lengths straddling page boundaries."""
    rng = np.random.RandomState(seed)
    b, d_model = 1, 16
    ps, max_pages = 4, 8                          # max_len 32
    h = kvh * int(rng.randint(1, 3))
    hd = d_model // h if d_model % h == 0 else 4
    C = chunk_pages * ps
    # Straddle page boundaries: one below, on, or one past a multiple.
    L = int(np.clip(ps * rng.randint(1, 6) + rng.randint(-1, 2), 2, 30))
    acfg = layers.AttnConfig(d_model=d_model, n_heads=h, n_kv_heads=kvh,
                             head_dim=hd)
    params = layers.attention_init(jax.random.PRNGKey(seed), acfg)
    x = jnp.asarray(rng.randn(b, L, d_model), jnp.float32)

    contig = {"k": jnp.zeros((b, 32, kvh, hd)),
              "v": jnp.zeros((b, 32, kvh, hd)),
              "index": jnp.zeros((b,), jnp.int32)}
    out_ref, new_ref = layers.attention_apply(params, acfg, x, cache=contig)

    cache = {"kp": jnp.zeros((1 + max_pages, ps, kvh, hd)),
             "vp": jnp.zeros((1 + max_pages, ps, kvh, hd)),
             "pages": jnp.asarray(
                 np.arange(1, max_pages + 1, dtype=np.int32)[None]),
             "index": jnp.zeros((b,), jnp.int32)}
    outs = []
    for s0 in range(0, L, C):
        n = min(C, L - s0)
        xi = x[:, s0:s0 + n]
        if n < C:                      # the engine pads the final chunk
            xi = jnp.pad(xi, ((0, 0), (0, C - n), (0, 0)))
        o, cache = layers.attention_apply(params, acfg, xi, cache=cache,
                                          use_flash=use_flash)
        # The engine resets the write position to the true length after a
        # padded chunk so padded rows are never attended.
        cache = dict(cache, index=jnp.minimum(cache["index"], L))
        outs.append(o[:, :n])
    out_chunk = jnp.concatenate(outs, axis=1)

    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_ref),
                               rtol=3e-5, atol=3e-5)
    ck, cv = paged.gather_kv(cache["kp"], cache["vp"], cache["pages"])
    np.testing.assert_array_equal(np.asarray(ck[:, :L]),
                                  np.asarray(new_ref["k"][:, :L]))
    np.testing.assert_array_equal(np.asarray(cv[:, :L]),
                                  np.asarray(new_ref["v"][:, :L]))


# ----------------------------------------------------------------------------
# Engine-level: parity, single executable, interleave, preemption
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("use_flash", [False, True])
def test_chunked_engine_matches_reference(model, use_flash):
    """Multi-chunk prompts (straddling page boundaries) reproduce the
    contiguous whole-prompt reference token streams exactly."""
    cfg, params = model
    if use_flash:
        cfg = dataclasses.replace(cfg, use_flash=True)
    rng = np.random.RandomState(0)
    prompts = {rid: rng.randint(2, cfg.vocab, size=n).astype(np.int32)
               for rid, n in enumerate((5, 16, 17, 27))}
    eng = ServingEngine(params, cfg, _chunked_cfg())
    for rid, pr in prompts.items():
        eng.submit(Request(rid=rid, prompt=pr, max_new=5))
    got = eng.run_until_drained()
    for rid, pr in prompts.items():
        ref = greedy_generate(params, model[0], jnp.asarray(pr)[None], 5,
                              max_len=64)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid
    assert eng.pool.pages_in_use == 0


def test_chunked_engine_compiles_one_prefill_executable(model):
    """The whole point of fixed-size chunks: ten distinct prompt lengths,
    one prefill trace — not one per bucket. check.sh's serving subset
    runs this test as the single-trace gate for the chunked path."""
    cfg, params = model
    rng = np.random.RandomState(1)
    eng = ServingEngine(params, cfg, _chunked_cfg())
    for rid, n in enumerate((3, 4, 7, 8, 9, 15, 16, 17, 25, 31)):
        eng.submit(Request(rid=rid,
                           prompt=rng.randint(2, cfg.vocab, n)
                           .astype(np.int32), max_new=3))
    eng.run_until_drained()
    assert set(eng.prefill_traces) == {eng.chunk}
    assert eng.prefill_traces[eng.chunk] == 1, eng.prefill_traces
    assert eng.decode_traces == 1


def test_decode_progresses_while_long_prompt_prefills(model):
    """The head-of-line fix: a 27-token prompt needs 4 chunk ticks; the
    already-decoding slot must gain one token per tick throughout."""
    cfg, params = model
    rng = np.random.RandomState(2)
    short = rng.randint(2, cfg.vocab, 5).astype(np.int32)
    long = rng.randint(2, cfg.vocab, 27).astype(np.int32)
    eng = ServingEngine(params, cfg, _chunked_cfg())
    eng.submit(Request(rid=0, prompt=short, max_new=20))
    eng.tick()
    gen0 = len(eng.slots[0].generated)
    eng.submit(Request(rid=1, prompt=long, max_new=3))
    eng.tick()                       # admits rid=1, first chunk
    assert 1 in eng._prefilling      # still mid-prefill
    mid_ticks = 0
    while 1 in eng._prefilling:
        gen_before = len(eng.slots[0].generated)
        eng.tick()
        mid_ticks += 1
        # Decode made progress in the same tick the chunk streamed.
        assert len(eng.slots[0].generated) == gen_before + 1
    assert mid_ticks >= 1
    got = eng.run_until_drained()
    ref0 = greedy_generate(params, cfg, jnp.asarray(short)[None], 20,
                           max_len=64)
    ref1 = greedy_generate(params, cfg, jnp.asarray(long)[None], 3,
                           max_len=64)
    assert got[0] == np.asarray(ref0[0]).tolist()
    assert got[1] == np.asarray(ref1[0]).tolist()
    assert gen0 >= 1


def test_pool_exhaustion_preempts_youngest_not_raises(model):
    """Graceful degradation: when decode growth outruns the pool, the
    youngest slot is evicted back to the queue (pages freed, generated
    tokens preserved) and both requests still finish with reference
    streams."""
    cfg, params = model
    rng = np.random.RandomState(3)
    # 5 usable pages; each request grows to 24 rows = 3 pages.
    scfg = _chunked_cfg(n_pages=6)
    eng = ServingEngine(params, cfg, scfg)
    pa = rng.randint(2, cfg.vocab, 15).astype(np.int32)
    pb = rng.randint(2, cfg.vocab, 15).astype(np.int32)
    eng.submit(Request(rid=0, prompt=pa, max_new=9))
    eng.submit(Request(rid=1, prompt=pb, max_new=9))
    got = eng.run_until_drained()
    assert eng.preemptions >= 1
    for rid, pr in ((0, pa), (1, pb)):
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], 9,
                              max_len=64)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid
    assert eng.pool.pages_in_use == 0


def test_preempted_request_preserves_generated_tokens(model):
    """A preempted request re-prefills prompt + generated-so-far and
    continues the same stream — the preserved tokens are not lost and
    not regenerated."""
    cfg, params = model
    rng = np.random.RandomState(4)
    scfg = _chunked_cfg(n_pages=6, batch=2)
    eng = ServingEngine(params, cfg, scfg)
    pa = rng.randint(2, cfg.vocab, 15).astype(np.int32)
    eng.submit(Request(rid=0, prompt=pa, max_new=9))
    # Let rid=0 decode a few tokens before the competitor arrives.
    for _ in range(3):
        eng.tick()
    head = list(eng.slots[0].generated) if eng.slots[0] else []
    eng.submit(Request(rid=1, prompt=rng.randint(2, cfg.vocab, 15)
                       .astype(np.int32), max_new=9))
    got = eng.run_until_drained()
    ref = greedy_generate(params, cfg, jnp.asarray(pa)[None], 9, max_len=64)
    assert got[0] == np.asarray(ref[0]).tolist()
    assert got[0][:len(head)] == head        # prefix survived preemption


def test_srf_chunk_order_cuts_mean_ttft(model):
    """Prefill-chunk admission fairness: under a per-tick chunk budget,
    shortest-remaining-first ordering finishes the short prompt's prefill
    first even though the long prompt holds the lower slot — mean TTFT
    2.5 ticks here vs the 3.0 slot-order round-robin would give (short
    would wait a tick behind the long prompt's first chunk)."""
    cfg, params = model
    rng = np.random.RandomState(6)
    scfg = _chunked_cfg(prefill_chunks_per_tick=1)
    eng = ServingEngine(params, cfg, scfg)
    long = rng.randint(2, cfg.vocab, 24).astype(np.int32)    # 3 chunks
    short = rng.randint(2, cfg.vocab, 8).astype(np.int32)    # 1 chunk
    eng.submit(Request(rid=0, prompt=long, max_new=8))       # slot 0 first
    eng.submit(Request(rid=1, prompt=short, max_new=8))
    got = eng.run_until_drained()
    assert eng.first_token_tick == {1: 1, 0: 4}              # SRPT order
    mean_ttft = sum(eng.first_token_tick.values()) / 2
    assert mean_ttft < 3.0                                   # RR baseline
    for rid, pr in ((0, long), (1, short)):                  # streams exact
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], 8,
                              max_len=64)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid


def test_prefill_budget_caps_chunks_per_tick(model):
    """prefill_chunks_per_tick=1: two mid-prefill slots advance on
    alternating ticks (by remaining length), never both in one."""
    cfg, params = model
    rng = np.random.RandomState(7)
    eng = ServingEngine(params, cfg,
                        _chunked_cfg(prefill_chunks_per_tick=1))
    eng.submit(Request(rid=0, prompt=rng.randint(2, cfg.vocab, 24)
                       .astype(np.int32), max_new=2))
    eng.submit(Request(rid=1, prompt=rng.randint(2, cfg.vocab, 24)
                       .astype(np.int32), max_new=2))
    eng.tick()
    assert dict(eng._prefilling) == {0: 8, 1: 0}   # only one chunk ran
    eng.tick()
    # SRPT commits to the slot with the least remaining — slot 0 again —
    # instead of round-robining; slot 1 starts once slot 0 is done.
    assert dict(eng._prefilling) == {0: 16, 1: 0}
    got = eng.run_until_drained()
    assert set(got) == {0, 1}
    assert eng.first_token_tick[0] < eng.first_token_tick[1]


def test_srf_aging_prevents_long_prompt_starvation(model):
    """Pure SRPT would starve: under a 1-chunk budget a long prompt loses
    to every fresh short arrival forever. The aging term (each waiting
    tick shrinks effective remaining work by one chunk) guarantees
    service every ~remaining-chunks ticks, so the long prompt's cursor
    must advance *while* shorts are still streaming in — and everything
    still drains to the exact reference streams."""
    cfg, params = model
    rng = np.random.RandomState(8)
    scfg = _chunked_cfg(batch=4, prefill_chunks_per_tick=1)
    eng = ServingEngine(params, cfg, scfg)
    long = rng.randint(2, cfg.vocab, 24).astype(np.int32)    # 3 chunks
    eng.submit(Request(rid=0, prompt=long, max_new=4))
    shorts = {rid: rng.randint(2, cfg.vocab, 8).astype(np.int32)
              for rid in range(1, 9)}                        # 1 chunk each
    for rid, pr in shorts.items():
        eng.submit(Request(rid=rid, prompt=pr, max_new=2))
    served_mid_stream = False
    for _ in range(8):
        eng.tick()
        # Aging bound: with ~3 chunks remaining the long prompt is
        # outranked for at most ~3 ticks before it wins a budget slot.
        if eng.queue and eng._prefilling.get(0, 0) > 0:
            served_mid_stream = True
    assert served_mid_stream                  # no starvation
    got = eng.run_until_drained()
    assert eng.first_token_tick[0] <= 11
    for rid, pr in [(0, long)] + list(shorts.items()):
        n = 4 if rid == 0 else 2
        ref = greedy_generate(params, cfg, jnp.asarray(pr)[None], n,
                              max_len=64)
        assert got[rid] == np.asarray(ref[0]).tolist(), rid


def test_chunk_page_need_prices_spans():
    assert paged.chunk_page_need(0, 8, 0, 8, 64) == 1
    assert paged.chunk_page_need(8, 8, 1, 8, 64) == 1
    assert paged.chunk_page_need(4, 8, 1, 8, 64) == 1     # straddle
    assert paged.chunk_page_need(12, 3, 2, 8, 64) == 0    # inside page 2
    assert paged.chunk_page_need(60, 8, 8, 8, 64) == 0    # clipped at max
    assert paged.chunk_page_need(56, 16, 7, 8, 64) == 1   # clip to 64


def test_chunked_admission_reserves_first_chunk_only(model):
    cfg, params = model
    eng = ServingEngine(params, cfg, _chunked_cfg(batch=1))
    rng = np.random.RandomState(5)
    eng.submit(Request(rid=0, prompt=rng.randint(2, cfg.vocab, 27)
                       .astype(np.int32), max_new=2))
    eng.tick()     # admit + first chunk (8 rows -> 1 page)
    assert len(eng.pool.slot_pages[0]) == 1
    eng.tick()     # second chunk
    assert len(eng.pool.slot_pages[0]) == 2
    got = eng.run_until_drained()
    assert len(got[0]) == 2
