"""Constant-resolution contract for the calibration pass
(core/calibrate.py + the ``calibrated:`` tuning-cache namespace):
probes measure every serving-path constant finite and positive,
``resolve_constants`` prefers calibrated entries per constant with
torn/mis-versioned entries falling back silently to the hand-set
defaults, the ``choose_*`` decisions respond monotonically to the
constants that price them, the serving engine provably prices its
decisions from the calibrated set, and ``REPRO_DEFAULT_CONSTANTS``
reproduces the default decisions bit-for-bit."""

import dataclasses
import json

import jax
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro import configs
from repro.core import autotune, calibrate
from repro.models import transformer as T
from repro.serve import telemetry
from repro.serve.engine import ServeConfig, ServingEngine

SYNTH = {"dispatch_s": 3e-6, "page_lookup_s": 7e-8,
         "hbm_bandwidth": 2e10, "chunk_dispatch_s": 9e-6,
         "draft_token_s": 4e-6, "prefix_hash_s": 1e-6}

# Cost ladder for the monotonicity properties (indices drawn by
# hypothesis; the ladder itself is deterministic).
COSTS = tuple(float(c) for c in np.geomspace(1e-7, 1e-2, 12))


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    """Isolated tuning cache + no force-defaults env leakage."""
    path = tmp_path / "cache.json"
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH", str(path))
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    monkeypatch.delenv(autotune.DEFAULT_CONSTANTS_ENV, raising=False)
    return path


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def fast_results():
    """One fast probe pass for the whole module (the chunk probe runs a
    real engine); persist=False keeps the committed cache untouched."""
    return calibrate.run_calibration(fast=True, persist=False)


# ----------------------------------------------------------------------------
# Probes: every constant measured, finite, positive
# ----------------------------------------------------------------------------

def test_probes_cover_every_constant_finite_positive(fast_results):
    assert set(fast_results) == set(autotune.CALIBRATED_NAMES)
    assert len(fast_results) >= 5
    for name, r in fast_results.items():
        assert np.isfinite(r.value) and r.value > 0, (name, r)
        assert r.n_trials > 0
        assert np.isfinite(r.spread) and r.spread >= 0
        assert r.unit


def test_page_lookup_probe_reports_its_regression(fast_results):
    d = fast_results["page_lookup_s"].detail
    assert np.isfinite(d["slope_paged_s"])
    assert np.isfinite(d["slope_contig_s"])
    assert len(d["tables"]) >= 3


def test_probe_result_rejects_nonfinite():
    with pytest.raises(AssertionError):
        calibrate.ProbeResult("dispatch_s", float("nan"), "s", 1, 0.0)
    with pytest.raises(AssertionError):
        calibrate.ProbeResult("dispatch_s", 0.0, "s", 1, 0.0)
    with pytest.raises(AssertionError):
        calibrate.ProbeResult("not_a_constant", 1.0, "s", 1, 0.0)


# ----------------------------------------------------------------------------
# Cache namespace: record / load / resolve round trip
# ----------------------------------------------------------------------------

def test_record_load_resolve_roundtrip(tmp_cache):
    for name, v in SYNTH.items():
        autotune.record_calibration(name, v, n_trials=5, spread=0.1,
                                    timestamp=123.0)
    for name, v in SYNTH.items():
        hit = autotune.load_calibration(name)
        assert hit["value"] == v
        assert hit["n_trials"] == 5
        assert hit["schema_version"] == autotune.CALIBRATION_SCHEMA_VERSION
    const = autotune.resolve_constants()
    assert const.source == "calibrated"
    assert const.dispatch_s == SYNTH["dispatch_s"]
    assert const.page_lookup_s == SYNTH["page_lookup_s"]
    assert const.hbm_bandwidth == SYNTH["hbm_bandwidth"]
    assert const.chunk_dispatch_s == SYNTH["chunk_dispatch_s"]
    assert const.draft_token_s == SYNTH["draft_token_s"]
    assert const.prefix_hash_s == SYNTH["prefix_hash_s"]
    assert const.timestamp == 123.0
    rep = autotune.calibration_report()
    assert rep["source"] == "calibrated"
    for name in autotune.CALIBRATED_NAMES:
        row = rep["constants"][name]
        assert row["measured"] == SYNTH[name]
        assert np.isfinite(row["drift_ratio"]) and row["drift_ratio"] > 0
        assert row["n_trials"] == 5


def test_record_rejects_nonfinite_and_unknown(tmp_cache):
    with pytest.raises(AssertionError):
        autotune.record_calibration("dispatch_s", float("inf"))
    with pytest.raises(AssertionError):
        autotune.record_calibration("dispatch_s", -1e-6)
    with pytest.raises(AssertionError):
        autotune.record_calibration("made_up_constant", 1.0)


def test_torn_or_misversioned_entries_fall_back_per_constant(tmp_cache):
    blob = {
        autotune.calibration_key("page_lookup_s"): {
            "schema_version": autotune.CALIBRATION_SCHEMA_VERSION,
            "value": 7e-8, "backend": "cpu", "mesh": "dev1",
            "n_trials": 3, "timestamp": 1.0},
        autotune.calibration_key("chunk_dispatch_s"): "torn garbage",
        autotune.calibration_key("draft_token_s"): {
            "schema_version": 999, "value": 1e-6},
        autotune.calibration_key("hbm_bandwidth"): {
            "schema_version": autotune.CALIBRATION_SCHEMA_VERSION,
            "value": -4.0},
        autotune.calibration_key("prefix_hash_s"): {
            "schema_version": autotune.CALIBRATION_SCHEMA_VERSION,
            "value": "not a number"},
    }
    tmp_cache.write_text(json.dumps(blob))
    autotune._tuning_cache = None
    assert autotune.load_calibration("page_lookup_s")["value"] == 7e-8
    for broken in ("chunk_dispatch_s", "draft_token_s", "hbm_bandwidth",
                   "prefix_hash_s", "dispatch_s"):
        assert autotune.load_calibration(broken) is None
    const = autotune.resolve_constants()          # never raises
    assert const.source == "calibrated"
    assert const.page_lookup_s == 7e-8            # the one valid entry
    assert const.chunk_dispatch_s == autotune.CHUNK_DISPATCH_S
    assert const.draft_token_s == autotune.NGRAM_DRAFT_S
    assert const.prefix_hash_s == autotune.PREFIX_HASH_S
    assert const.hbm_bandwidth is None
    assert const.dispatch_s is None


def test_env_switch_forces_defaults(tmp_cache, monkeypatch):
    autotune.record_calibration("chunk_dispatch_s", 1e-3, n_trials=3,
                                spread=0.0, timestamp=1.0)
    assert autotune.resolve_constants().source == "calibrated"
    monkeypatch.setenv(autotune.DEFAULT_CONSTANTS_ENV, "1")
    assert autotune.resolve_constants() == autotune.DEFAULT_CONSTANTS
    monkeypatch.setenv(autotune.DEFAULT_CONSTANTS_ENV, "0")
    assert autotune.resolve_constants().source == "calibrated"


def test_run_calibration_persists_under_calibrated_keys(tmp_cache):
    # Synthetic persistence path (probe values injected via the public
    # API): every CALIBRATED_NAMES key lands in the calibrated: namespace
    # with metadata, and the validator's shape holds.
    for name, v in SYNTH.items():
        autotune.record_calibration(name, v, n_trials=4, spread=0.2,
                                    unit="s", timestamp=9.0)
    raw = json.loads(tmp_cache.read_text())
    keys = [k for k in raw if k.startswith(autotune.CALIBRATED_PREFIX)]
    assert len(keys) == len(autotune.CALIBRATED_NAMES)
    for k in keys:
        e = raw[k]
        assert e["schema_version"] == autotune.CALIBRATION_SCHEMA_VERSION
        assert e["value"] > 0 and e["n_trials"] == 4
        assert isinstance(e["backend"], str) and isinstance(e["mesh"], str)


# ----------------------------------------------------------------------------
# Decisions respond monotonically to the constants that price them
# ----------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=len(COSTS) - 1),
       st.integers(min_value=0, max_value=len(COSTS) - 1))
def test_chunk_no_smaller_under_bigger_dispatch_cost(i, j):
    if i > j:
        i, j = j, i
    lo = dataclasses.replace(autotune.DEFAULT_CONSTANTS,
                             chunk_dispatch_s=COSTS[i])
    hi = dataclasses.replace(autotune.DEFAULT_CONSTANTS,
                             chunk_dispatch_s=COSTS[j])
    c_lo, _ = autotune.choose_prefill_chunk(4096, 16, 4, 128, 8,
                                            constants=lo)
    c_hi, _ = autotune.choose_prefill_chunk(4096, 16, 4, 128, 8,
                                            constants=hi)
    assert c_hi >= c_lo, (COSTS[i], COSTS[j], c_lo, c_hi)


@given(st.integers(min_value=0, max_value=len(COSTS) - 1),
       st.integers(min_value=0, max_value=len(COSTS) - 1))
def test_spec_k_no_larger_under_bigger_draft_cost(i, j):
    if i > j:
        i, j = j, i
    lengths = [256, 512, 1024, 2048]
    lo = dataclasses.replace(autotune.DEFAULT_CONSTANTS,
                             draft_token_s=COSTS[i])
    hi = dataclasses.replace(autotune.DEFAULT_CONSTANTS,
                             draft_token_s=COSTS[j])
    k_lo, _ = autotune.choose_spec_k(lengths, 16, 4, 128, 8, 0.7, 4e9,
                                     constants=lo)
    k_hi, _ = autotune.choose_spec_k(lengths, 16, 4, 128, 8, 0.7, 4e9,
                                     constants=hi)
    assert k_hi <= k_lo, (COSTS[i], COSTS[j], k_lo, k_hi)


def test_constants_argument_defaults_to_the_handset_set():
    # constants=None must be the pre-calibration arithmetic exactly —
    # the bit-for-bit reproducibility contract every existing caller
    # (tests, bench cells) relies on.
    plain = autotune.prefill_chunk_model(4096, 256, 16, 4, 128, 8)
    pinned = autotune.prefill_chunk_model(
        4096, 256, 16, 4, 128, 8, constants=autotune.DEFAULT_CONSTANTS)
    assert plain == pinned


# ----------------------------------------------------------------------------
# The engine provably prices choose_* from the calibrated set
# ----------------------------------------------------------------------------

def test_engine_prices_chunk_from_calibrated_set(tmp_cache, model,
                                                 monkeypatch):
    cfg, params = model
    # A huge measured chunk-dispatch cost: the chunk model amortizes it
    # with a bigger chunk than the defaults would pick.
    autotune.record_calibration("chunk_dispatch_s", 2e-3, n_trials=3,
                                spread=0.0, timestamp=42.0)
    scfg = ServeConfig(max_len=512, batch=2, eos_id=-1, paged=True,
                       page_size=8, chunk_size=None)
    eng = ServingEngine(params, cfg, scfg)
    assert eng.constants.source == "calibrated"
    assert eng.constants.chunk_dispatch_s == 2e-3
    expect, _ = autotune.choose_prefill_chunk(
        512, cfg.n_heads, cfg.n_kv_heads, cfg.dhead, 8,
        constants=eng.constants)
    assert eng.chunk == expect
    default_chunk, _ = autotune.choose_prefill_chunk(
        512, cfg.n_heads, cfg.n_kv_heads, cfg.dhead, 8)
    assert eng.chunk != default_chunk    # the decision provably moved
    # Forcing defaults reproduces the pre-calibration decision
    # bit-for-bit, same cache contents.
    monkeypatch.setenv(autotune.DEFAULT_CONSTANTS_ENV, "1")
    eng2 = ServingEngine(params, cfg, scfg)
    assert eng2.constants == autotune.DEFAULT_CONSTANTS
    assert eng2.chunk == default_chunk


def test_drift_report_carries_constant_provenance(tmp_cache, model):
    cfg, params = model
    autotune.record_calibration("page_lookup_s", 7e-8, n_trials=3,
                                spread=0.1, timestamp=7.0)
    eng = ServingEngine(params, cfg, ServeConfig(
        max_len=32, batch=2, eos_id=-1, paged=True, page_size=8,
        chunk_size=8))
    rep = telemetry.drift_report(eng)
    assert rep["constants"]["source"] == "calibrated"
    cal = rep["calibration"]
    assert cal["source"] == "calibrated"
    row = cal["constants"]["page_lookup_s"]
    assert row["measured"] == 7e-8
    assert row["drift_ratio"] == pytest.approx(
        7e-8 / autotune.PAGE_LOOKUP_S)
    assert cal["constants"]["chunk_dispatch_s"]["measured"] is None
