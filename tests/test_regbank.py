"""Register bank model + Table 1.1 + Fig 3.8 dissection."""

import pytest
from hypothesis import given, strategies as st

from repro.core import hwmodel, regbank
from repro.core.regbank import FFMA


V = hwmodel.V100.regfile
P = hwmodel.P100.regfile


def test_table_1_1_listings_parse_and_cover():
    nvcc = regbank.parse_listing(regbank.NVCC_LISTING)
    opt = regbank.parse_listing(regbank.IMPROVED_LISTING)
    assert len(nvcc) == 64 and len(opt) == 64
    assert regbank.tile_coverage(nvcc)
    assert regbank.tile_coverage(opt)


def test_nvcc_has_conflicts_improved_has_none():
    nvcc = regbank.parse_listing(regbank.NVCC_LISTING)
    opt = regbank.parse_listing(regbank.IMPROVED_LISTING)
    for mode, expect_nvcc in (("pair", 4), ("next", 8)):
        _, s_n = regbank.instruction_cycles(V, nvcc, mode)
        _, s_o = regbank.instruction_cycles(V, opt, mode)
        assert s_n == expect_nvcc
        assert s_o == 0


def test_modeled_speedup_brackets_paper():
    nvcc = regbank.parse_listing(regbank.NVCC_LISTING)
    opt = regbank.parse_listing(regbank.IMPROVED_LISTING)
    g_n = regbank.gflops_per_sm(V, nvcc, 1380.0)
    g_o = regbank.gflops_per_sm(V, opt, 1380.0)
    # Calibrated on the optimized kernel (152.43); NVCC prediction should be
    # within a few percent of the measured 132.05.
    assert abs(g_o - regbank.PAPER_GFLOPS_IMPROVED) < 0.5
    assert abs(g_n - regbank.PAPER_GFLOPS_NVCC) / 132.05 < 0.05


def test_volta_conflict_rule():
    # 3 same-bank sources stall; 2 do not (64-bit banks).
    ins3 = FFMA(6, (2, 4, 8), (False,) * 3)
    ins2 = FFMA(6, (2, 4, 9), (False,) * 3)
    assert regbank.instruction_cycles(V, [ins3])[1] == 1
    assert regbank.instruction_cycles(V, [ins2])[1] == 0


def test_pascal_conflict_rule():
    # 2 same-bank sources already stall (32-bit banks).
    ins2 = FFMA(6, (2, 6, 9), (False,) * 3)      # 2 % 4 == 6 % 4
    assert regbank.instruction_cycles(P, [ins2])[1] == 1


def test_reuse_cache_prevents_conflict():
    a = FFMA(6, (2, 4, 8), (True, False, False))
    b = FFMA(7, (2, 4, 8), (False, False, False))   # slot0 hit -> 2 reads
    _, stalls = regbank.instruction_cycles(V, [a, b], reuse_mode="next")
    assert stalls == 1                               # only the first instr


def test_dissect_banks_volta_and_pascal():
    for spec, expect in ((V, (2, 64)), (P, (4, 32)),
                         (hwmodel.M60.regfile, (4, 32)),
                         (hwmodel.K80.regfile, (4, 32))):
        probe = lambda srcs: regbank.ffma_probe(spec, srcs)
        assert regbank.dissect_register_banks(probe, probe) == expect


def test_fig_3_8_sweep_periodicity():
    # FFMA R6, R97, R99, RX: conflicts iff RX odd on Volta.
    probe3 = lambda srcs: regbank.ffma_probe(V, srcs)
    lat = regbank.conflict_sweep(probe3, (97, 99), range(8, 24))
    pattern = [l > min(lat) for l in lat]
    assert pattern == [x % 2 == 1 for x in range(8, 24)]
