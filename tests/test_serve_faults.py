"""Fault injection against the serving engine: every injected fault must
have a measured response (counters moved), a bounded one (no crash, no
hang, preemptions capped per request), and a recovering one (the engine
returns to clean service when the window ends) — while every token any
degraded mode emits stays bit-identical to the fault-free engine's
stream for that request (full stream for completed requests, exact
prefix for force-completed ones)."""

import json
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import autotune
from repro.models import transformer as T
from repro.serve import spec
from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                SLOClass, greedy_generate)
from repro.serve.faults import (Fault, FaultInjector, PHANTOM_SLOT,
                                canonical_schedule)
from repro.serve import traffic


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scfg(**kw):
    base = dict(max_len=64, batch=2, eos_id=-1, paged=True, page_size=8,
                chunk_size=8)
    base.update(kw)
    return ServeConfig(**base)


def _refs(model, prompts, max_new):
    cfg, params = model
    return {rid: np.asarray(greedy_generate(
        params, cfg, jnp.asarray(pr)[None], max_new, max_len=64)[0]).tolist()
        for rid, pr in prompts.items()}


def _drive(eng, inj, max_ticks=400):
    for _ in range(max_ticks):
        inj.step(eng)
        eng.tick()
        if not eng.queue and all(s is None for s in eng.slots):
            break
    inj.finish(eng)


# ----------------------------------------------------------------------------
# Pressure signal + degradation latch (pure functions)
# ----------------------------------------------------------------------------

def test_serve_pressure_saturates_on_either_resource():
    assert autotune.serve_pressure(0.0, 0, 8) == 0.0
    assert autotune.serve_pressure(0.9, 0, 8) == pytest.approx(0.9)
    assert autotune.serve_pressure(0.1, 8, 8) == 1.0     # queue alone
    assert autotune.serve_pressure(2.0, 100, 8) == 1.0   # bounded
    assert autotune.serve_pressure(0.5, 2, 8) == 0.5     # max, not sum


def test_choose_degradation_hysteresis():
    h, lo = autotune.DEGRADE_HIGH, autotune.DEGRADE_LOW
    assert not autotune.choose_degradation(h - 0.01, False)
    assert autotune.choose_degradation(h, False)          # enter at high
    assert autotune.choose_degradation(lo + 0.01, True)   # dead band holds
    assert not autotune.choose_degradation(lo, True)      # leave at low
    with pytest.raises(AssertionError):
        autotune.choose_degradation(0.5, False, high=0.3, low=0.6)


# ----------------------------------------------------------------------------
# Preemption policy: priority + cost victim choice, guards
# ----------------------------------------------------------------------------

def test_choose_victim_protects_high_class_and_near_done(model):
    cfg, params = model
    rng = np.random.RandomState(0)
    eng = ServingEngine(params, cfg, _scfg(
        batch=3, classes=(SLOClass("hi", priority=2), SLOClass("lo")),
        max_preemptions=3, preempt_cooldown=2))
    pr = {r: rng.randint(2, cfg.vocab, 8).astype(np.int32) for r in range(3)}
    eng.submit(Request(rid=0, prompt=pr[0], max_new=20, rclass="hi"))
    eng.submit(Request(rid=1, prompt=pr[1], max_new=20, rclass="lo"))
    eng.submit(Request(rid=2, prompt=pr[2], max_new=8, rclass="lo"))
    for _ in range(3):
        eng.tick()
    assert all(s is not None for s in eng.slots)
    # rid1: lo class, far from done -> cheapest eviction.
    assert eng._choose_victim([0, 1, 2]) == 1
    # Storm guard: a just-readmitted slot is skipped while others exist.
    eng.slots[1].readmitted_at = eng.ticks
    assert eng._choose_victim([0, 1, 2]) == 2
    # Cap guard: a capped slot is skipped; the cooling one returns as the
    # fallback before the high-class slot is touched.
    eng.slots[2].preempt_count = 3
    assert eng._choose_victim([0, 1, 2]) == 1
    # A preemption that must happen always can: sole victim wins every
    # filter fallback.
    assert eng._choose_victim([2]) == 2


def test_churn_storm_is_bounded_by_max_preemptions(model):
    """Satellite: a sustained preemption storm (one forced eviction per
    tick through the engine's own victim policy) can never preempt the
    same request more than ``max_preemptions`` times — the next eviction
    force-completes or cleanly rejects it, and nothing hangs."""
    cfg, params = model
    rng = np.random.RandomState(1)
    reqs = [Request(rid=r, prompt=rng.randint(2, cfg.vocab, 10)
                    .astype(np.int32), max_new=16) for r in range(4)]
    eng = ServingEngine(params, cfg, _scfg(
        batch=2, max_preemptions=2, preempt_cooldown=1))
    for r in reqs:
        eng.submit(r)
    inj = FaultInjector([Fault(kind=FaultInjector.SLOT_CHURN, start=2,
                               stop=40, victims_per_tick=2)])
    _drive(eng, inj)
    assert not eng.queue and all(s is None for s in eng.slots)
    for r in reqs:
        assert r.preempt_count <= 2, (r.rid, r.preempt_count)
        assert eng.outcome[r.rid] in (
            "done", "forced:preempt_limit", "rejected:preempt_limit",
            "forced:max_len")
    evictions = {}
    for rid, _, _ in eng.preemption_log:
        evictions[rid] = evictions.get(rid, 0) + 1
    assert evictions and all(n <= 2 for n in evictions.values())
    # The storm was violent enough that the cap actually fired.
    assert any(o.endswith("preempt_limit") for o in eng.outcome.values())


# ----------------------------------------------------------------------------
# Pool exhaustion: squeezed to zero free pages, then recovery
# ----------------------------------------------------------------------------

def test_pool_squeeze_degrades_then_recovers_bit_identical(model):
    """A phantom co-tenant grabs every free page for six ticks. The
    engine must hold admissions / preempt / self-preempt (measured),
    never crash, and once the squeeze clears, finish everything it can
    — with every completed stream bit-identical to the fault-free run
    and every force-completed stream an exact prefix of it."""
    cfg, params = model
    rng = np.random.RandomState(2)
    prompts = {r: rng.randint(2, cfg.vocab, 12).astype(np.int32)
               for r in range(4)}
    refs = _refs(model, prompts, 8)

    eng = ServingEngine(params, cfg, _scfg(batch=2, n_pages=17,
                                           max_preemptions=3))
    for r, pr in prompts.items():
        eng.submit(Request(rid=r, prompt=pr, max_new=8))
    inj = FaultInjector([Fault(kind=FaultInjector.POOL_SQUEEZE, start=2,
                               stop=8, min_free=0)])
    _drive(eng, inj)
    assert inj.injected == 1 and inj.cleared == 1
    # Measured response: the squeeze visibly moved the failure counters.
    assert eng.admission_rejections + eng.preemptions >= 1
    # Bounded + recovering: every request terminal, phantom released,
    # no page leaked.
    assert PHANTOM_SLOT not in eng.pool.slot_pages
    assert eng.pool.pages_in_use == 0
    for r in prompts:
        out = eng.outcome[r]
        if out == "done":
            assert eng.finished[r] == refs[r], r
        elif out.startswith("forced"):
            got = eng.finished[r]
            assert got == refs[r][:len(got)], r       # exact prefix
        else:
            assert out.startswith("rejected:"), out


# ----------------------------------------------------------------------------
# Accept-rate collapse: adaptive disable, then probe-driven recovery
# ----------------------------------------------------------------------------

def test_accept_collapse_probe_ticks_recover_speculation(model):
    """Satellite (ROADMAP carry-over): the ``k_live=0`` disable regime
    used to be terminal. With ``spec_probe_every`` set, an injected
    accept collapse must drive ``k_live`` to 0, and once the fault
    clears, periodic k=1 trial ticks must feed the adaptation window
    until speculation re-opens — with the emitted stream exactly the
    plain reference throughout."""
    cfg, params = model
    prompt = list(range(3, 11))
    ref = np.asarray(greedy_generate(
        params, cfg, jnp.asarray(prompt)[None], 40, max_len=64)[0]).tolist()
    draft = spec.ScriptedDraft(len(prompt), ref, [1], cfg.vocab)
    eng = ServingEngine(params, cfg, _scfg(
        batch=2, spec_k=2, draft=draft, spec_adapt_every=2,
        spec_probe_every=2))
    eng.submit(Request(rid=0, prompt=np.asarray(prompt, np.int32),
                       max_new=40))
    inj = FaultInjector([Fault(kind=FaultInjector.ACCEPT_COLLAPSE,
                               start=3, stop=11)])
    disabled_at = None
    for _ in range(200):
        inj.step(eng)
        eng.tick()
        if disabled_at is None and eng.k_live == 0:
            disabled_at = eng.ticks
        if not eng.queue and all(s is None for s in eng.slots):
            break
    inj.finish(eng)
    assert eng.finished[0] == ref                     # bit-identical
    assert disabled_at is not None, \
        "collapsed accept rate must disable speculation"
    assert eng.spec_probes >= 1                       # trial ticks ran
    assert eng.k_live >= 1, \
        "probing must re-open speculation after the collapse clears"
    assert eng.verify_traces == 1                     # still one executable


def test_without_probing_disable_stays_terminal(model):
    """Regression guard for the legacy contract: spec_probe_every=None
    keeps the disable regime terminal even after the fault clears."""
    cfg, params = model
    prompt = list(range(5, 13))
    ref = np.asarray(greedy_generate(
        params, cfg, jnp.asarray(prompt)[None], 24, max_len=64)[0]).tolist()
    draft = spec.ScriptedDraft(len(prompt), ref, [1], cfg.vocab)
    eng = ServingEngine(params, cfg, _scfg(batch=2, spec_k=2, draft=draft,
                                           spec_adapt_every=2))
    eng.submit(Request(rid=0, prompt=np.asarray(prompt, np.int32),
                       max_new=24))
    inj = FaultInjector([Fault(kind=FaultInjector.ACCEPT_COLLAPSE,
                               start=2, stop=8)])
    _drive(eng, inj)
    assert eng.finished[0] == ref
    assert eng.k_live == 0 and eng.spec_probes == 0


# ----------------------------------------------------------------------------
# Torn tuning-cache reads
# ----------------------------------------------------------------------------

def test_torn_tuning_cache_discards_and_heals(tmp_path, monkeypatch):
    """CACHE_TORN truncates the persistent tuning cache mid-JSON (a torn
    concurrent write). The loader must discard the bad file and carry on
    analytically — never crash — and the window's end restores the
    original bytes byte-for-byte."""
    path = str(tmp_path / "attn_tuning_cache.json")
    good = {"k0": {"block_q": 128, "block_k": 128, "time_s": 1e-3,
                   "terms": {}}}
    with open(path, "w") as f:
        json.dump(good, f)
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH", path)
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    assert autotune._load_tuning_cache() == good

    stub = types.SimpleNamespace(ticks=0, pool=None, slots=[],
                                 _prefilling={}, draft=None)
    inj = FaultInjector([Fault(kind=FaultInjector.CACHE_TORN, start=1,
                               stop=3)], cache_path=path)
    stub.ticks = 1
    inj.step(stub)                    # arm: tear the file
    assert autotune._load_tuning_cache() == {}     # discarded, no crash
    stub.ticks = 3
    inj.step(stub)                    # disarm: heal
    assert inj.injected == 1 and inj.cleared == 1
    assert autotune._load_tuning_cache() == good   # bytes restored


# ----------------------------------------------------------------------------
# Degradation ladder: downshift under pressure, recover, stay exact
# ----------------------------------------------------------------------------

def test_degradation_ladder_downshifts_and_recovers(model):
    """A queue deeper than the batch drives pressure past the enter
    threshold: the engine must latch degraded (spec off, chunk budget
    1), spend measurable ticks there, and *leave* once pressure clears
    — with every emitted stream identical to the non-degrading engine's
    (the downshifts are stream-transparent by construction)."""
    cfg, params = model
    rng = np.random.RandomState(4)
    prompts = {r: rng.randint(2, cfg.vocab, 16).astype(np.int32)
               for r in range(6)}

    def run(degrade):
        eng = ServingEngine(params, cfg, _scfg(batch=2, degrade=degrade))
        for r, pr in prompts.items():
            eng.submit(Request(rid=r, prompt=pr, max_new=6))
        eng.run_until_drained()
        return eng

    hot, ref = run(True), run(False)
    assert hot.downshifts >= 1 and hot.degraded_ticks >= 1
    assert not hot.degraded, "pressure cleared: the latch must release"
    assert hot.last_pressure <= hot.scfg.pressure_low
    for r in prompts:
        assert hot.finished[r] == ref.finished[r], r


# ----------------------------------------------------------------------------
# The seeded end-to-end schedule (acceptance criterion)
# ----------------------------------------------------------------------------

def test_canonical_fault_schedule_end_to_end(model):
    """Pool exhaustion, then accept collapse, then a churn storm — the
    acceptance schedule — against open-loop traffic on the full stack
    (spec + adaptation + probing + degradation + SLO admission). Every
    offered request must complete or cleanly reject (zero crashes,
    zero hangs), and every surviving stream must be bit-identical to
    the fault-free engine's (prefix-exact for force-completed ones)."""
    cfg, params = model

    def build():
        return ServingEngine(params, cfg, _scfg(
            batch=2, n_pages=17, spec_k=2, draft="ngram",
            spec_adapt_every=4, spec_probe_every=4,
            classes=(SLOClass("default", ttft_slo=16),),
            max_queue=8, max_preemptions=3, degrade=True))

    arr = traffic.TrafficGenerator(traffic.TrafficConfig(
        rate=1.5, n_requests=18, seed=11, vocab=cfg.vocab,
        classes=(traffic.TrafficClass("default", prompt_lo=4, prompt_hi=20,
                                      out_lo=2, out_hi=8),))).arrivals()

    inj = FaultInjector(canonical_schedule(t0=4, dwell=8, gap=6))
    faulty = build()
    res = traffic.run_open_loop(faulty, arr, max_ticks=2000, injector=inj)
    inj.finish(faulty)
    clean = build()
    res_clean = traffic.run_open_loop(clean, arr, max_ticks=2000)

    # Zero hangs: every offered request reached a terminal outcome.
    assert res["unresolved"] == [] and res_clean["unresolved"] == []
    # All three fault windows armed and cleared.
    assert inj.injected == 3 and inj.cleared == 3
    assert faulty.pool.pages_in_use == 0
    # Bit-identical on survivors; exact prefixes on forced completions.
    compared = 0
    for a in arr:
        if clean.outcome.get(a.rid) != "done":
            continue
        out = faulty.outcome[a.rid]
        if out == "done":
            assert faulty.finished[a.rid] == clean.finished[a.rid], a.rid
            compared += 1
        elif out.startswith("forced"):
            got = faulty.finished[a.rid]
            assert got == clean.finished[a.rid][:len(got)], a.rid
            compared += 1
    assert compared >= 5, "schedule killed (almost) every stream"
    s = traffic.summarize(faulty, arr)
    assert s["done"] + s["forced"] + s["rejected"] == len(arr)
