"""HLO parsing, roofline math, autotuner and interconnect models."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import autotune, hlo_analysis, hwmodel, interconnect, roofline

HLO = """
HloModule jit_step
ENTRY %main {
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %ag = bf16[256,1024]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[512,512]{1,0} all-reduce(%x), to_apply=%add
  %rs = bf16[8,128]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = f32[64]{0} all-to-all(%z), dimensions={0}
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ars = f32[128,128]{1,0} all-reduce-start(%q)
  %ard = f32[128,128]{1,0} all-reduce-done(%ars)
  %dot = f32[128,128]{1,0} dot(%a, %b)
  ROOT %t = (f32[128,128]{1,0}) tuple(%dot)
}
"""


def test_collective_stats_parsing():
    stats = hlo_analysis.collective_stats(HLO)
    assert stats.bytes_by_kind["all-gather"] == 256 * 1024 * 2
    assert stats.bytes_by_kind["all-reduce"] == 512 * 512 * 4 + 128 * 128 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 8 * 128 * 2
    assert stats.bytes_by_kind["all-to-all"] == 64 * 4
    assert stats.bytes_by_kind["collective-permute"] == 32 * 32 * 2
    assert stats.count_by_kind["all-reduce"] == 2     # incl. async start


def test_op_census():
    census = hlo_analysis.op_census(HLO)
    assert census["dot"] == 1
    assert census["all-gather"] == 1


def test_shape_bytes():
    assert hlo_analysis.shape_bytes("bf16[16,1024]{1,0}") == 32768
    assert hlo_analysis.shape_bytes("f32[]") == 4
    assert hlo_analysis.shape_bytes("pred[7]") == 7


def test_roofline_terms_and_dominance():
    t = roofline.compute_terms(
        "a", "s", "m", chips=256,
        hlo_flops=1.97e12,            # 10 ms of compute at 197 TF
        hlo_bytes=8.19e9,             # 10 ms of HBM at 819 GB/s
        collective_bytes=1e9,         # 10 ms at 100 GB/s (2 links)
        model_flops=1.97e12 * 256 * 0.5)
    assert abs(t.compute_s - 0.01) < 1e-6
    assert abs(t.memory_s - 0.01) < 1e-6
    assert abs(t.collective_s - 0.01) < 1e-6
    assert t.flops_efficiency == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.5)
    t.memory_s *= 3
    assert t.dominant == "memory"


def test_roofline_json_roundtrip(tmp_path):
    t = roofline.compute_terms("a", "s", "m", 4, 1e12, 1e9, 1e8, 5e14)
    path = str(tmp_path / "rows.json")
    roofline.save_rows([t], path)
    (t2,) = roofline.load_rows(path)
    assert t2.compute_s == t.compute_s
    assert t2.dominant == t.dominant


@given(m=st.sampled_from([256, 1024, 4096]),
       k=st.sampled_from([512, 2048]),
       n=st.sampled_from([256, 2048, 8192]))
@settings(max_examples=15)
def test_autotuner_respects_vmem_and_beats_naive(m, k, n):
    p = autotune.GemmProblem(m=m, k=k, n=n)
    cfg, terms = autotune.choose_gemm_block(p)
    assert cfg.vmem_bytes(p) <= hwmodel.DEFAULT_TPU.vmem_bytes * 0.5
    gain = autotune.tuning_gain(p)
    assert gain["speedup"] >= 1.0      # tuned never loses to naive 128^3


def test_mxu_efficiency_cliffs():
    assert autotune.mxu_efficiency(256, 256, 256) == 1.0
    # m pads at sublane (8) granularity; k/n pad to the 128 MXU edge.
    assert autotune.mxu_efficiency(129, 256, 256) == pytest.approx(129 / 136)
    assert autotune.mxu_efficiency(256, 129, 256) == pytest.approx(129 / 256)
    assert autotune.mxu_efficiency(8, 128, 128) == 1.0
    assert autotune.mxu_efficiency(8, 100, 128) < 1.0


def test_layer_sharding_ranking():
    choices = autotune.choose_layer_sharding(
        batch_tokens=65536, d_in=4096, d_out=4096, data_axis=16,
        model_axis=16)
    names = [c.name for c in choices]
    assert set(names) == {"dp", "tp_col", "tp_row"}
    assert choices[0].time_s <= choices[-1].time_s


def test_alpha_beta_collective_costs():
    c = interconnect.collective_time("all_reduce", 1e9, 16)
    assert c.time_s > 0
    # all-reduce moves ~2x the all-gather bytes.
    g = interconnect.collective_time("all_gather", 1e9, 16)
    assert 1.8 < c.bytes_on_wire / g.bytes_on_wire < 2.2
    # alpha dominates tiny messages.
    tiny = interconnect.collective_time("all_reduce", 1e3, 16)
    assert tiny.alpha_s > tiny.beta_s


def test_link_comparison_table_5_1():
    links = interconnect.link_comparison()
    assert links["V100-NVLink2"][0] == pytest.approx(47.99)
    assert links["V100-PCIe"][0] == pytest.approx(10.63)
    eff = interconnect.measured_vs_theoretical()
    assert eff["V100-NVLink2"] > eff["V100-PCIe"]
