"""Minimal, deterministic stand-in for ``hypothesis`` when it isn't
installed (offline CI image).

Implements exactly the surface this repo's tests use — ``given``,
``settings`` (decorator + register_profile/load_profile), and
``strategies.integers / sampled_from / booleans / composite`` — by drawing
a fixed number of pseudo-random examples seeded from the test's qualified
name, so runs are reproducible and fixture-free (the wrapper exposes a
zero-argument signature to pytest, like real hypothesis).

``tests/conftest.py`` installs this module as ``sys.modules["hypothesis"]``
only when the real package is missing; with hypothesis installed it is
never imported.
"""

from __future__ import annotations

import sys
import types
import zlib

import numpy as np


# ----------------------------------------------------------------------------
# Strategies
# ----------------------------------------------------------------------------

class Strategy:
    def __init__(self, sample):
        self._sample = sample        # fn(rng: RandomState) -> value

    def map(self, f):
        return Strategy(lambda rng: f(self._sample(rng)))


def integers(min_value, max_value):
    span = int(max_value) - int(min_value)

    def sample(rng):
        if span < 2 ** 31 - 1:
            return int(min_value) + int(rng.randint(0, span + 1))
        # Wide ranges (e.g. 2**90): draw raw bytes, reduce mod span.
        return int(min_value) + int.from_bytes(rng.bytes(16),
                                               "little") % (span + 1)

    return Strategy(sample)


def sampled_from(elements):
    elements = list(elements)
    return Strategy(lambda rng: elements[rng.randint(0, len(elements))])


def booleans():
    return Strategy(lambda rng: bool(rng.randint(0, 2)))


def composite(fn):
    """@st.composite: ``fn(draw, *args)`` becomes a strategy factory."""

    def factory(*args, **kwargs):
        def sample(rng):
            return fn(lambda strat: strat._sample(rng), *args, **kwargs)

        return Strategy(sample)

    return factory


# ----------------------------------------------------------------------------
# settings
# ----------------------------------------------------------------------------

class settings:
    _profiles: dict = {}
    _active = None                   # set below to a default instance

    def __init__(self, max_examples=20, deadline=None, derandomize=True,
                 **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline
        self.derandomize = derandomize

    def __call__(self, fn):          # used as @settings(...) decorator
        fn._mh_settings = self
        return fn

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = cls(**kwargs)

    @classmethod
    def load_profile(cls, name):
        cls._active = cls._profiles[name]


settings._active = settings()


# ----------------------------------------------------------------------------
# given
# ----------------------------------------------------------------------------

def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        def wrapper():
            conf = (getattr(wrapper, "_mh_settings", None)
                    or getattr(fn, "_mh_settings", None)
                    or settings._active)
            n = conf.max_examples or 20
            name = f"{fn.__module__}.{getattr(fn, '__qualname__', fn.__name__)}"
            base = zlib.crc32(name.encode())
            for i in range(n):
                rng = np.random.RandomState((base + i) % (2 ** 32))
                args = [s._sample(rng) for s in arg_strategies]
                kwargs = {k: s._sample(rng)
                          for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: args={args!r} "
                        f"kwargs={kwargs!r}") from e

        # Copy identity by hand: functools.wraps would set __wrapped__ and
        # pytest would then see the original signature and demand fixtures
        # for every strategy parameter.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        if hasattr(fn, "pytestmark"):
            wrapper.pytestmark = fn.pytestmark
        return wrapper

    return decorate


def assume(condition):
    """Best-effort: abort the whole example loop is overkill for a shim;
    raise to surface impossible assumptions instead of silently passing."""
    if not condition:
        raise AssertionError("assume() condition failed under minihypothesis")


def install():
    """Register this module as ``hypothesis`` (+``.strategies``)."""
    mod = sys.modules[__name__]
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "composite"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    sys.modules.setdefault("hypothesis", mod)
    sys.modules.setdefault("hypothesis.strategies", strategies)
