"""Open-loop traffic: seeded determinism of the arrival processes, the
overload regime (bounded queue shed, per-class token buckets, priority
admission), the operator summary's schema, and the liveness property —
under continuous offered load every offered request reaches a terminal
outcome and every completed one actually emitted (no livelock between
admission holds, preemption, and chunked prefill aging)."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import Request, ServeConfig, ServingEngine, SLOClass
from repro.serve import traffic


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _tcfg(**kw):
    base = dict(rate=2.0, n_requests=40, seed=7, vocab=128,
                classes=(traffic.TrafficClass(
                    "default", prompt_lo=4, prompt_hi=24,
                    out_lo=2, out_hi=6),))
    base.update(kw)
    return traffic.TrafficConfig(**base)


def _scfg(**kw):
    base = dict(max_len=64, batch=2, eos_id=-1, paged=True, page_size=8,
                chunk_size=8)
    base.update(kw)
    return ServeConfig(**base)


# ----------------------------------------------------------------------------
# Generator: determinism and arrival-process shape (no model needed)
# ----------------------------------------------------------------------------

def test_generator_is_deterministic_per_seed():
    a = traffic.TrafficGenerator(_tcfg()).arrivals()
    b = traffic.TrafficGenerator(_tcfg()).arrivals()
    c = traffic.TrafficGenerator(_tcfg(seed=8)).arrivals()
    assert len(a) == len(b) == 40
    for x, y in zip(a, b):
        assert (x.tick, x.rid, x.rclass, x.max_new) == \
            (y.tick, y.rid, y.rclass, y.max_new)
        np.testing.assert_array_equal(x.prompt, y.prompt)
    assert any(x.tick != z.tick or x.prompt.shape != z.prompt.shape
               for x, z in zip(a, c))


def test_session_mode_shares_prefixes_without_perturbing_arrivals():
    """Session classes (returning users) draw shared per-session prefixes
    from a dedicated RNG stream: every arrival's head is one of the
    class's pooled prefixes, and the underlying arrival process (ticks,
    suffix tokens, output lengths) is bit-identical with sessions on or
    off — sessions only prepend, they never re-seed the main stream."""
    base = dict(prompt_lo=4, prompt_hi=12, out_lo=2, out_hi=4)
    off = traffic.TrafficGenerator(_tcfg(classes=(
        traffic.TrafficClass("chat", **base),))).arrivals()
    gen = traffic.TrafficGenerator(_tcfg(classes=(
        traffic.TrafficClass("chat", sessions=3, prefix_len=16,
                             **base),)))
    on = gen.arrivals()
    pool = gen._session_prefixes["chat"]
    assert pool.shape == (3, 16)
    seen = set()
    for a, b in zip(off, on):
        assert (a.tick, a.rid, a.max_new) == (b.tick, b.rid, b.max_new)
        head, tail = b.prompt[:16], b.prompt[16:]
        sids = [s for s in range(3) if (pool[s] == head).all()]
        assert sids, "arrival head is not a pooled session prefix"
        seen.update(sids)
        np.testing.assert_array_equal(tail, a.prompt)   # same main draw
    assert len(seen) >= 2                    # multiple sessions exercised


def test_session_mode_requires_both_knobs():
    with pytest.raises(AssertionError):
        traffic.TrafficClass("bad", sessions=2)
    with pytest.raises(AssertionError):
        traffic.TrafficClass("bad", prefix_len=8)


def test_poisson_arrivals_match_offered_rate():
    arr = traffic.TrafficGenerator(
        _tcfg(rate=4.0, n_requests=2000)).arrivals()
    ticks = [a.tick for a in arr]
    assert ticks == sorted(ticks)
    # 2000 exponential gaps at rate 4 -> span ~500 ticks (CLT: +-10%).
    span = max(ticks) - min(ticks)
    assert 0.8 * 500 < span < 1.2 * 500, span


def test_bursty_arrivals_cluster_beyond_poisson():
    """The MMPP's burst state must produce windows denser than the calm
    rate explains — that clustering is what trips admission control."""
    cfg = _tcfg(rate=1.0, n_requests=1000, process="bursty",
                burst_factor=8.0)
    arr = traffic.TrafficGenerator(cfg).arrivals()
    ticks = np.asarray([a.tick for a in arr])
    window = 20
    counts = [int(((ticks >= t) & (ticks < t + window)).sum())
              for t in range(0, int(ticks.max()), window)]
    # Calm Poisson at rate 1 puts ~20 in a window (p[>40] ~ 1e-5);
    # the burst state (rate 8) must blow through that repeatedly.
    assert max(counts) > 40, max(counts)
    # ... while calm stretches still exist (it's modulated, not just fast).
    assert min(counts[:-1]) < 15, counts


def test_lengths_and_classes_respect_the_mix():
    cls = (traffic.TrafficClass("hot", weight=3.0, prompt_lo=4,
                                prompt_hi=16, out_lo=2, out_hi=4),
           traffic.TrafficClass("cold", weight=1.0, prompt_lo=16,
                                prompt_hi=32, out_lo=4, out_hi=8))
    arr = traffic.TrafficGenerator(
        _tcfg(n_requests=400, classes=cls)).arrivals()
    by = {"hot": [], "cold": []}
    for a in arr:
        by[a.rclass].append(a)
        lo, hi = (4, 16) if a.rclass == "hot" else (16, 32)
        assert lo <= len(a.prompt) <= hi
        lo, hi = (2, 4) if a.rclass == "hot" else (4, 8)
        assert lo <= a.max_new <= hi
    # 3:1 mix (binomial n=400 p=0.75: +-5 sigma ~ 43).
    assert 250 <= len(by["hot"]) <= 350, len(by["hot"])


# ----------------------------------------------------------------------------
# Recorded-log format: JSONL round trip, session heads, record_to
# ----------------------------------------------------------------------------

def test_recorded_log_round_trips(tmp_path):
    """write_log -> replay_log -> write_log must be a fixed point: the
    replayed arrivals carry the same shape metadata (ticks, classes,
    lengths, budgets, session ids), same-session replays share their
    prompt heads, and re-recording the replay is bit-identical JSONL —
    so a recorded incident trace replays deterministically forever."""
    cls = (traffic.TrafficClass("chat", prompt_lo=4, prompt_hi=24,
                                out_lo=2, out_hi=6,
                                sessions=3, prefix_len=8),
           traffic.TrafficClass("batch", prompt_lo=8, prompt_hi=16,
                                out_lo=2, out_hi=4))
    arrivals = traffic.TrafficGenerator(
        _tcfg(n_requests=30, classes=cls)).arrivals()
    p1 = str(tmp_path / "trace.jsonl")
    traffic.write_log(p1, arrivals)
    replayed = traffic.replay_log(p1, vocab=128, seed=5, prefix_len=8)
    assert len(replayed) == len(arrivals)
    for a, b in zip(arrivals, replayed):
        assert (a.tick, a.rclass, len(a.prompt), a.max_new,
                a.session_id) == \
            (b.tick, b.rclass, len(b.prompt), b.max_new, b.session_id)
    # Same-session replays share the synthesized prefix head (the log
    # records no token content, only session identity).
    by_sid = {}
    for b in replayed:
        if b.session_id is not None:
            by_sid.setdefault(b.session_id, []).append(b)
    multi = [v for v in by_sid.values() if len(v) >= 2]
    assert multi, "no session produced two arrivals; widen the config"
    for grp in multi:
        for b in grp[1:]:
            np.testing.assert_array_equal(b.prompt[:8], grp[0].prompt[:8])
    # Fixed point: recording the replay reproduces the file bit-for-bit,
    # and replaying that file reproduces the prompts bit-for-bit.
    p2 = str(tmp_path / "trace2.jsonl")
    traffic.write_log(p2, replayed)
    assert open(p1).read() == open(p2).read()
    again = traffic.replay_log(p2, vocab=128, seed=5, prefix_len=8)
    for b, c in zip(replayed, again):
        np.testing.assert_array_equal(b.prompt, c.prompt)


def test_run_open_loop_record_to_captures_the_offered_trace(model,
                                                            tmp_path):
    cfg, params = model
    eng = ServingEngine(params, cfg, _scfg())
    arr = traffic.TrafficGenerator(_tcfg(n_requests=8)).arrivals()
    p_rec = str(tmp_path / "rec.jsonl")
    p_ref = str(tmp_path / "ref.jsonl")
    res = traffic.run_open_loop(eng, arr, max_ticks=2000,
                                record_to=p_rec)
    assert res["unresolved"] == []
    traffic.write_log(p_ref, arr)     # generator output is tick-sorted
    assert open(p_rec).read() == open(p_ref).read()


# ----------------------------------------------------------------------------
# Engine under offered load: shed accounting, buckets, priority
# ----------------------------------------------------------------------------

def test_overload_sheds_cleanly_and_summary_is_sane(model):
    """Offered load far past capacity: the bounded queue must shed with
    explicit per-request accounting (nothing unresolved, nothing
    silently dropped) and the operator summary's percentiles must be
    ordered."""
    cfg, params = model
    eng = ServingEngine(params, cfg, _scfg(
        batch=2, n_pages=17,
        classes=(SLOClass("default", ttft_slo=8, tpot_slo=4.0),),
        max_queue=4, max_preemptions=3))
    arr = traffic.TrafficGenerator(
        _tcfg(rate=3.0, n_requests=30)).arrivals()
    res = traffic.run_open_loop(eng, arr, max_ticks=2000)
    assert res["unresolved"] == []
    assert eng.shed_by_class.get("default", 0) >= 1     # overload bit
    for rid in res["rejected"]:
        assert eng.outcome[rid].startswith("rejected:")
    s = traffic.summarize(eng, arr)
    assert s["offered"] == 30
    assert s["done"] + s["forced"] + s["rejected"] == 30
    assert s["ttft_p99"] >= s["ttft_p50"] >= 0
    assert 0.0 <= s["shed_rate"] <= 1.0
    assert 0.0 <= s["ttft_slo_attainment"] <= 1.0
    assert s["goodput_tokens_per_tick"] > 0


def test_token_bucket_caps_a_classes_throughput(model):
    """A metered class's admitted token volume is bounded by its refill
    rate (plus one burst and one debit overshoot) no matter how much it
    offers — the other class's service is what the meter protects."""
    cfg, params = model
    rate = 1.0
    metered = SLOClass("metered", rate=rate, burst=8.0)
    free = SLOClass("free", priority=1)
    eng = ServingEngine(params, cfg, _scfg(
        batch=2, classes=(metered, free), max_queue=50))
    tcls = (traffic.TrafficClass("metered", weight=1.0, prompt_lo=8,
                                 prompt_hi=8, out_lo=4, out_hi=4),
            traffic.TrafficClass("free", weight=1.0, prompt_lo=8,
                                 prompt_hi=8, out_lo=4, out_hi=4))
    arr = traffic.TrafficGenerator(
        _tcfg(rate=4.0, n_requests=40, classes=tcls)).arrivals()
    traffic.run_open_loop(eng, arr, max_ticks=2000)
    admitted_tokens = sum(
        12 for a in arr if a.rclass == "metered"
        and not str(eng.outcome.get(a.rid, "")).startswith("rejected"))
    # Debit bucket: spend <= refill + cap + one oversized overshoot.
    assert admitted_tokens <= rate * eng.ticks + 8.0 + 12, \
        (admitted_tokens, eng.ticks)
    # The meter throttles (some metered requests waited or shed) while
    # the unmetered class rode through.
    done_free = sum(1 for a in arr if a.rclass == "free"
                    and eng.outcome.get(a.rid) == "done")
    assert done_free >= 10


def test_priority_classes_shed_low_first(model):
    """Under a bounded queue, overflow removes the lowest-priority
    newest request — the paying class keeps its completion rate."""
    cfg, params = model
    eng = ServingEngine(params, cfg, _scfg(
        batch=2,
        classes=(SLOClass("hi", priority=2), SLOClass("lo", priority=0)),
        max_queue=3, max_preemptions=3))
    tcls = (traffic.TrafficClass("hi", weight=1.0, prompt_lo=4,
                                 prompt_hi=12, out_lo=2, out_hi=4),
            traffic.TrafficClass("lo", weight=1.0, prompt_lo=4,
                                 prompt_hi=12, out_lo=2, out_hi=4))
    arr = traffic.TrafficGenerator(
        _tcfg(rate=4.0, n_requests=40, classes=tcls,
              process="bursty")).arrivals()
    res = traffic.run_open_loop(eng, arr, max_ticks=2000)
    assert res["unresolved"] == []
    shed = eng.shed_by_class
    assert shed.get("lo", 0) >= 1                 # overload actually shed
    assert shed.get("hi", 0) <= shed.get("lo", 0)
    s = traffic.summarize(eng, arr)
    hi, lo = s["by_class"]["hi"], s["by_class"]["lo"]
    assert hi["done"] / hi["offered"] >= lo["done"] / lo["offered"]


# ----------------------------------------------------------------------------
# Liveness property (satellite): continuous offered load, no livelock
# ----------------------------------------------------------------------------

@given(seed=st.integers(0, 1000), rate=st.sampled_from([1.0, 2.0, 4.0]),
       n_pages=st.sampled_from([17, 25]),
       process=st.sampled_from(["poisson", "bursty"]))
@settings(max_examples=4, deadline=None)
def test_every_offered_request_reaches_a_terminal_outcome(
        seed, rate, n_pages, process):
    """Property: under continuous offered load — any seed, rate, pool
    size, arrival shape — every offered request ends finished or
    cleanly rejected within the drain window (no hang, no livelock
    between admission holds, preemption, and chunked prefill aging),
    and every completed request actually emitted its first token."""
    cfg = configs.get_smoke("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, _scfg(
        batch=2, n_pages=n_pages,
        classes=(SLOClass("default"),), max_queue=6, max_preemptions=4))
    arr = traffic.TrafficGenerator(_tcfg(
        rate=rate, n_requests=16, seed=seed, process=process)).arrivals()
    res = traffic.run_open_loop(eng, arr, max_ticks=1500)
    assert res["unresolved"] == [], res["unresolved"]
    for a in arr:
        out = eng.outcome[a.rid]
        if out == "done":
            assert a.rid in eng.first_token_tick
            assert len(eng.finished[a.rid]) >= 1
        else:
            assert out.startswith("forced:") or out.startswith("rejected:")
    # The engine drained: no stranded pages, no occupied slots.
    assert eng.pool.pages_in_use == 0
    assert all(s is None for s in eng.slots)
