"""Property tests: the dissector recovers *randomized* ground-truth
geometries, not just the published V100 numbers."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dissect, hwmodel, pchase
from repro.core.simulator import (LatencyConfig, MemoryHierarchy,
                                  SetAssocCache, TLB)

KiB = 1024


def make_hier(l1_size=32 * KiB, l1_line=32, l1_sets=4, policy="lru",
              reserved=0, l2_size=512 * KiB, l2_line=64, l2_ways=16,
              tlb1=(16, 128 * KiB), tlb2=(64, 1024 * KiB),
              caches_enabled=True):
    return MemoryHierarchy(
        SetAssocCache(l1_size, l1_line, sets=l1_sets, policy=policy,
                      reserved_ways=reserved),
        SetAssocCache(l2_size, l2_line, ways=l2_ways, policy="lru"),
        TLB(tlb1[0] * tlb1[1], tlb1[1]),
        TLB(tlb2[0] * tlb2[1], tlb2[1]),
        LatencyConfig(),
        caches_enabled=caches_enabled)


@given(size_kib=st.sampled_from([8, 16, 24, 32, 64]),
       line=st.sampled_from([32, 64, 128]),
       sets=st.sampled_from([2, 4, 8]))
@settings(max_examples=12)
def test_recover_random_l1_geometry(size_kib, line, sets):
    hier = make_hier(l1_size=size_kib * KiB, l1_line=line, l1_sets=sets,
                     l2_size=4096 * KiB)
    size = pchase.detect_size(hier, lo=2 * KiB, hi=256 * KiB, stride=8)
    assert size == size_kib * KiB
    got_line = pchase.detect_line(hier, size)
    assert got_line == line
    # L1-miss threshold probed by thrashing L1 (same recipe as dissect_l1 —
    # the cold-scan L2 class is invisible when L1 and L2 share a line size).
    l2_hit = pchase.measure_next_level_latency(hier, size)
    ways = pchase.detect_ways(hier, size, miss_threshold=l2_hit,
                              max_ways=2048)
    assert size // (got_line * ways) == sets


@given(reserved=st.sampled_from([4, 16, 56]))
@settings(max_examples=6)
def test_recover_prio_policy(reserved):
    nominal = 32 * KiB
    hier = make_hier(l1_size=nominal, policy="prio", reserved=reserved)
    # threshold=0: the simulator is deterministic, so a single second-scan
    # miss marks overflow; resolution below the stride pins the boundary.
    size = pchase.detect_size(hier, lo=2 * KiB, hi=256 * KiB, stride=8,
                              resolution=8, threshold=0.0)
    expect = nominal - reserved * 4 * 32
    assert abs(size - expect) < 8
    if reserved >= 16:
        # The size-deficit policy test needs the reserved region to exceed
        # its 3% sensitivity (the paper's V100 case is ~5-22% short).
        assert pchase.detect_policy(size, nominal) == "non-LRU"


def test_lru_policy_detected():
    hier = make_hier()
    size = pchase.detect_size(hier, lo=2 * KiB, hi=256 * KiB, stride=8)
    assert pchase.detect_policy(size, 32 * KiB) == "LRU"


@given(entries1=st.sampled_from([8, 16, 32]),
       page1_kib=st.sampled_from([128, 256]),
       entries2=st.sampled_from([64, 128]))
@settings(max_examples=8)
def test_recover_random_tlbs(entries1, page1_kib, entries2):
    page2 = 8 * page1_kib * KiB
    hier = make_hier(tlb1=(entries1, page1_kib * KiB),
                     tlb2=(entries2, page2), caches_enabled=False)
    tlbs = pchase.dissect_tlbs(
        hier,
        page_candidates_l1=[32 * KiB, 64 * KiB, 128 * KiB, 256 * KiB,
                            512 * KiB],
        page_candidates_l2=[page1_kib * KiB * m for m in (1, 2, 4, 8, 16)],
        max_pages=300)
    assert tlbs[0].page_entry == page1_kib * KiB
    assert tlbs[0].coverage == entries1 * page1_kib * KiB
    assert tlbs[1].page_entry == page2
    assert tlbs[1].coverage == entries2 * page2


def test_v100_full_dissection_matches_paper():
    rep = dissect.dissect(hwmodel.V100)
    assert all(rep.matches.values()), rep.matches


@pytest.mark.parametrize("gpu", ["P100", "M60", "K80"])
def test_other_gpus_dissect(gpu):
    rep = dissect.dissect(hwmodel.GPUS[gpu], include_tlb=False)
    bad = {k: v for k, v in rep.matches.items() if not v}
    assert not bad, bad


def test_table_3_3_reproduction():
    got = {k: v // KiB for k, v in dissect.table_3_3().items()}
    assert got == {0: 121, 64: 57, 96: 25}    # paper Table 3.3
