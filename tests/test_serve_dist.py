"""Distributed paged serving: the engine sharded over a mesh must be a
*bit-identical* re-plumbing of the single-device engine — same token
streams across greedy, sampled, preemption and spec-decode paths, with
weights tensor-parallel, the KV page pool device-sharded (pages as the
shard unit, so one slot's context spans devices), and still exactly one
decode/verify executable per mesh. The 8-device checks run in one
subprocess (``--xla_force_host_platform_device_count=8``); the allocator
property tests, the adaptive spec-k regression, and the mesh-keyed tuning
cache tests are host-side and fast. check.sh gates this file in the
serving subset."""

import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.core import autotune, roofline
from repro.serve import paged, spec


# ----------------------------------------------------------------------------
# Device-sharded PageAllocator (host-side: no jax, no mesh needed)
# ----------------------------------------------------------------------------

def test_single_device_allocation_order_unchanged():
    """D=1 must allocate 1, 2, 3, ... exactly as the pre-mesh allocator:
    the device-sharded pool is a superset, not a behavior change."""
    pool = paged.PageAllocator(n_pages=8, page_size=4)
    got = pool.alloc(0, 7)
    assert got == [1, 2, 3, 4, 5, 6, 7]
    assert pool.capacity == 7
    assert pool.device_occupancy() == [7]


def test_capacity_is_mesh_invariant():
    """Same n_pages -> same capacity on any device count (one global null
    page, not one per device) — the 1-vs-8 parity the bench cell pins."""
    for d in (1, 2, 4, 8):
        pool = paged.PageAllocator(n_pages=16, page_size=4, n_devices=d)
        assert pool.capacity == 15, d


@given(d=st.sampled_from([2, 4, 8]), seed=st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_sharded_allocator_churn_invariants(d, seed):
    """Property: under admit/free churn, (a) no (device, local_page) pair
    is ever live twice, (b) per-device occupancy sums to the global count,
    (c) the null page is never handed out, (d) freed pages return to their
    home device's free list (devices never leak capacity)."""
    rng = np.random.RandomState(seed)
    pool = paged.PageAllocator(n_pages=8 * d, page_size=4, n_devices=d)
    live = {}
    for step in range(120):
        rid = int(rng.randint(0, 6))
        if rng.rand() < 0.6 and pool.free_pages:
            n = int(rng.randint(1, min(4, pool.free_pages) + 1))
            for p in pool.alloc(rid, n):
                assert p != paged.NULL_PAGE
                key = (pool.device_of(p), pool.local_of(p))
                assert key not in live, "double allocation of " + str(key)
                assert 0 <= key[1] < pool.block
                live[key] = rid
        elif rid in pool.slot_pages:
            for p in pool.slot_pages[rid]:
                del live[(pool.device_of(p), pool.local_of(p))]
            pool.free_slot(rid)
        occ = pool.device_occupancy()
        assert sum(occ) == len(live) == \
            sum(len(v) for v in pool.slot_pages.values())
        for dev in range(d):
            assert occ[dev] == sum(1 for (pd, _) in live if pd == dev)
    assert pool.free_pages == pool.capacity - len(live)


def test_occupancy_reports_per_device_counts():
    pool = paged.PageAllocator(n_pages=8, page_size=2, n_devices=4)
    pool.alloc(0, 5)
    occ = pool.occupancy()
    assert occ["capacity"] == 7 and occ["n_devices"] == 4
    assert sum(occ["pages_in_use_by_device"]) == occ["pages_in_use"] == 5
    # Least-loaded placement spreads pages across every device.
    assert all(c >= 1 for c in occ["pages_in_use_by_device"])


# ----------------------------------------------------------------------------
# Tuning cache keyed by backend AND mesh shape (satellite: two writes,
# two entries — single- and multi-device runs must not clobber each other)
# ----------------------------------------------------------------------------

def test_tuning_cache_keyed_by_mesh_shape(tmp_path, monkeypatch):
    monkeypatch.setattr(autotune, "TUNING_CACHE_PATH",
                        str(tmp_path / "cache.json"))
    # The in-memory memo outlives earlier tests in the same process;
    # reset it so this test sees only its own two writes (monkeypatch
    # restores the shared memo afterwards).
    monkeypatch.setattr(autotune, "_tuning_cache", None)
    p = autotune.AttnProblem(sq=128, skv=512, n_heads=4, head_dim=64,
                             causal=True, in_bytes=2)
    b1, _ = autotune.choose_attn_block(p, mesh_shape="dev1")
    b8, _ = autotune.choose_attn_block(p, mesh_shape={"model": 8})
    cache = autotune._load_tuning_cache()
    assert len(cache) == 2, list(cache)
    keys = sorted(cache)
    assert any(":dev1:" in k for k in keys), keys
    assert any(":mesh(model=8):" in k for k in keys), keys
    # Same problem, same backend: only the mesh component differs.
    assert {k.split(":", 2)[2] for k in keys} == \
        {keys[0].split(":", 2)[2]}
    # Both entries hit on re-lookup (no clobbering).
    assert autotune.choose_attn_block(p, mesh_shape="dev1")[0] == b1
    assert autotune.choose_attn_block(p, mesh_shape={"model": 8})[0] == b8


def test_default_mesh_key_is_device_count():
    import jax
    # check.sh runs this file with 8 forced host devices; bare pytest
    # sees 1 — either way the default key is the visible device count.
    assert autotune._mesh_key() == f"dev{jax.device_count()}"
    assert autotune._mesh_key("dev8") == "dev8"
    assert autotune._mesh_key((2, 4)) == "mesh(2,4)"


# ----------------------------------------------------------------------------
# TP cost models (collective terms in decode/chunk/spec models)
# ----------------------------------------------------------------------------

def test_tp_decode_model_shards_weight_stream():
    terms = autotune.tp_decode_model(
        [4096] * 8, n_heads=32, n_kv_heads=8, head_dim=128, page_size=64,
        param_bytes=8e9, d_model=4096, n_layers=36, n_devices=8)
    assert terms["weight_stream_tp_s"] * 8 == \
        pytest.approx(terms["weight_stream_1dev_s"])
    assert terms["speedup"] > 1.0          # decode is weight-stream bound
    assert terms["collective_s"] > 0.0
    assert terms["pool_capacity_ratio"] == 8.0
    assert terms["attn_sharded"]


def test_tp_collective_terms_price_in_models():
    """The chunk/spec/decode models all surface a nonzero collective term
    under tp and reduce to their exact single-device selves without it."""
    tp = autotune.TPServe(n_devices=8, d_model=4096, n_layers=36)
    c0 = autotune.prefill_chunk_model(2048, 256, 32, 8, 128, 64)
    c8 = autotune.prefill_chunk_model(2048, 256, 32, 8, 128, 64, tp=tp)
    assert c0["collective_s"] == 0.0 and c8["collective_s"] > 0.0
    d0 = autotune.paged_decode_model(4096, [1000, 2000], 32, 8, 128, 64)
    d8 = autotune.paged_decode_model(4096, [1000, 2000], 32, 8, 128, 64,
                                     tp=tp)
    assert d0["collective_s"] == 0.0 and d8["collective_s"] > 0.0
    s8 = autotune.spec_decode_model([2048] * 4, 32, 8, 128, 64, k=4,
                                    accept_rate=0.8, param_bytes=8e9,
                                    tp=tp)
    s0 = autotune.spec_decode_model([2048] * 4, 32, 8, 128, 64, k=4,
                                    accept_rate=0.8, param_bytes=8e9)
    assert s8["weight_stream_s"] * 8 == pytest.approx(s0["weight_stream_s"])


def test_collective_matmul_roofline_prices_rs_vs_ar():
    """rs_matmul's ring moves half the all-reduce baseline's wire bytes
    and the ag variants differ only in overlap, not bytes."""
    t = roofline.collective_matmul_terms(256, 4096, 8192, 8)
    assert t["rs_ring"].collective_bytes * 2 == \
        pytest.approx(t["all_reduce"].collective_bytes)
    assert t["ag_ring"].collective_bytes == t["all_gather"].collective_bytes
    for v in t.values():
        assert v.step_time_overlapped_s <= v.step_time_s


# ----------------------------------------------------------------------------
# Adaptive spec-k: measured accept rate feeds back into choose_spec_k
# ----------------------------------------------------------------------------

def _spec_engine(cfg, params, prompt, ref, pattern, adapt_every):
    from repro.serve.engine import Request, ServeConfig, ServingEngine
    draft = spec.ScriptedDraft(len(prompt), ref, pattern, cfg.vocab)
    eng = ServingEngine(params, cfg,
                        ServeConfig(max_len=64, batch=2, eos_id=-1,
                                    paged=True, page_size=8, chunk_size=8,
                                    spec_k=2, draft=draft,
                                    spec_adapt_every=adapt_every))
    eng.submit(Request(rid=0, prompt=np.asarray(prompt, np.int32),
                       max_new=len(ref)))
    return eng


@pytest.fixture(scope="module")
def model():
    import jax
    from repro.models import transformer as T
    cfg = configs.get_smoke("qwen3-4b")
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


def test_collapsing_accept_rate_disables_speculation(model):
    """Regression (satellite): an always-rejected draft drives the
    measured accept rate to zero, and the runtime re-choice pushes
    ``k_live`` into the disable regime (0 = plain decode ticks) — while
    the emitted stream stays exactly the reference."""
    import jax.numpy as jnp
    from repro.serve.engine import greedy_generate
    cfg, params = model
    prompt = list(range(3, 11))
    ref = np.asarray(greedy_generate(
        params, cfg, jnp.asarray(prompt)[None], 12, max_len=64)[0]).tolist()
    eng = _spec_engine(cfg, params, prompt, ref, [0], adapt_every=2)
    out = eng.run_until_drained()
    assert out[0] == ref
    assert eng.k_live == 0, "zero accept rate must disable speculation"
    assert eng.spec_ticks < 12, "later ticks must be plain decode"


def test_healthy_accept_rate_keeps_speculation_live(model):
    import jax.numpy as jnp
    from repro.serve.engine import greedy_generate
    cfg, params = model
    prompt = list(range(5, 12))
    ref = np.asarray(greedy_generate(
        params, cfg, jnp.asarray(prompt)[None], 12, max_len=64)[0]).tolist()
    eng = _spec_engine(cfg, params, prompt, ref, [1], adapt_every=3)
    out = eng.run_until_drained()
    assert out[0] == ref
    assert eng.k_live >= 1, "perfect drafts must keep speculation on"


def test_rechoose_k_tracks_accept_rate():
    cfg = configs.get_smoke("qwen3-4b")
    k_lo, _ = spec.rechoose_k(cfg, 4, [16, 20], 0.0, 2)
    k_hi, _ = spec.rechoose_k(cfg, 4, [16, 20], 1.0, 2)
    assert k_lo == 0 and 1 <= k_hi <= 2


# ----------------------------------------------------------------------------
# 8-device subprocess: parity oracle + rs_matmul + sharded-pool engine
# ----------------------------------------------------------------------------

MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch import mesh as mesh_lib
from repro.dist import collective_matmul as cm
from repro.models import transformer as T
from repro.serve.engine import Request, ServeConfig, ServingEngine
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

assert jax.device_count() == 8
results = {}

# 1. rs_matmul == ag_matmul == x @ w == explicit all-reduce, and the ring
#    compiles to collective-permutes (no entry-computation all-reduce).
mesh = mesh_lib.make_mesh((8,), ("model",))
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(16, 64), jnp.float32)
w = jnp.asarray(rng.randn(64, 128), jnp.float32)
ref = np.asarray(x @ w)

def ar_matmul(x, w):   # the naive row-parallel baseline rs_matmul halves
    kb = x.shape[1] // 8
    def body(xb, wf):
        i = jax.lax.axis_index("model")
        wb = jax.lax.dynamic_slice_in_dim(wf, i * kb, kb, axis=0)
        return jax.lax.psum(xb @ wb, "model")
    return shard_map(body, mesh=mesh, in_specs=(P(None, "model"),
                     P(None, None)), out_specs=P(None, None),
                     check_rep=False)(x, w)

for name, fn in (("rs", lambda: cm.rs_matmul(x, w, mesh, "model")),
                 ("ag", lambda: cm.ag_matmul(x, w, mesh, "model")),
                 ("ar", lambda: ar_matmul(x, w))):
    np.testing.assert_allclose(np.asarray(fn()), ref, rtol=1e-4, atol=1e-4)
hlo = jax.jit(lambda x, w: cm.rs_matmul(x, w, mesh, "model")).lower(
    x, w).compile().as_text()
assert "collective-permute" in hlo
assert "all-reduce" not in hlo.split("ENTRY")[-1], \
    "psum-scatter ring should replace the big all-reduce"
# Non-divisible n falls back to the plain matmul.
w_odd = jnp.asarray(rng.randn(64, 130), jnp.float32)
np.testing.assert_allclose(np.asarray(cm.rs_matmul(x, w_odd, mesh,
                           "model")), np.asarray(x @ w_odd), rtol=1e-4)
results["rs_matmul"] = "ok"

# 2. Engine parity oracle: greedy / sampled / preemption / spec streams on
#    the 8-device engine must be bit-identical to the single-device paged
#    engine, with >= one slot's page table spanning >= 2 devices and
#    exactly one decode (and verify) executable per mesh.
cfg = configs.get_smoke("qwen3-4b")
params = T.init_params(jax.random.PRNGKey(0), cfg)
prng = np.random.RandomState(1)
prompts = [prng.randint(2, cfg.vocab, n).astype(np.int32)
           for n in (9, 13, 6, 11)]

def run(scfg_kw, n_req, max_new, mesh=None, watch_span=False):
    eng = ServingEngine(params, cfg, ServeConfig(**scfg_kw), mesh=mesh)
    for i in range(n_req):
        eng.submit(Request(rid=i, prompt=prompts[i].copy(),
                           max_new=max_new))
    spans = {}
    if watch_span:
        orig = eng.tick
        def tick():
            n = orig()
            for rid, pages in eng.pool.slot_pages.items():
                devs = {eng.pool.device_of(p) for p in pages}
                spans[rid] = spans.get(rid, set()) | devs
            return n
        eng.tick = tick
    out = {k: list(v) for k, v in eng.run_until_drained().items()}
    return out, eng, spans

greedy = dict(max_len=64, batch=3, eos_id=-1, paged=True, page_size=4,
              chunk_size=8, n_pages=56)
g1, _, _ = run(greedy, 3, 12)
g8, e8, spans = run(greedy, 3, 12, mesh=mesh, watch_span=True)
assert g1 == g8, (g1, g8)
assert any(len(v) >= 2 for v in spans.values()), spans
assert e8.decode_traces == 1, e8.decode_traces
assert e8.pool.n_devices == 8
# Same n_pages -> same capacity as the 1-device pool (global null page).
assert e8.pool.capacity == ServingEngine(
    params, cfg, ServeConfig(**greedy)).pool.capacity
results["greedy"] = "ok"

sampled = dict(greedy, temperature=0.9, seed=5)
s1, _, _ = run(sampled, 3, 8)
s8, _, _ = run(sampled, 3, 8, mesh=mesh)
assert s1 == s8, (s1, s8)
results["sampled"] = "ok"

tiny = dict(max_len=64, batch=4, eos_id=-1, paged=True, page_size=4,
            chunk_size=8, n_pages=16)
p1, ep1, _ = run(tiny, 4, 10)
p8, ep8, _ = run(tiny, 4, 10, mesh=mesh)
assert p1 == p8, (p1, p8)
assert ep8.preemptions > 0 and ep1.preemptions == ep8.preemptions
results["preempt"] = "ok"

spec_kw = dict(max_len=64, batch=3, eos_id=-1, paged=True, page_size=4,
               chunk_size=8, spec_k=2, draft="ngram")
k1, _, _ = run(spec_kw, 3, 10)
k8, ev8, _ = run(spec_kw, 3, 10, mesh=mesh)
assert k1 == k8, (k1, k8)
assert ev8.verify_traces == 1, ev8.verify_traces
results["spec"] = "ok"

print("MULTIDEV_RESULTS:" + ",".join(f"{k}={v}"
                                     for k, v in results.items()))
"""


@pytest.mark.slow
def test_multidevice_serving_parity(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = tmp_path / "serve_dist.py"
    script.write_text(MULTIDEV_SCRIPT)
    proc = subprocess.run([sys.executable, str(script), src],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    for part in ("rs_matmul", "greedy", "sampled", "preempt", "spec"):
        assert f"{part}=ok" in proc.stdout, proc.stdout
