"""Layer-level unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers


def test_rmsnorm_scale_invariance():
    p = layers.rmsnorm_init(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 16))
    y1 = layers.rmsnorm(p, x)
    y2 = layers.rmsnorm(p, 7.0 * x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def test_layernorm_zero_mean_unit_var():
    p = layers.layernorm_init(64)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64)) * 5 + 3
    y = np.asarray(layers.layernorm(p, x))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 6, 2, 8))
    pos = jnp.arange(6)[None]
    y = layers.rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # Relative property: <rope(q,i), rope(k,j)> depends only on i-j.
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 8))

    def dot_at(i, j):
        qi = layers.rope(q, jnp.array([[i]]))
        kj = layers.rope(k, jnp.array([[j]]))
        return float(jnp.sum(qi * kj))

    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)


def test_gqa_equals_mha_when_kv_heads_match():
    cfg_g = layers.AttnConfig(32, 4, 4, 8)
    p = layers.attention_init(jax.random.PRNGKey(5), cfg_g)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 10, 32))
    y_g, _ = layers.attention_apply(p, cfg_g, x)
    # sdpa with group=1 must equal plain attention math.
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = layers.rope(q, jnp.arange(10)[None])
    k = layers.rope(k, jnp.arange(10)[None])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(8)
    mask = layers.causal_mask(10)
    pr = jax.nn.softmax(s + mask, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, v)
    y_ref = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_causal_mask_blocks_future():
    cfg = layers.AttnConfig(16, 2, 2, 8)
    p = layers.attention_init(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 8, 16))
    y1, _ = layers.attention_apply(p, cfg, x)
    x2 = x.at[:, -1].set(99.0)       # mutate the future
    y2, _ = layers.attention_apply(p, cfg, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]),
                               np.asarray(y2[:, :-1]), rtol=1e-4, atol=1e-4)


def test_attention_per_slot_cache_positions():
    cfg = layers.AttnConfig(16, 2, 2, 8)
    p = layers.attention_init(jax.random.PRNGKey(9), cfg)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 1, 16))
    cache = {"k": jnp.zeros((2, 8, 2, 8)), "v": jnp.zeros((2, 8, 2, 8)),
             "index": jnp.array([0, 3], jnp.int32)}
    _, new = layers.attention_apply(p, cfg, x, cache=cache)
    k = np.asarray(new["k"])
    assert np.abs(k[0, 0]).sum() > 0 and np.abs(k[0, 3]).sum() == 0
    assert np.abs(k[1, 3]).sum() > 0 and np.abs(k[1, 0]).sum() == 0
    np.testing.assert_array_equal(np.asarray(new["index"]), [1, 4])


def test_cross_attention_gate_starts_closed():
    cfg = layers.AttnConfig(16, 2, 2, 8, causal=False)
    p = layers.cross_attention_init(jax.random.PRNGKey(11), cfg)
    x = jax.random.normal(jax.random.PRNGKey(12), (1, 4, 16))
    kv = jax.random.normal(jax.random.PRNGKey(13), (1, 6, 16))
    y = layers.cross_attention_apply(p, cfg, x, kv)
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-7)  # tanh(0)=0


@given(act=st.sampled_from(["swiglu", "gelu"]))
@settings(max_examples=4, deadline=None)
def test_mlp_shapes(act):
    cfg = layers.MLPConfig(16, 32, act)
    p = layers.mlp_init(jax.random.PRNGKey(14), cfg)
    x = jax.random.normal(jax.random.PRNGKey(15), (2, 3, 16))
    y = layers.mlp_apply(p, cfg, x)
    assert y.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(y)))
