"""Warp scheduler model (Table 2.1) + atomics contention model (Table 4.2)."""

import numpy as np

from repro.core import atomics, hwmodel, scheduler


def test_scheduler_mapping():
    assert [scheduler.scheduler_id(w) for w in range(8)] == [0, 1, 2, 3] * 2


def test_table_2_1_same_vs_different_block():
    t = scheduler.table_2_1()
    for (a, b), measured in scheduler.PAPER_TABLE_2_1.items():
        modeled = t[(a, b)]
        # Same block pairs ~42-44, split pairs ~66.
        assert abs(modeled - measured) / measured < 0.06, ((a, b), modeled)


def test_min_threads_to_saturate():
    assert scheduler.min_threads_to_saturate() == 128    # paper §2.2


def test_atomics_fit_quality():
    for gpu in ("V100", "P100", "M60"):
        spec = hwmodel.GPUS[gpu]
        res = atomics.model_residuals(spec, "shared")
        errs = [abs(m - p) / p for p, m in res.values()]
        assert np.mean(errs) < 0.45, (gpu, res)


def test_kepler_emulated_atomics_blow_up():
    # The paper: Kepler's lock-based shared atomics degrade ~linearly x2/level.
    k = hwmodel.K80.atomic_latency
    assert k[32][0] / k[1][0] > 40
    v = hwmodel.V100.atomic_latency
    assert v[32][0] / v[1][0] < 15


def test_throughput_scenarios_ordering():
    v = hwmodel.V100
    s1 = atomics.throughput_scenario(v, 1)
    s4 = atomics.throughput_scenario(v, 4)
    assert s4 > s1        # no-contention multi-SM is the best case (paper)
