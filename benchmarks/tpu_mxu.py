"""TPU-side MXU dissection: alignment cliffs + microbench-informed GEMM
tiling (the Ch.1 analogue on the target hardware)."""
from repro.core import autotune

def run():
    rows = []
    cliffs = {d: autotune.mxu_efficiency(256, d, 256)
              for d in (128, 129, 192, 255, 256)}
    rows.append(("alignment_cliff",
                 ";".join(f"k={d}:eff={e:.2f}" for d, e in cliffs.items())))
    for m, k, n in ((8192, 4096, 4096), (1024, 1024, 151936),
                    (65536, 896, 4864)):
        gain = autotune.tuning_gain(autotune.GemmProblem(m=m, k=k, n=n))
        rows.append((f"gemm_{m}x{k}x{n}",
                     f"naive={gain['naive']['time_s']*1e3:.3f}ms;"
                     f"tuned={gain['tuned']['time_s']*1e3:.3f}ms;"
                     f"block={gain['tuned']['config']};"
                     f"speedup={gain['speedup']:.2f}x"))
    return rows
