"""End-to-end roofline summary over the dry-run baseline artifact."""
import json
import os

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "dryrun_baseline.json")

def run():
    if not os.path.exists(ARTIFACT):
        return "missing: run `python -m repro.launch.dryrun --mesh both --out benchmarks/artifacts/dryrun_baseline.json`"
    cells = [c for c in json.load(open(ARTIFACT))
             if c["ok"] and not c["skipped"]]
    rows = []
    ranked = sorted(cells, key=lambda c: -c["roofline"]["roofline_fraction"])
    best, worst = ranked[0], ranked[-1]
    rows.append(("cells", f"n={len(cells)};all_compiled=True"))
    rows.append(("best", f"{best['arch']}x{best['shape']}@{best['mesh']}:"
                 f"frac={best['roofline']['roofline_fraction']:.3f}"))
    rows.append(("worst", f"{worst['arch']}x{worst['shape']}@{worst['mesh']}:"
                 f"frac={worst['roofline']['roofline_fraction']:.4f}"))
    coll = sorted(cells, key=lambda c: -c["roofline"]["collective_s"])
    c0 = coll[0]
    rows.append(("most_collective_bound",
                 f"{c0['arch']}x{c0['shape']}@{c0['mesh']}:"
                 f"coll_s={c0['roofline']['collective_s']:.3e}"))
    dom = {}
    for c in cells:
        dom[c["roofline"]["dominant"]] = dom.get(c["roofline"]["dominant"], 0) + 1
    rows.append(("dominant_census", ";".join(f"{k}={v}" for k, v in
                                             sorted(dom.items()))))
    return rows
