"""Fig 3.2: fine-grained p-chase latency classes (28/193/375/1029)."""
import numpy as np
from repro.core import hwmodel, pchase, simulator

def run():
    hier = simulator.build_hierarchy(hwmodel.V100)
    c = pchase.latency_classes(hier, span=64 * 1024)
    hier.flush()
    lat = hier.scan(np.arange(0, 512, 8))
    # One latency per 32B line start: cold, L2-hit, dram, L2-hit, ...
    starts = [int(lat[i]) for i in (0, 4, 8, 12, 16, 20)]
    return (f"l1_hit={c.l1_hit}(28);l2_hit={c.l2_hit}(193);"
            f"dram={c.dram}(375);cold={c.cold}(1029);"
            f"line_start_pattern={starts}")
