"""Fig 3.8: register-bank conflict sweep FFMA R6, R97, R99, RX."""
from repro.core import hwmodel, regbank

def run():
    rf = hwmodel.V100.regfile
    probe3 = lambda srcs: regbank.ffma_probe(rf, srcs)
    lat = regbank.conflict_sweep(probe3, (97, 99), range(8, 24))
    pattern = "".join("C" if l > min(lat) else "." for l in lat)
    banks, width = regbank.dissect_register_banks(probe3, probe3)
    return (f"rx8..23={pattern};dissected={banks}banks x{width}bit"
            f"(paper 2x64)")
