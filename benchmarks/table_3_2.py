"""Table 3.2: L1 load throughput per SM (measured vs theoretical)."""
from repro.core import hwmodel

def run():
    rows = []
    for name in ("V100", "P100", "P4", "M60"):
        s = hwmodel.GPUS[name]
        if s.l1_bw_bytes_per_cycle:
            rows.append((name, f"measured={s.l1_bw_bytes_per_cycle}B/cyc;"
                         f"upper={s.l1_bw_upper_bytes_per_cycle}B/cyc;"
                         f"ratio={s.l1_bw_bytes_per_cycle/s.l1_bw_upper_bytes_per_cycle:.2f}"))
    return rows
