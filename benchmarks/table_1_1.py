"""Ch.1 / Table 1.1: register-mapping optimization (+15.4% measured)."""
from repro.core import hwmodel, regbank, regremap

def run():
    rf = hwmodel.V100.regfile
    nvcc = regbank.parse_listing(regbank.NVCC_LISTING)
    opt = regbank.parse_listing(regbank.IMPROVED_LISTING)
    ours = regremap.remap_tile(rf, regbank.A_REGS, regbank.B_REGS,
                               list(range(16, 80)))
    g_nvcc = regbank.gflops_per_sm(rf, nvcc, 1380.0)
    g_opt = regbank.gflops_per_sm(rf, opt, 1380.0)
    g_ours = regbank.gflops_per_sm(rf, ours, 1380.0)
    _, s_n = regbank.instruction_cycles(rf, nvcc, "next")
    _, s_o = regbank.instruction_cycles(rf, opt, "next")
    _, s_u = regbank.instruction_cycles(rf, ours, "next")
    return (f"nvcc={g_nvcc:.2f}GF(paper 132.05);stalls={s_n};"
            f"paper_opt={g_opt:.2f}GF(paper 152.43);stalls={s_o};"
            f"our_remap={g_ours:.2f}GF;stalls={s_u};"
            f"modeled_gain={g_opt/g_nvcc-1:+.1%}(paper +15.4%)")
