"""Table 3.4: L2 load throughput across GPU generations."""
from repro.core import hwmodel

def run():
    rows = []
    for name in ("V100", "P100", "P4", "M60", "K80"):
        s = hwmodel.GPUS[name]
        if s.l2_bw_gbs:
            rows.append((name, f"l2_bw={s.l2_bw_gbs}GB/s"))
    v, p = hwmodel.V100.l2_bw_gbs, hwmodel.P100.l2_bw_gbs
    rows.append(("volta_vs_pascal", f"speedup={v/p:.2f}x"))
    return rows
