"""§4.3 / Figs 4.2-4.7: HMMA fragment maps + 4-set/4-step emulation."""
import numpy as np
from repro.core import tensorcore as tc

def run():
    rng = np.random.RandomState(0)
    a = rng.randint(-3, 4, (16, 16)).astype(np.float16)
    b = rng.randint(-3, 4, (16, 16)).astype(np.float16)
    c = np.zeros((16, 16), np.float32)
    exact = np.array_equal(tc.emulate_mma_sync(a, b, c),
                           a.astype(np.float32) @ b.astype(np.float32))
    la = set(tc.loads_per_thread("A").tolist())
    return (f"emulation_exact={exact};loads/thread A={la}(paper 16);"
            f"A(0,0)->{tc.a_fragment_threads(0,0)};"
            f"B(0,4)->{tc.b_fragment_threads(0,4)};"
            f"C(15,15)->t{tc.c_fragment_thread(15,15)}")
