"""Benchmark harness: one module per paper table/figure (+ TPU-side benches).

Prints ``name,us_per_call,derived`` CSV. Each module exposes
``run() -> str | list[(subname, str)]`` returning the derived metric(s).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only table_1_1 fig_3_8
  PYTHONPATH=src python -m benchmarks.run --fast     # skip the slow sweeps
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    # paper reproductions (ch.1 - ch.5)
    "table_1_1",        # FFMA register remapping (+15.4%)
    "table_2_1",        # warp scheduler mapping
    "table_3_1",        # memory hierarchy dissection (all 5 GPUs)  [slow]
    "fig_3_2",          # global latency classes
    "table_3_2",        # L1 bandwidth
    "fig_3_3",          # instruction cache hierarchy
    "table_3_4",        # L2 bandwidth
    "fig_3_7",          # constant cache broadcast
    "fig_3_8",          # register bank conflicts
    "fig_3_9",          # shared memory latency/bandwidth
    "fig_3_11",         # global memory bandwidth
    "fig_3_12",         # TLB sweep
    "table_4_1",        # instruction latencies
    "table_4_2",        # atomics under contention
    "fig_4_3",          # tensor core HMMA fragment maps
    "fig_4_8",          # floating-point throughput
    "table_5_1",        # interconnect p2p
    # TPU-side (the framework's own microbenchmarks)
    "tpu_mxu",          # MXU alignment cliffs + autotuned GEMM blocks
    "tpu_vmem",         # VMEM working-set budget + host p-chase demo
    "tpu_collectives",  # ICI alpha-beta curves over a real mesh  [slow]
    "tpu_e2e",          # roofline summary of the dry-run cells
    "tpu_serving",      # engine tokens/sec + modeled flash-decode speedup
    "breaking_point",   # load sweep + faults + telemetry overhead/drift
]

SLOW = {"table_3_1", "tpu_collectives"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    names = args.only if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        if args.fast and name in SLOW:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            t0 = time.perf_counter()
            out = mod.run()
            us = (time.perf_counter() - t0) * 1e6
            rows = out if isinstance(out, list) else [("", out)]
            for sub, derived in rows:
                full = f"{name}.{sub}" if sub else name
                print(f"{full},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
