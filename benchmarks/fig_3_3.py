"""Figs 3.3-3.5: instruction-cache hierarchy via inverse-throughput plateaus.

Simulates an FFMA stream of growing footprint through the modeled
L0(12KiB)/L1(128KiB)/L2 icache hierarchy and detects the plateau ends, the
paper's methodology for discovering icache sizes."""
import numpy as np
from repro.core.simulator import SetAssocCache

KiB = 1024
INSTR_BYTES = 16    # 128-bit Volta words

def _avg_cycles(footprint, l0, l1, l2):
    for c in (l0, l1, l2):
        c.flush()
    n = footprint // INSTR_BYTES
    addrs = (np.arange(n) * INSTR_BYTES)
    total = 0
    for rep in range(2):
        cyc = 0
        for a in addrs:
            a = int(a)
            if l0.access(a):
                cyc += 2            # NVCC's 2-cycle stall cadence (paper 3.3)
            elif l1.access(a):
                cyc += 5
            elif l2.access(a):
                cyc += 20
            else:
                cyc += 100
        total = cyc                  # keep second (warm) pass
    return total / n

def run():
    l0 = SetAssocCache(12 * KiB, 256, sets=16)    # 3-way (paper fig 3.4)
    l1 = SetAssocCache(128 * KiB, 512, sets=32)   # 8-way
    l2 = SetAssocCache(1024 * KiB, 512)           # stand-in for 6 MiB L2
    sizes = [2, 4, 8, 10, 12, 16, 24, 32, 64, 96, 128, 160, 192, 256, 384]
    curve = [(s, _avg_cycles(s * KiB, l0, l1, l2)) for s in sizes]
    # Plateau ends where inverse throughput jumps between tested sizes.
    jumps = [curve[i][0] for i in range(len(curve) - 1)
             if curve[i + 1][1] > curve[i][1] + 0.08]
    l0_end = jumps[0] if jumps else sizes[-1]
    l1_end = jumps[1] if len(jumps) > 1 else sizes[-1]
    c = dict(curve)
    return (f"L0_plateau_end={l0_end}KiB(12);L1_plateau_end={l1_end}KiB(128);"
            f"inverse_throughput@2K={c[2]:.2f}cyc"
            f"@16K={c[16]:.2f}@192K={c[192]:.2f}")
