"""Serving bench: prefill + decode tokens/sec through the engine, plus the
modeled naive-vs-fast-path decode attention comparison.

Two kinds of numbers:

* **Measured** — wall-clock tokens/sec of ``ServingEngine`` on the smoke
  model (interpret-mode kernels on CPU, native on TPU): prefill tok/s,
  decode tok/s, and the prefill executable count (buckets, not prompts).
* **Modeled** — the autotuner's attention cost model priced at production
  shape (``decode_32k``): every slot attending the full 32k cache (the
  seed engine) vs flash decode streaming only each slot's live context.
  This is the speedup the skipped-load machinery buys, reportable even
  off-TPU.

Paged vs contiguous rides in both: the measured run repeats through a
paged engine (same prompts, half-size page pool, chunked prefill) and
reports the HBM rows each cache layout actually holds; the modeled
``decode_32k`` cell prices the paged variant (page-table-lookup overhead,
reservation ratio) over a long-tailed stagger of slot lengths — the
serving distribution where flat ``slots * max_len`` reservations waste the
most.

Chunked prefill adds two cells: ``prefill_chunked_interleave`` (measured —
decode tokens that land *while* a long prompt is mid-prefill, the
head-of-line stall the chunk scheduler removes) and ``prefill_chunked_32k``
(modeled — the autotune chunk cost model's chosen chunk vs whole-prompt
prefill: total-time overhead paid, interleave latency bought back).

Speculative decoding adds two more: ``spec_decode_accept`` (measured — the
n-gram drafter on a repetitive prompt through the spec engine: accepted
drafts per verify tick, stream parity with the plain greedy engine, one
verify executable) and ``spec_decode_32k`` (modeled —
``autotune.choose_spec_k`` pricing accept-rate against verify-width
overhead at production shape, including the regime where it returns k=0
and disables speculation).

Prefix caching adds two: ``prefix_cache_hit`` (measured — shared-prefix
waves through the paged engine with the cache off then on: byte-identical
streams, suffix-only TTFT for the four concurrent sharers, pool high
water strictly below the uncached engine's, hit/COW counters reconciled
against the allocator) and ``prefix_cache_32k`` (modeled —
``autotune.choose_prefix_cache`` pricing suffix-only prefill plus the
probe/COW tax at an 8k cached prefix on a 32k prompt, including the
hit-rate-0 regime where it disables itself).

Distributed serving adds the last two: ``tp_pool_capacity`` (measured —
an 8-host-device subprocess runs the same request mix through the
single-device and mesh-sharded engines: token-stream parity flag, page
tables spanning devices, 1-vs-8 pool capacity at the same ``n_pages``,
and exactly one decode executable per mesh) and ``tp_decode_32k``
(modeled — ``autotune.tp_decode_model``: the weight-stream term sharded
by the mesh degree vs the per-layer activation all-reduces + unembed
ring gather it buys them with, plus the x8 pool-capacity headline).

  PYTHONPATH=src python -m benchmarks.tpu_serving --out BENCH_serving.json
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import autotune
from repro.models import transformer as T
from repro.serve import traffic
from repro.serve.engine import (Request, ServeConfig, ServingEngine,
                                greedy_generate)

ARCH = "qwen3-4b"
N_REQUESTS = 6
MAX_NEW = 8
MAX_LEN = 64
BATCH = 4


PAGE_SIZE = 8           # smoke-model pages (production: 128+, MXU-aligned)


def _run_engine(params, cfg, prompts, serve_cfg: ServeConfig) -> dict:
    eng = ServingEngine(params, cfg, serve_cfg)
    # Warm every executable the timed run will hit (compile time is not
    # serving throughput). Contiguous: one prompt per bucket. Paged: the
    # single chunk executable — one multi-chunk prompt covers it.
    if eng.pool is None:
        buckets = {eng.bucket_for(len(p)) for p in prompts}
        for wid, b in enumerate(sorted(buckets)):
            eng.submit(Request(rid=-1 - wid,
                               prompt=np.resize(prompts[0], b), max_new=2))
    else:
        warm_len = min(eng.chunk + 1, serve_cfg.max_len - 2)
        eng.submit(Request(rid=-1, prompt=np.resize(prompts[0], warm_len),
                           max_new=2))
    eng.run_until_drained()
    if eng.pool is not None:
        # Report the timed run's pool pressure, not the warm-up's.
        eng.pool.high_water = eng.pool.pages_in_use
        eng.admission_rejections = 0

    t0 = time.perf_counter()
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new=MAX_NEW))
    finished = eng.run_until_drained()
    dt = time.perf_counter() - t0
    prefill_toks = sum(len(p) for p in prompts)
    decode_toks = sum(len(v) for rid, v in finished.items() if rid >= 0)
    out = {
        "prefill_tokens": prefill_toks,
        "decode_tokens": decode_toks,
        "wall_s": dt,
        "tokens_per_s": (prefill_toks + decode_toks) / dt,
        "prefill_executables": len(eng.prefill_traces),
        "prefill_buckets": sorted(eng.prefill_traces),
        "cache_hbm_rows": T.cache_hbm_rows(eng.caches),
    }
    if eng.pool is not None:
        occ = eng.pool.occupancy()
        out["pool_high_water_pages"] = occ["high_water"]
        out["admission_rejections"] = eng.admission_rejections
        out["prefill_chunk"] = eng.chunk
        out["preemptions"] = eng.preemptions
    return out


def _measured() -> dict:
    cfg = configs.get_smoke(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(2, cfg.vocab, size=rng.randint(4, 17))
               .astype(np.int32) for _ in range(N_REQUESTS)]
    contig = _run_engine(params, cfg, prompts,
                         ServeConfig(max_len=MAX_LEN, batch=BATCH,
                                     eos_id=-1))
    # Paged: same prompts through a pool holding half the contiguous
    # reservation — the engine must stay correct *and* cheaper-resident.
    # Prompts stream through the page table in 8-row chunks (one chunk
    # executable total; see prefill_executables == 1 in the output).
    n_pages = 1 + BATCH * MAX_LEN // PAGE_SIZE // 2
    paged = _run_engine(params, cfg, prompts,
                        ServeConfig(max_len=MAX_LEN, batch=BATCH,
                                    eos_id=-1, paged=True,
                                    page_size=PAGE_SIZE, n_pages=n_pages,
                                    chunk_size=PAGE_SIZE))
    contig["paged"] = paged
    contig["paged_rows_ratio"] = (paged["cache_hbm_rows"]
                                  / contig["cache_hbm_rows"])
    return contig


def _measured_interleave() -> dict:
    """Long-prompt interleave cell: three slots decoding while a 48-token
    prompt streams in 8-row chunks — every mid-prefill tick must land one
    decode token per active slot (the head-of-line stall the bucketed
    row-cache prefill used to impose is gone)."""
    cfg = configs.get_smoke(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(1)
    scfg = ServeConfig(max_len=64, batch=4, eos_id=-1, paged=True,
                       page_size=8, chunk_size=8)
    eng = ServingEngine(params, cfg, scfg)
    eng.submit(Request(rid=-1, prompt=rng.randint(2, cfg.vocab, 9)
                       .astype(np.int32), max_new=2))      # warm both fns
    eng.run_until_drained()
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=rng.randint(2, cfg.vocab, 7)
                           .astype(np.int32), max_new=40))
    eng.tick()                                 # all three decoding
    long_prompt = rng.randint(2, cfg.vocab, 48).astype(np.int32)
    eng.submit(Request(rid=9, prompt=long_prompt, max_new=2))
    decoded_before = sum(len(eng.slots[i].generated) for i in range(3))
    t0 = time.perf_counter()
    mid_ticks = 0
    eng.tick()                                 # admit + first chunk
    while 3 in eng._prefilling:
        eng.tick()
        mid_ticks += 1
    dt = time.perf_counter() - t0
    decoded_during = sum(len(eng.slots[i].generated)
                         for i in range(3)) - decoded_before
    eng.run_until_drained()
    return {
        "long_prompt_len": len(long_prompt),
        "prefill_chunks": -(-len(long_prompt) // scfg.chunk_size),
        "mid_prefill_ticks": mid_ticks,
        "decode_slots": 3,
        "decode_tokens_during_prefill": decoded_during,
        "wall_s": dt,
        "prefill_executables": len(eng.prefill_traces),
    }


def _measured_spec() -> dict:
    """spec_decode_accept cell: the n-gram (prompt-lookup) drafter over a
    period-4 repetitive prompt, spec_k=4. The stream the smoke model
    greedily settles into is periodic, so once the history repeats the
    drafter lands whole 4-token drafts per verify tick — the accepted
    tokens that amortize the per-tick dispatch + weight stream. Parity
    with the plain greedy engine is asserted, not assumed."""
    cfg = configs.get_smoke(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(3)
    motif = rng.randint(2, cfg.vocab, 4).astype(np.int32)
    prompt = np.tile(motif, 6)                   # 24 tokens, period 4
    max_new, spec_k = 48, 4
    ref = np.asarray(greedy_generate(
        params, cfg, jnp.asarray(prompt)[None], max_new,
        max_len=128)[0]).tolist()
    eng = ServingEngine(params, cfg,
                        ServeConfig(max_len=128, batch=2, eos_id=-1,
                                    paged=True, page_size=8, chunk_size=8,
                                    spec_k=spec_k, draft="ngram"))
    # Warm the chunk + verify executables (compile time is not serving
    # throughput), then reset the accept counters for the timed run.
    eng.submit(Request(rid=-1, prompt=rng.randint(2, cfg.vocab, 9)
                       .astype(np.int32), max_new=6))
    eng.run_until_drained()
    eng.spec_ticks = eng.spec_accepted = eng.spec_emitted = 0

    t0 = time.perf_counter()
    eng.submit(Request(rid=0, prompt=prompt, max_new=max_new))
    finished = eng.run_until_drained()
    dt = time.perf_counter() - t0
    ticks = max(1, eng.spec_ticks)
    return {
        "spec_k": spec_k,
        "draft": "ngram",
        "prompt_len": len(prompt),
        "decode_tokens": len(finished[0]),
        "verify_ticks": eng.spec_ticks,
        "accepted": eng.spec_accepted,
        "accepted_per_tick": eng.spec_accepted / ticks,
        "emitted_per_tick": eng.spec_emitted / ticks,
        "accept_rate": eng.spec_accepted / (spec_k * ticks),
        "greedy_parity": finished[0] == ref,
        "wall_s": dt,
        "tokens_per_s": len(finished[0]) / dt,
        "verify_executables": eng.verify_traces,
        "prefill_executables": len(eng.prefill_traces),
    }


def _modeled_spec() -> dict:
    """spec_decode_32k cell: choose_spec_k at production shape — verify
    width priced against the fixed per-tick weight stream it amortizes
    (the paper's latency-hiding arithmetic at serving granularity). Also
    reports the disable regime: a 1 GB model draft at 5% accept must come
    back k=0."""
    cfg = configs.get_config(ARCH)
    max_len = 32768
    lengths = np.geomspace(256, max_len, 128).astype(int)
    param_bytes = T.active_param_count(cfg) * 2.0        # bf16
    k, terms = autotune.choose_spec_k(
        lengths, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.dhead, page_size=256, accept_rate=0.7,
        param_bytes=param_bytes)
    k_low, _ = autotune.choose_spec_k(
        lengths, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.dhead, page_size=256, accept_rate=0.05,
        param_bytes=param_bytes, draft_bytes=1e9)
    out = dict(terms)
    out.update({
        "max_len": max_len,
        "param_bytes": param_bytes,
        "k_at_low_accept_model_draft": k_low,
    })
    return out


def _modeled_chunked() -> dict:
    """prefill_chunked_32k: the autotune chunk cost model at production
    shape — chosen chunk vs whole-prompt (row-cache-equivalent) prefill:
    the total-time overhead chunking pays, and the interleave latency it
    buys back for concurrent decode slots."""
    cfg = configs.get_config(ARCH)
    page_size = 256
    chunk, terms = autotune.choose_prefill_chunk(
        32768, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.dhead, page_size=page_size)
    whole = autotune.prefill_chunk_model(
        32768, 32768, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.dhead, page_size=page_size)
    out = dict(terms)
    out.update({
        "page_size": page_size,
        "whole_prompt_prefill_s": whole["prefill_s"],
        "whole_prompt_latency_s": whole["interleave_latency_s"],
        "prefill_overhead_frac":
            terms["prefill_s"] / whole["prefill_s"] - 1.0,
        "latency_reduction":
            whole["interleave_latency_s"] / terms["interleave_latency_s"],
    })
    return out


def _modeled() -> dict:
    """decode_32k cell: 128 slots, 32k cache, uniformly ragged contexts."""
    cfg = configs.get_config(ARCH)
    max_len = 32768
    lengths = np.linspace(512, max_len, 128).astype(int)
    out = autotune.decode_attn_speedup(
        max_len, lengths, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.dhead)
    out["max_len"] = max_len
    out["mean_context"] = float(lengths.mean())
    return out


def _modeled_paged() -> dict:
    """Paged decode_32k: long-tailed staggered lengths (geomspace — most
    contexts short, a few at max_len, the shape real serving traffic has),
    256-row pages."""
    cfg = configs.get_config(ARCH)
    max_len = 32768
    lengths = np.geomspace(256, max_len, 128).astype(int)
    out = autotune.paged_decode_model(
        max_len, lengths, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.dhead, page_size=256)
    out["max_len"] = max_len
    out["mean_context"] = float(lengths.mean())
    return out


PREFIX_LEN = 24


def _measured_prefix() -> dict:
    """prefix_cache_hit cell: session traffic (a two-session
    ``TrafficClass``, 24-token shared prefixes, six arrivals) through
    the paged engine with the prefix cache off then on. The first
    arrival of each session publishes its prefix; the remaining sharers
    ride it. The on-engine must emit byte-identical streams while
    prefilling only each sharer's suffix — TTFT drops to the suffix
    chunk count — and must hold strictly fewer pages at high water than
    the off-engine: one resident copy per distinct prefix while the
    sharers decode concurrently."""
    cfg = configs.get_smoke(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    arr = traffic.TrafficGenerator(traffic.TrafficConfig(
        rate=1.0, n_requests=N_REQUESTS, seed=5, vocab=cfg.vocab,
        classes=(traffic.TrafficClass(
            "chat", sessions=2, prefix_len=PREFIX_LEN,
            prompt_lo=4, prompt_hi=9, out_lo=MAX_NEW,
            out_hi=MAX_NEW),))).arrivals()
    first = {}
    for a in arr:                             # session publishers
        first.setdefault(a.prompt[:PREFIX_LEN].tobytes(), a.rid)
    pubs = sorted(first.values())
    sharers = [a.rid for a in arr if a.rid not in pubs]
    by_rid = {a.rid: a for a in arr}
    wrng = np.random.RandomState(9)

    def run(on):
        eng = ServingEngine(params, cfg, ServeConfig(
            max_len=MAX_LEN, batch=BATCH, eos_id=-1, paged=True,
            page_size=PAGE_SIZE, chunk_size=PAGE_SIZE, prefix_cache=on))
        eng.submit(Request(rid=-1, prompt=wrng.randint(
            2, cfg.vocab, PAGE_SIZE + 1).astype(np.int32), max_new=2))
        eng.run_until_drained()               # warm the executables
        if eng.prefix is not None:
            eng.prefix.clear()                # timed run seeds its own
        eng.pool.high_water = eng.pool.pages_in_use
        for rid in pubs:
            eng.submit(Request(rid=rid, prompt=by_rid[rid].prompt.copy(),
                               max_new=MAX_NEW))
        eng.run_until_drained()
        t0 = eng.ticks
        for rid in sharers:
            eng.submit(Request(rid=rid, prompt=by_rid[rid].prompt.copy(),
                               max_new=MAX_NEW))
        # run_until_drained returns the cumulative finished dict — drop
        # the warm-up rid (its prompt differs between the two runs).
        streams = {rid: s for rid, s in eng.run_until_drained().items()
                   if rid >= 0}
        ttft = [eng.first_token_tick[rid] - t0 for rid in sharers]
        return streams, sum(ttft) / len(ttft), eng

    off_streams, ttft_off, eng_off = run(False)
    on_streams, ttft_on, eng = run(True)
    return {
        "prefix_len": PREFIX_LEN,
        "page_size": PAGE_SIZE,
        "sessions": 2,
        "publishers": len(pubs),
        "sharers": len(sharers),
        "stream_parity": on_streams == off_streams,
        "ttft_ticks_uncached": ttft_off,
        "ttft_ticks_hit": ttft_on,
        "ttft_reduction": ttft_off / max(ttft_on, 1e-9),
        "prefix_hits": eng.prefix_hits,
        "prefix_misses": eng.prefix_misses,
        "hit_pages": eng.prefix_hit_pages,
        "cow_copies": eng.cow_copies,
        "index_entries": len(eng.prefix),
        "high_water_pages_uncached": eng_off.pool.high_water,
        "high_water_pages_cached": eng.pool.high_water,
        "reservation_ratio": (eng.pool.high_water
                              / max(1, eng_off.pool.high_water)),
        "counters_reconcile": (
            eng.prefix_hit_pages == eng.pool.shared_mappings
            and eng.cow_copies == eng.pool.cow_count),
    }


def _modeled_prefix() -> dict:
    """prefix_cache_32k cell: ``autotune.choose_prefix_cache`` at
    production shape — an 8k-row session prefix on a 32k prompt at 60%
    hit rate: suffix-only prefill plus the COW split and probe tax vs
    prefilling from row 0, and the disable regime (hit rate 0 must come
    back off — the probe tax buys nothing)."""
    cfg = configs.get_config(ARCH)
    on, terms = autotune.choose_prefix_cache(
        32768, prefix_rows=8192, hit_rate=0.6, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.dhead, page_size=256)
    on_zero, _ = autotune.choose_prefix_cache(
        32768, prefix_rows=8192, hit_rate=0.0, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.dhead, page_size=256)
    out = dict(terms)
    out.update({
        "max_len": 32768,
        "page_size": 256,
        "enabled": on,
        "enabled_at_zero_hit_rate": on_zero,
    })
    return out


TP_DEVICES = 8

_TP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, sys.argv[1])
import jax, numpy as np
from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.serve.engine import Request, ServeConfig, ServingEngine

cfg = configs.get_smoke("qwen3-4b")
params = T.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.RandomState(2)
prompts = [rng.randint(2, cfg.vocab, n).astype(np.int32)
           for n in (9, 14, 6, 12)]
kw = dict(max_len=64, batch=4, eos_id=-1, paged=True, page_size=4,
          chunk_size=8, n_pages=64)

def run(mesh):
    eng = ServingEngine(params, cfg, ServeConfig(**kw), mesh=mesh)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new=10))
    spans = {}
    orig = eng.tick
    def tick():
        n = orig()
        for rid, pages in eng.pool.slot_pages.items():
            spans[rid] = spans.get(rid, set()) | {
                eng.pool.device_of(p) for p in pages}
        return n
    eng.tick = tick
    out = {k: list(v) for k, v in eng.run_until_drained().items()}
    return out, eng, spans

ref, e1, _ = run(None)
got, e8, spans = run(mesh_lib.make_mesh((8,), ("model",)))
print("TP_RESULTS:" + json.dumps({
    "n_devices": e8.pool.n_devices,
    "parity": ref == got,
    "capacity_1dev": e1.pool.capacity,
    "capacity_tp": e8.pool.capacity,
    "max_device_span": max(len(v) for v in spans.values()),
    "decode_executables_1dev": e1.decode_traces,
    "decode_executables_tp": e8.decode_traces,
    "preemptions_tp": e8.preemptions,
}))
"""


def _measured_tp() -> dict:
    """tp_pool_capacity cell: the acceptance oracle, measured — same
    prompts through the 1-device and 8-device engines in a subprocess
    with 8 host devices (the bench process itself sees one)."""
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    with tempfile.NamedTemporaryFile("w", suffix=".py",
                                     delete=False) as f:
        f.write(_TP_SCRIPT)
        script = f.name
    try:
        proc = subprocess.run([sys.executable, script, src],
                              capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stderr[-3000:]
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("TP_RESULTS:")][0]
        return json.loads(line[len("TP_RESULTS:"):])
    finally:
        os.unlink(script)


def _modeled_tp() -> dict:
    """tp_decode_32k cell: one decode tick 1-dev vs tensor-parallel at
    production shape — the sharded weight stream vs the activation
    collectives it costs, and the x(mesh) pool-capacity headline."""
    cfg = configs.get_config(ARCH)
    max_len = 32768
    lengths = np.geomspace(256, max_len, 128).astype(int)
    param_bytes = T.active_param_count(cfg) * 2.0        # bf16
    out = autotune.tp_decode_model(
        lengths, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.dhead, page_size=256, param_bytes=param_bytes,
        d_model=cfg.d_model, n_layers=cfg.n_layers, n_devices=TP_DEVICES)
    out["max_len"] = max_len
    out["param_bytes"] = param_bytes
    return out


def _measured_calibration() -> dict:
    """calibration_probes cell: run the microbenchmark calibration pass
    (core/calibrate.py — fast mode, the probes' CI shape), persist the
    measured constants under the tuning cache's ``calibrated:``
    namespace, and report measured-vs-assumed per constant. After this
    cell, ``resolve_constants`` prefers the measured set — the bench
    asserts that loop actually closed."""
    from repro.core import calibrate

    results = calibrate.run_calibration(fast=True, persist=True)
    report = autotune.calibration_report()
    resolved = autotune.resolve_constants()
    rows = {}
    for name, r in results.items():
        rows[name] = {
            "measured": r.value,
            "assumed": report["constants"][name]["assumed"],
            "drift_ratio": report["constants"][name]["drift_ratio"],
            "n_trials": r.n_trials,
            "spread": r.spread,
            "unit": r.unit,
        }
    return {
        "schema_version": autotune.CALIBRATION_SCHEMA_VERSION,
        "backend": report["backend"],
        "mesh": report["mesh"],
        "n_measured": len(rows),
        "resolved_source": resolved.source,
        "constants": rows,
    }


def run():
    m = _measured()
    c = _modeled()
    p = _modeled_paged()
    il = _measured_interleave()
    ck = _modeled_chunked()
    sp = _measured_spec()
    sk = _modeled_spec()
    pfx = _measured_prefix()
    pfk = _modeled_prefix()
    tpm = _measured_tp()
    tpk = _modeled_tp()
    cal = _measured_calibration()
    return [
        ("calibration_probes",
         f"measured={cal['n_measured']};source={cal['resolved_source']};"
         f"page_lookup_drift="
         f"{cal['constants']['page_lookup_s']['drift_ratio']:.2g}"),
        ("measured",
         f"{m['tokens_per_s']:.1f}tok/s;prefill={m['prefill_tokens']};"
         f"decode={m['decode_tokens']};"
         f"executables={m['prefill_executables']}"),
        ("measured_paged",
         f"{m['paged']['tokens_per_s']:.1f}tok/s;"
         f"rows_ratio={m['paged_rows_ratio']:.2f};"
         f"chunk={m['paged']['prefill_chunk']};"
         f"executables={m['paged']['prefill_executables']}"),
        ("modeled_decode_32k",
         f"naive={c['naive_s']*1e3:.3f}ms;fast={c['fast_s']*1e3:.3f}ms;"
         f"speedup={c['speedup']:.2f}x"),
        ("paged_decode_32k",
         f"reservation={p['reservation_ratio']:.2f};"
         f"overhead={p['lookup_overhead_frac']*100:.1f}%;"
         f"tok/s={p['tokens_per_s_paged']:.0f}"),
        ("prefill_chunked_interleave",
         f"decode_toks_mid_prefill={il['decode_tokens_during_prefill']};"
         f"chunks={il['prefill_chunks']};"
         f"executables={il['prefill_executables']}"),
        ("prefill_chunked_32k",
         f"chunk={ck['chunk']};"
         f"overhead={ck['prefill_overhead_frac']*100:.1f}%;"
         f"latency/{ck['latency_reduction']:.0f}"),
        ("spec_decode_accept",
         f"accepted/tick={sp['accepted_per_tick']:.2f};"
         f"emitted/tick={sp['emitted_per_tick']:.2f};"
         f"verify_executables={sp['verify_executables']}"),
        ("spec_decode_32k",
         f"k={sk['chosen_k']};speedup={sk['speedup']:.2f}x;"
         f"accept={sk['accept_rate']:.2f};"
         f"k_low_accept={sk['k_at_low_accept_model_draft']}"),
        ("prefix_cache_hit",
         f"parity={pfx['stream_parity']};"
         f"ttft={pfx['ttft_ticks_hit']:.1f}/{pfx['ttft_ticks_uncached']:.1f}t;"
         f"reservation={pfx['reservation_ratio']:.2f};"
         f"cow={pfx['cow_copies']}"),
        ("prefix_cache_32k",
         f"speedup={pfk['speedup']:.2f}x;"
         f"ttft_frac_hit={pfk['ttft_frac_hit']:.2f};"
         f"on={pfk['enabled']};zero_hit_on={pfk['enabled_at_zero_hit_rate']}"),
        ("tp_pool_capacity",
         f"parity={tpm['parity']};devices={tpm['n_devices']};"
         f"span={tpm['max_device_span']};"
         f"executables={tpm['decode_executables_tp']}"),
        ("tp_decode_32k",
         f"speedup={tpk['speedup']:.2f}x;"
         f"collective={tpk['collective_frac']*100:.0f}%;"
         f"pool_x{tpk['pool_capacity_ratio']:.0f}"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    payload = {"measured": _measured(), "modeled_decode_32k": _modeled(),
               "paged_decode_32k": _modeled_paged(),
               "prefill_chunked_interleave": _measured_interleave(),
               "prefill_chunked_32k": _modeled_chunked(),
               "spec_decode_accept": _measured_spec(),
               "spec_decode_32k": _modeled_spec(),
               "prefix_cache_hit": _measured_prefix(),
               "prefix_cache_32k": _modeled_prefix(),
               "tp_pool_capacity": _measured_tp(),
               "tp_decode_32k": _modeled_tp(),
               "calibration_probes": _measured_calibration()}
    print(json.dumps(payload, indent=1))
    assert payload["modeled_decode_32k"]["speedup"] > 1.0
    # Acceptance: paged holds < 50% of the contiguous reservation at
    # decode_32k with staggered slot lengths.
    assert payload["paged_decode_32k"]["reservation_ratio"] < 0.5
    assert payload["measured"]["paged_rows_ratio"] < 1.0
    # Acceptance: one chunk executable regardless of prompt-length mix,
    # and decode ticks land tokens while the long prompt is mid-prefill.
    assert payload["measured"]["paged"]["prefill_executables"] == 1
    assert payload["prefill_chunked_interleave"][
        "decode_tokens_during_prefill"] > 0
    assert payload["prefill_chunked_interleave"]["prefill_executables"] == 1
    assert payload["prefill_chunked_32k"]["latency_reduction"] > 1.0
    # Acceptance: the n-gram drafter lands > 1 accepted token per verify
    # tick on the repetitive prompt, the stream is the plain greedy
    # engine's, and exactly one verify executable was traced; the modeled
    # cell speculates profitably at accept=0.7 and disables (k=0) for the
    # low-accept model draft.
    assert payload["spec_decode_accept"]["accepted_per_tick"] > 1.0
    assert payload["spec_decode_accept"]["greedy_parity"]
    assert payload["spec_decode_accept"]["verify_executables"] == 1
    assert payload["spec_decode_32k"]["chosen_k"] >= 1
    assert payload["spec_decode_32k"]["speedup"] > 1.0
    assert payload["spec_decode_32k"]["k_at_low_accept_model_draft"] == 0
    # Acceptance: cached admissions stream bit-identically to the
    # uncached engine while prefilling only the suffix (TTFT strictly
    # below uncached with >= 2 concurrent sharers), the shared pool's
    # high water sits strictly below the uncached engine's, and the
    # hit/COW telemetry reconciles with the allocator's refcount totals;
    # the modeled cell speculates profitably at 60% hit rate and
    # disables itself at hit rate 0 (the probe tax buys nothing).
    pfx = payload["prefix_cache_hit"]
    assert pfx["stream_parity"]
    assert pfx["sharers"] >= 2 and pfx["prefix_hits"] >= 2
    assert pfx["ttft_ticks_hit"] < pfx["ttft_ticks_uncached"]
    assert pfx["reservation_ratio"] < 1.0
    assert pfx["counters_reconcile"]
    assert payload["prefix_cache_32k"]["enabled"]
    assert payload["prefix_cache_32k"]["speedup"] > 1.0
    assert not payload["prefix_cache_32k"]["enabled_at_zero_hit_rate"]
    # Acceptance: the mesh-sharded engine's streams are bit-identical to
    # the single-device engine's, a slot's page table spans devices, the
    # same n_pages gives the same capacity on either mesh, and each mesh
    # compiled exactly one decode executable.
    tp = payload["tp_pool_capacity"]
    assert tp["parity"]
    assert tp["max_device_span"] >= 2
    assert tp["capacity_tp"] == tp["capacity_1dev"]
    assert tp["decode_executables_tp"] == 1
    assert tp["decode_executables_1dev"] == 1
    assert payload["tp_decode_32k"]["speedup"] > 1.0
    assert payload["tp_decode_32k"]["pool_capacity_ratio"] == TP_DEVICES
    # Acceptance: the calibration pass measured >= 5 constants (finite
    # positive, with a recorded drift ratio against the hand-set
    # assumption) and resolve_constants now prefers the measured set.
    cal = payload["calibration_probes"]
    assert cal["n_measured"] >= 5, cal
    assert cal["resolved_source"] == "calibrated", cal
    for name, row in cal["constants"].items():
        assert row["measured"] > 0 and row["assumed"] > 0, (name, row)
        assert row["drift_ratio"] > 0, (name, row)
    if args.out:
        # Read-modify-write: breaking_point.py merges its cells into the
        # same BENCH json, so a rerun here must not clobber them.
        existing = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        existing.update(payload)
        with open(args.out, "w") as f:
            json.dump(existing, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
