"""Table 4.2 / Fig 4.1: atomic latency and throughput under contention."""
from repro.core import atomics, hwmodel

def run():
    rows = []
    for name in ("V100", "P100", "K80"):
        s = hwmodel.GPUS[name]
        res = atomics.model_residuals(s, "shared")
        pub1, mod1 = res[1]
        pub32, mod32 = res[32]
        rows.append((name, f"shared@1:pub={pub1:.0f}/model={mod1:.0f};"
                     f"@32:pub={pub32:.0f}/model={mod32:.0f}"))
    v = hwmodel.V100
    s4 = atomics.throughput_scenario(v, 4)
    s3 = atomics.throughput_scenario(v, 3)
    rows.append(("V100_fig4_1", f"scenario4/scenario3={s4/s3:.0f}x"
                 "(no-contention scaling wins, paper's conclusion)"))
    return rows
