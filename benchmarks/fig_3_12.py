"""Fig 3.12: TLB sweep -> page entry sizes and coverages."""
from repro.core import dissect, hwmodel

def run():
    tlbs = dissect.dissect_tlbs(hwmodel.V100)
    MiB = 1024 * 1024
    return (f"L1TLB:page={tlbs[0].page_entry//MiB}MiB(2),"
            f"coverage={tlbs[0].coverage//MiB}MiB(32);"
            f"L2TLB:page={tlbs[1].page_entry//MiB}MiB(32),"
            f"coverage={tlbs[1].coverage//MiB}MiB(8192)")
