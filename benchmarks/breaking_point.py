"""Breaking-point bench: sweep offered load until the serving engine
breaks, and characterize the break.

This is the paper's method applied to our own stack: the
microbenchmarks drive each cache level past its comfortable operating
point and report *where* the latency cliff sits and *what* the
degraded plateau looks like — here the swept axis is offered load
(requests per engine tick through the open-loop traffic generator)
and the reported surface is what a production operator reads:

  * ``breaking_point_sweep`` — per offered rate: TTFT/TPOT p50/p99,
    goodput (completed tokens per tick), shed rate, preemptions, pool
    high water; plus the **knee point** — the offered rate where
    goodput peaks. Past the knee the engine is saturated: more offered
    load buys shed and preemption churn, not throughput, so goodput
    must be monotone non-increasing from there (the validator gates
    it).
  * ``breaking_point_faults`` — the canonical seeded fault schedule
    (pool squeeze -> accept collapse -> churn storm) against open-loop
    traffic on the full stack: every request must complete or cleanly
    reject, surviving streams bit-identical to the fault-free engine's
    (prefix-exact for force-completions), all fault windows armed and
    cleared.

All latencies are in *engine ticks* (deterministic, hardware-blind:
one tick = one decode step for every active slot); multiply by the
measured per-tick wall time — reported as ``tick_wall_s`` — to get
seconds on this machine. Tick-domain numbers are what make the
committed cells schema-gateable with hard inequalities: the same
sweep reproduces bit-for-bit on any host.

  PYTHONPATH=src python -m benchmarks.breaking_point --out BENCH_serving.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serve import telemetry, traffic
from repro.serve.engine import Request, ServeConfig, ServingEngine, SLOClass
from repro.serve.faults import FaultInjector, canonical_schedule

ARCH = "qwen3-4b"
MAX_LEN = 64
BATCH = 2
PAGE_SIZE = 8
N_PAGES = 17
N_REQUESTS = 24
RATES = (0.25, 0.5, 1.0, 2.0, 4.0)
SEED = 11


def _serve_cfg(**kw) -> ServeConfig:
    base = dict(
        max_len=MAX_LEN, batch=BATCH, eos_id=-1, paged=True,
        page_size=PAGE_SIZE, chunk_size=8, n_pages=N_PAGES,
        classes=(SLOClass("default", ttft_slo=16, tpot_slo=4.0),),
        max_queue=8, max_preemptions=3, degrade=True)
    base.update(kw)
    return ServeConfig(**base)


def _traffic_cfg(rate: float, vocab: int) -> traffic.TrafficConfig:
    return traffic.TrafficConfig(
        rate=rate, n_requests=N_REQUESTS, seed=SEED, vocab=vocab,
        classes=(traffic.TrafficClass("default", prompt_lo=4, prompt_hi=20,
                                      out_lo=2, out_hi=8),))


def _engine(params, cfg, **kw) -> ServingEngine:
    eng = ServingEngine(params, cfg, _serve_cfg(**kw))
    # Warm the chunk + decode executables outside the timed region.
    eng.submit(Request(rid=-1, prompt=np.resize(
        np.arange(3, 12, dtype=np.int32), eng.chunk + 1), max_new=2))
    eng.run_until_drained()
    eng.pool.high_water = 0
    # One reset clears the trace ring, every counter view (admission
    # holds, preemptions, spec accounting, ...) and the span/tick timing
    # aggregates, so the timed region starts from a clean epoch.
    eng.telemetry.reset()
    eng.ticks = 0
    return eng


def sweep_cell(params, cfg) -> dict:
    points = []
    for rate in RATES:
        eng = _engine(params, cfg)
        arr = traffic.TrafficGenerator(
            _traffic_cfg(rate, cfg.vocab)).arrivals()
        t0 = time.perf_counter()
        res = traffic.run_open_loop(eng, arr, max_ticks=4000)
        wall = time.perf_counter() - t0
        assert res["unresolved"] == [], (rate, res["unresolved"])
        s = traffic.summarize(eng, arr)
        tstats = eng.telemetry.tick_stats()
        points.append({
            "offered_rate": rate,
            "ticks": s["ticks"],
            "tick_wall_s": wall / max(1, s["ticks"]),
            "tick_wall_p50_s": tstats["p50_s"],
            "tick_wall_p99_s": tstats["p99_s"],
            "done": s["done"], "forced": s["forced"],
            "rejected": s["rejected"],
            "ttft_p50": s["ttft_p50"], "ttft_p99": s["ttft_p99"],
            "tpot_p50": s["tpot_p50"], "tpot_p99": s["tpot_p99"],
            "goodput_tokens_per_tick": s["goodput_tokens_per_tick"],
            "shed_rate": s["shed_rate"],
            "ttft_slo_attainment": s.get("ttft_slo_attainment", 1.0),
            "preemptions": s["preemptions"],
            "admission_holds": s["admission_holds"],
            "downshifts": s["downshifts"],
            "degraded_ticks": s["degraded_ticks"],
            "pool_high_water_pages": eng.pool.high_water,
            "pool_capacity_pages": eng.pool.capacity,
        })
        print(f"  rate {rate:>5}: goodput "
              f"{points[-1]['goodput_tokens_per_tick']:.3f} tok/tick, "
              f"ttft p50/p99 {s['ttft_p50']:.0f}/{s['ttft_p99']:.0f}, "
              f"shed {s['shed_rate']:.2f}")
    knee_i = max(range(len(points)),
                 key=lambda i: points[i]["goodput_tokens_per_tick"])
    return {
        "arch": ARCH, "batch": BATCH, "n_pages": N_PAGES,
        "n_requests": N_REQUESTS, "seed": SEED,
        "offered_rates": list(RATES),
        "points": points,
        "knee_rate": points[knee_i]["offered_rate"],
        "knee_goodput_tokens_per_tick":
            points[knee_i]["goodput_tokens_per_tick"],
    }


def faults_cell(params, cfg) -> dict:
    arr = traffic.TrafficGenerator(
        _traffic_cfg(1.5, cfg.vocab)).arrivals()

    def run(injector):
        eng = _engine(params, cfg, spec_k=2, draft="ngram",
                      spec_adapt_every=4, spec_probe_every=4)
        res = traffic.run_open_loop(eng, arr, max_ticks=4000,
                                    injector=injector)
        if injector is not None:
            injector.finish(eng)
        return eng, res

    inj = FaultInjector(canonical_schedule(t0=4, dwell=8, gap=6))
    faulty, res = run(inj)
    clean, res_clean = run(None)
    assert res["unresolved"] == [] and res_clean["unresolved"] == []

    parity, compared = True, 0
    for a in arr:
        if clean.outcome.get(a.rid) != "done":
            continue
        out = faulty.outcome.get(a.rid, "")
        if out == "done":
            parity &= faulty.finished[a.rid] == clean.finished[a.rid]
            compared += 1
        elif out.startswith("forced"):
            got = faulty.finished[a.rid]
            parity &= got == clean.finished[a.rid][:len(got)]
            compared += 1
    s = traffic.summarize(faulty, arr)
    return {
        "arch": ARCH, "seed": SEED, "n_requests": len(arr),
        "faults_injected": inj.injected, "faults_cleared": inj.cleared,
        "unresolved": len(res["unresolved"]),
        "parity": bool(parity), "streams_compared": compared,
        "done": s["done"], "forced": s["forced"], "rejected": s["rejected"],
        "shed_rate": s["shed_rate"],
        "preemptions": s["preemptions"],
        "admission_holds": s["admission_holds"],
        "downshifts": s["downshifts"],
        "degraded_ticks": s["degraded_ticks"],
        "spec_probes": faulty.spec_probes,
        "pool_pages_leaked": faulty.pool.pages_in_use,
    }


def telemetry_overhead_cell(params, cfg) -> dict:
    """Tracing must be observational: same tokens, < 5% wall overhead.

    Runs the identical rate-1.0 workload with telemetry on and off
    (best-of-3 each to damp scheduler noise) and compares both the
    finished token streams (bit parity) and the wall clocks.
    """
    arr = traffic.TrafficGenerator(_traffic_cfg(1.0, cfg.vocab)).arrivals()

    def run(enabled: bool):
        walls, finished, n_events = [], None, 0
        for _ in range(3):
            eng = _engine(params, cfg, telemetry=enabled)
            t0 = time.perf_counter()
            res = traffic.run_open_loop(eng, arr, max_ticks=4000)
            walls.append(time.perf_counter() - t0)
            assert res["unresolved"] == []
            assert finished is None or finished == eng.finished, \
                "non-deterministic replay"
            finished = eng.finished
            n_events = len(eng.telemetry.events)
        return min(walls), finished, n_events

    traced_wall, traced_fin, n_events = run(True)
    plain_wall, plain_fin, _ = run(False)
    parity = traced_fin == plain_fin
    ratio = traced_wall / max(1e-9, plain_wall)
    print(f"  traced {traced_wall*1e3:.1f} ms vs untraced "
          f"{plain_wall*1e3:.1f} ms -> overhead x{ratio:.3f}, "
          f"parity={parity}, {n_events} events")
    return {
        "arch": ARCH, "seed": SEED, "n_requests": len(arr),
        "repeats": 3,
        "traced_wall_s": traced_wall,
        "untraced_wall_s": plain_wall,
        "overhead_ratio": ratio,
        "parity": bool(parity),
        "trace_events": n_events,
    }


def model_vs_measured_cell(params, cfg) -> dict:
    """Drift gate: analytic serving models vs measured engine spans.

    Runs the spec-decode engine (so decode, prefill_chunk *and*
    spec_verify spans all populate) under open-loop traffic, then asks
    ``telemetry.drift_report`` to price the same geometry through
    ``autotune.paged_decode_model`` / ``prefill_chunk_model`` /
    ``spec_decode_model`` and report measured/modeled ratios. Ratios are
    host-dependent, so the validator gates them on *finite and positive*
    (i.e. the spans were actually measured), not on a magnitude band.
    ``persist=True`` drops each measurement into the attn tuning cache
    under ``serve_measured:`` keys for cross-run comparison.
    """
    arr = traffic.TrafficGenerator(_traffic_cfg(1.5, cfg.vocab)).arrivals()
    eng = _engine(params, cfg, spec_k=2, draft="ngram",
                  spec_adapt_every=4, spec_probe_every=4)
    res = traffic.run_open_loop(eng, arr, max_ticks=4000)
    assert res["unresolved"] == []
    rep = telemetry.drift_report(eng, persist=True)
    for comp in ("decode", "prefill_chunk", "spec_verify"):
        row = rep.get(comp)
        if row is None:
            continue
        print(f"  {comp}: measured {row['measured_s']*1e3:.2f} ms vs "
              f"modeled {row['modeled_s']*1e3:.2f} ms "
              f"-> ratio {row['ratio']:.2f} ({row['n_spans']} spans)")
    return {"arch": ARCH, "seed": SEED, **rep}


def run():
    """benchmarks/run.py entry point: one derived row per cell."""
    cfg = configs.get_smoke(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sweep = sweep_cell(params, cfg)
    faults = faults_cell(params, cfg)
    overhead = telemetry_overhead_cell(params, cfg)
    drift = model_vs_measured_cell(params, cfg)
    knee = next(p for p in sweep["points"]
                if p["offered_rate"] == sweep["knee_rate"])
    ratios = ";".join(
        f"{comp}={drift[comp]['ratio']:.2f}" for comp
        in ("decode", "prefill_chunk", "spec_verify") if comp in drift)
    return [
        ("sweep",
         f"knee_rate={sweep['knee_rate']};"
         f"goodput={sweep['knee_goodput_tokens_per_tick']:.3f}tok/tick;"
         f"shed@knee={knee['shed_rate']:.2f}"),
        ("faults",
         f"parity={faults['parity']};cleared={faults['faults_cleared']};"
         f"leaked={faults['pool_pages_leaked']}"),
        ("telemetry_overhead",
         f"x{overhead['overhead_ratio']:.3f};"
         f"parity={overhead['parity']};events={overhead['trace_events']}"),
        ("model_vs_measured", ratios),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="merge cells into this BENCH json (read-modify-"
                         "write; other cells are preserved)")
    args = ap.parse_args()

    cfg = configs.get_smoke(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    print("offered-load sweep:")
    sweep = sweep_cell(params, cfg)
    print("canonical fault schedule:")
    faults = faults_cell(params, cfg)
    print("telemetry overhead:")
    overhead = telemetry_overhead_cell(params, cfg)
    print("model vs measured:")
    drift = model_vs_measured_cell(params, cfg)

    payload = {"breaking_point_sweep": sweep,
               "breaking_point_faults": faults,
               "telemetry_overhead": overhead,
               "model_vs_measured": drift}
    print(json.dumps(payload, indent=1))

    # Acceptance (mirrored as hard gates in scripts/validate_artifacts.py).
    pts = sweep["points"]
    knee_i = sweep["offered_rates"].index(sweep["knee_rate"])
    for a, b in zip(pts[knee_i:], pts[knee_i + 1:]):
        assert b["goodput_tokens_per_tick"] <= \
            a["goodput_tokens_per_tick"] * 1.05, "goodput rose past knee"
    for p in pts:
        assert p["ttft_p99"] >= p["ttft_p50"]
        assert 0.0 <= p["shed_rate"] <= 1.0
    assert faults["unresolved"] == 0
    assert faults["parity"] is True
    assert faults["faults_injected"] == faults["faults_cleared"] == 3
    assert faults["pool_pages_leaked"] == 0
    assert overhead["parity"] is True, "tracing changed the token stream"
    assert overhead["overhead_ratio"] < 1.05, overhead["overhead_ratio"]
    for comp in ("decode", "prefill_chunk", "spec_verify"):
        row = drift.get(comp)
        assert row is not None, f"{comp} spans never measured"
        assert row["ratio"] > 0.0, (comp, row)

    if args.out:
        existing = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        existing.update(payload)
        with open(args.out, "w") as f:
            json.dump(existing, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
