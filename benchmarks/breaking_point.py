"""Breaking-point bench: sweep offered load until the serving engine
breaks, and characterize the break.

This is the paper's method applied to our own stack: the
microbenchmarks drive each cache level past its comfortable operating
point and report *where* the latency cliff sits and *what* the
degraded plateau looks like — here the swept axis is offered load
(requests per engine tick through the open-loop traffic generator)
and the reported surface is what a production operator reads:

  * ``breaking_point_sweep`` — per offered rate: TTFT/TPOT p50/p99,
    goodput (completed tokens per tick), shed rate, preemptions, pool
    high water; plus the **knee point** — the offered rate where
    goodput peaks. Past the knee the engine is saturated: more offered
    load buys shed and preemption churn, not throughput, so goodput
    must be monotone non-increasing from there (the validator gates
    it).
  * ``breaking_point_faults`` — the canonical seeded fault schedule
    (pool squeeze -> accept collapse -> churn storm) against open-loop
    traffic on the full stack: every request must complete or cleanly
    reject, surviving streams bit-identical to the fault-free engine's
    (prefix-exact for force-completions), all fault windows armed and
    cleared.

All latencies are in *engine ticks* (deterministic, hardware-blind:
one tick = one decode step for every active slot); multiply by the
measured per-tick wall time — reported as ``tick_wall_s`` — to get
seconds on this machine. Tick-domain numbers are what make the
committed cells schema-gateable with hard inequalities: the same
sweep reproduces bit-for-bit on any host.

  PYTHONPATH=src python -m benchmarks.breaking_point --out BENCH_serving.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serve import traffic
from repro.serve.engine import Request, ServeConfig, ServingEngine, SLOClass
from repro.serve.faults import FaultInjector, canonical_schedule

ARCH = "qwen3-4b"
MAX_LEN = 64
BATCH = 2
PAGE_SIZE = 8
N_PAGES = 17
N_REQUESTS = 24
RATES = (0.25, 0.5, 1.0, 2.0, 4.0)
SEED = 11


def _serve_cfg(**kw) -> ServeConfig:
    base = dict(
        max_len=MAX_LEN, batch=BATCH, eos_id=-1, paged=True,
        page_size=PAGE_SIZE, chunk_size=8, n_pages=N_PAGES,
        classes=(SLOClass("default", ttft_slo=16, tpot_slo=4.0),),
        max_queue=8, max_preemptions=3, degrade=True)
    base.update(kw)
    return ServeConfig(**base)


def _traffic_cfg(rate: float, vocab: int) -> traffic.TrafficConfig:
    return traffic.TrafficConfig(
        rate=rate, n_requests=N_REQUESTS, seed=SEED, vocab=vocab,
        classes=(traffic.TrafficClass("default", prompt_lo=4, prompt_hi=20,
                                      out_lo=2, out_hi=8),))


def _engine(params, cfg, **kw) -> ServingEngine:
    eng = ServingEngine(params, cfg, _serve_cfg(**kw))
    # Warm the chunk + decode executables outside the timed region.
    eng.submit(Request(rid=-1, prompt=np.resize(
        np.arange(3, 12, dtype=np.int32), eng.chunk + 1), max_new=2))
    eng.run_until_drained()
    eng.pool.high_water = 0
    eng.admission_rejections = 0
    eng.preemptions = 0
    eng.ticks = 0
    return eng


def sweep_cell(params, cfg) -> dict:
    points = []
    for rate in RATES:
        eng = _engine(params, cfg)
        arr = traffic.TrafficGenerator(
            _traffic_cfg(rate, cfg.vocab)).arrivals()
        t0 = time.perf_counter()
        res = traffic.run_open_loop(eng, arr, max_ticks=4000)
        wall = time.perf_counter() - t0
        assert res["unresolved"] == [], (rate, res["unresolved"])
        s = traffic.summarize(eng, arr)
        points.append({
            "offered_rate": rate,
            "ticks": s["ticks"],
            "tick_wall_s": wall / max(1, s["ticks"]),
            "done": s["done"], "forced": s["forced"],
            "rejected": s["rejected"],
            "ttft_p50": s["ttft_p50"], "ttft_p99": s["ttft_p99"],
            "tpot_p50": s["tpot_p50"], "tpot_p99": s["tpot_p99"],
            "goodput_tokens_per_tick": s["goodput_tokens_per_tick"],
            "shed_rate": s["shed_rate"],
            "ttft_slo_attainment": s.get("ttft_slo_attainment", 1.0),
            "preemptions": s["preemptions"],
            "admission_holds": s["admission_holds"],
            "downshifts": s["downshifts"],
            "degraded_ticks": s["degraded_ticks"],
            "pool_high_water_pages": eng.pool.high_water,
            "pool_capacity_pages": eng.pool.capacity,
        })
        print(f"  rate {rate:>5}: goodput "
              f"{points[-1]['goodput_tokens_per_tick']:.3f} tok/tick, "
              f"ttft p50/p99 {s['ttft_p50']:.0f}/{s['ttft_p99']:.0f}, "
              f"shed {s['shed_rate']:.2f}")
    knee_i = max(range(len(points)),
                 key=lambda i: points[i]["goodput_tokens_per_tick"])
    return {
        "arch": ARCH, "batch": BATCH, "n_pages": N_PAGES,
        "n_requests": N_REQUESTS, "seed": SEED,
        "offered_rates": list(RATES),
        "points": points,
        "knee_rate": points[knee_i]["offered_rate"],
        "knee_goodput_tokens_per_tick":
            points[knee_i]["goodput_tokens_per_tick"],
    }


def faults_cell(params, cfg) -> dict:
    arr = traffic.TrafficGenerator(
        _traffic_cfg(1.5, cfg.vocab)).arrivals()

    def run(injector):
        eng = _engine(params, cfg, spec_k=2, draft="ngram",
                      spec_adapt_every=4, spec_probe_every=4)
        res = traffic.run_open_loop(eng, arr, max_ticks=4000,
                                    injector=injector)
        if injector is not None:
            injector.finish(eng)
        return eng, res

    inj = FaultInjector(canonical_schedule(t0=4, dwell=8, gap=6))
    faulty, res = run(inj)
    clean, res_clean = run(None)
    assert res["unresolved"] == [] and res_clean["unresolved"] == []

    parity, compared = True, 0
    for a in arr:
        if clean.outcome.get(a.rid) != "done":
            continue
        out = faulty.outcome.get(a.rid, "")
        if out == "done":
            parity &= faulty.finished[a.rid] == clean.finished[a.rid]
            compared += 1
        elif out.startswith("forced"):
            got = faulty.finished[a.rid]
            parity &= got == clean.finished[a.rid][:len(got)]
            compared += 1
    s = traffic.summarize(faulty, arr)
    return {
        "arch": ARCH, "seed": SEED, "n_requests": len(arr),
        "faults_injected": inj.injected, "faults_cleared": inj.cleared,
        "unresolved": len(res["unresolved"]),
        "parity": bool(parity), "streams_compared": compared,
        "done": s["done"], "forced": s["forced"], "rejected": s["rejected"],
        "shed_rate": s["shed_rate"],
        "preemptions": s["preemptions"],
        "admission_holds": s["admission_holds"],
        "downshifts": s["downshifts"],
        "degraded_ticks": s["degraded_ticks"],
        "spec_probes": faulty.spec_probes,
        "pool_pages_leaked": faulty.pool.pages_in_use,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="merge cells into this BENCH json (read-modify-"
                         "write; other cells are preserved)")
    args = ap.parse_args()

    cfg = configs.get_smoke(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    print("offered-load sweep:")
    sweep = sweep_cell(params, cfg)
    print("canonical fault schedule:")
    faults = faults_cell(params, cfg)

    payload = {"breaking_point_sweep": sweep,
               "breaking_point_faults": faults}
    print(json.dumps(payload, indent=1))

    # Acceptance (mirrored as hard gates in scripts/validate_artifacts.py).
    pts = sweep["points"]
    knee_i = sweep["offered_rates"].index(sweep["knee_rate"])
    for a, b in zip(pts[knee_i:], pts[knee_i + 1:]):
        assert b["goodput_tokens_per_tick"] <= \
            a["goodput_tokens_per_tick"] * 1.05, "goodput rose past knee"
    for p in pts:
        assert p["ttft_p99"] >= p["ttft_p50"]
        assert 0.0 <= p["shed_rate"] <= 1.0
    assert faults["unresolved"] == 0
    assert faults["parity"] is True
    assert faults["faults_injected"] == faults["faults_cleared"] == 3
    assert faults["pool_pages_leaked"] == 0

    if args.out:
        existing = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        existing.update(payload)
        with open(args.out, "w") as f:
            json.dump(existing, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
