"""Fig 4.8 / Table 5.3: floating-point throughput vs peak."""
from repro.core import hwmodel

def run():
    f = 1380e6
    peak_half_tc = 80 * 8 * 64 * 2 * f / 1e12     # tensor cores
    peak_single = 80 * 64 * 2 * f / 1e12
    peak_double = 80 * 32 * 2 * f / 1e12
    meas = {"half": 83.03, "single": 14.03, "double": 7.07}  # table 5.3 PCIe
    rows = []
    for prec, peak in (("half", peak_half_tc), ("single", peak_single),
                       ("double", peak_double)):
        rows.append((prec, f"measured={meas[prec]}TF;peak={peak:.1f}TF;"
                     f"frac={meas[prec]/peak:.1%}"))
    return rows
