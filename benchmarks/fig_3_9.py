"""Figs 3.9-3.10: shared memory latency under contention + bandwidth."""
from repro.core import hwmodel, simulator

def run():
    rows = []
    for name in ("V100", "P100", "M60", "K80"):
        s = hwmodel.GPUS[name]
        curve = {k: simulator.smem_latency(s, k) for k in (1, 2, 4, 32)}
        rows.append((name, f"lat@1={curve[1]:.0f};lat@2={curve[2]:.0f};"
                     f"lat@32={curve[32]:.0f}"))
    v = hwmodel.V100
    theo = v.sms * v.smem_banks * v.smem_bank_width * v.max_clock_mhz * 1e6 / 2**30
    rows.append(("V100_bandwidth",
                 f"theoretical={theo:.0f}GiB/s(paper 13800);"
                 f"measured={v.smem_measured_gibs}GiB/s;"
                 f"ratio={v.smem_measured_gibs/theo:.2f}"))
    return rows
