"""Table 2.1: warp-pair FFMA throughput vs processing-block placement."""
import numpy as np
from repro.core import scheduler

def run():
    model = scheduler.table_2_1()
    errs = [abs(model[k] - v) / v for k, v in scheduler.PAPER_TABLE_2_1.items()]
    same = model[(0, 4)]
    diff = model[(1, 4)]
    return (f"same_block={same:.2f}GF(paper 42.27);"
            f"diff_block={diff:.2f}GF(paper 66.05);"
            f"mean_err={np.mean(errs):.1%};min_threads="
            f"{scheduler.min_threads_to_saturate()}")
