"""ICI collective microbenchmarks over a real (placeholder-device) mesh:
compiled wire bytes vs the alpha-beta model (ch.5 TPU analogue).

Runs in a subprocess so the harness keeps its single CPU device."""
import json
import os
import subprocess
import sys

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys
sys.path.insert(0, os.environ["REPRO_SRC"])
import json
from repro.core import collectives
from repro.launch import mesh as mesh_mod
mesh = mesh_mod.make_mesh((4, 4), ("data", "model"))
out = []
for kind in ("all_reduce", "all_gather", "reduce_scatter", "all_to_all"):
    b = collectives.bench_collective(mesh, kind, 1 << 22, "model")
    out.append(dict(kind=kind, hlo_bytes=b.hlo_bytes,
                    modeled_bytes=b.modeled_bytes,
                    time_ms=b.modeled_time_s * 1e3))
small = collectives.bench_collective(mesh, "all_reduce", 1 << 12, "model")
big = collectives.bench_collective(mesh, "all_reduce", 1 << 26, "model")
out.append(dict(kind="alpha_beta", small_ms=small.modeled_time_s*1e3,
                big_ms=big.modeled_time_s*1e3))
print("JSON:" + json.dumps(out))
'''

def run():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, REPRO_SRC=src)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    data = json.loads(proc.stdout.split("JSON:")[1])
    rows = []
    for d in data:
        if d["kind"] == "alpha_beta":
            rows.append(("alpha_beta", f"4KiB={d['small_ms']:.3f}ms;"
                         f"64MiB={d['big_ms']:.3f}ms"))
        else:
            rows.append((d["kind"], f"hlo_bytes={d['hlo_bytes']:.3e};"
                         f"model_bytes={d['modeled_bytes']:.3e};"
                         f"t={d['time_ms']:.3f}ms"))
    return rows
