"""Fig 3.11: global memory bandwidth, measured vs theoretical."""
from repro.core import hwmodel

def run():
    rows = []
    for name in ("V100", "P100", "P4", "M60", "K80"):
        s = hwmodel.GPUS[name]
        ratio = s.gmem_measured_gibs / s.gmem_theoretical_gibs
        rows.append((name, f"{s.gmem_bus};theoretical="
                     f"{s.gmem_theoretical_gibs:.0f};measured="
                     f"{s.gmem_measured_gibs:.0f};ratio={ratio:.1%}"))
    return rows
