"""Table 3.1: full memory-hierarchy dissection of all five GPUs."""
from repro.core import dissect, hwmodel

def run():
    rows = []
    for name in ("V100", "P100", "P4", "M60", "K80"):
        rep = dissect.dissect(hwmodel.GPUS[name])
        ok = sum(rep.matches.values())
        n = len(rep.matches)
        rows.append((name,
                     f"matches={ok}/{n};L1={rep.l1.size//1024}KiB/"
                     f"line{rep.l1.line}/{rep.l1.policy};"
                     f"L2={rep.l2.size//1024}KiB/line{rep.l2.line}/"
                     f"{rep.l2.ways}w;banks={rep.reg_banks}x"
                     f"{rep.reg_bank_width}b"))
    return rows
