"""Figs 3.6-3.7: constant-cache broadcast vs diverging accesses."""
from repro.core import hwmodel, simulator

def run():
    v = hwmodel.V100
    rows = []
    for level, paper in (("l1", 27), ("l1.5", 89), ("l2", 245)):
        lat1 = simulator.constant_latency(v, level, 1)
        lat8 = simulator.constant_latency(v, level, 8)
        rows.append((level.replace(".", "_"),
                     f"broadcast={lat1:.0f}cyc(paper ~{paper});"
                     f"diverge8={lat8:.0f}cyc;serialization=8x"))
    return rows
