"""Render the §Roofline markdown table from the dry-run artifact.

  PYTHONPATH=src python -m benchmarks.render_roofline [artifact.json]
"""

import json
import sys


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "benchmarks/artifacts/dryrun_baseline.json"
    cells = json.load(open(path))
    print("| arch | shape | mesh | compute_s | memory_s | collective_s |"
          " dominant | MODEL/HLO | frac | temp GiB |")
    print("|" + "---|" * 10)
    for c in cells:
        if c["skipped"]:
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — |"
                  f" skipped | — | — | — |")
            continue
        if not c["ok"]:
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | FAILED |")
            continue
        r = c["roofline"]
        temp = (c["memory"] or {}).get("temp_bytes", 0) / 2 ** 30
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} |"
              f" {r['compute_s']:.3g} | {r['memory_s']:.3g} |"
              f" {r['collective_s']:.3g} | {r['dominant']} |"
              f" {r['flops_efficiency']:.2f} |"
              f" {r['roofline_fraction']:.3f} | {temp:.1f} |")


if __name__ == "__main__":
    main()
