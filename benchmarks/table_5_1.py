"""Ch.5: interconnect p2p bandwidth/latency (NVLink/PCIe) + ICI model."""
from repro.core import hwmodel, interconnect

def run():
    rows = []
    for name, (bw, lat) in interconnect.link_comparison().items():
        rows.append((name.replace("-", "_"), f"unidir={bw:.1f}GB/s;"
                     f"latency={lat:.2f}us"))
    h2d, d2h = hwmodel.HOST_BANDWIDTH_MBS["V100-PCIe"]
    rows.append(("host_device", f"h2d={h2d}MB/s;d2h={d2h}MB/s"))
    c = interconnect.collective_time("all_reduce", 1 << 30, 16)
    rows.append(("ici_allreduce_1GiB_16chips",
                 f"time={c.time_s*1e3:.2f}ms;wire={c.bytes_on_wire/2**30:.2f}GiB"))
    return rows
