"""Table 4.1: dependent-issue latencies, measured by the control-word
stall-shrinking method, plus host-CPU dependent-chain wall clocks."""
from repro.core import hwmodel, latency

def run():
    rows = []
    for arch, table in (("volta", hwmodel.VOLTA_INSTR_LATENCY),
                        ("pascal", hwmodel.PASCAL_INSTR_LATENCY)):
        board = latency.Scoreboard(table)
        ok = sum(latency.measure_fixed_latency(board, op, 100) == lat
                 for op, lat in table.items() if lat > 1)
        n = sum(1 for lat in table.values() if lat > 1)
        key = {op: table[op] for op in ("FFMA", "DFMA") if op in table}
        rows.append((arch, f"recovered={ok}/{n};key={key}"))
    import jax.numpy as jnp
    x = jnp.zeros((8,), jnp.float32)
    suite = latency.standard_op_suite()
    host = {name: latency.measure_op_chain(fn, x, n=256, repeats=2)
            for name, fn in list(suite.items())[:3]}
    rows.append(("host_cpu_ns", ";".join(f"{k}={v:.0f}" for k, v in
                                         host.items())))
    return rows
