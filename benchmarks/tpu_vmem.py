"""VMEM working-set budgets + a real p-chase of THIS host's caches — the
paper's ch.3 method running on actual silicon available in the container."""
import time
import numpy as np
from repro.core import autotune, hwmodel

def _host_pchase(n_bytes, steps=200_000):
    # Random-permutation chain at cache-line granularity defeats the
    # prefetcher, exactly like the paper's fine-grained p-chase.
    n = max(8, n_bytes // 64)
    rng = np.random.RandomState(0)
    order = rng.permutation(n)
    chain = np.empty(n * 8, np.int64)          # one slot per 64B line
    chain[order * 8] = np.roll(order, -1) * 8
    pos = 0
    t0 = time.perf_counter_ns()
    for _ in range(steps):
        pos = chain[pos]
    return (time.perf_counter_ns() - t0) / steps

def run():
    rows = []
    p = autotune.GemmProblem(m=4096, k=4096, n=4096)
    cfg, terms = autotune.choose_gemm_block(p)
    rows.append(("vmem_budget",
                 f"block=({cfg.bm},{cfg.bk},{cfg.bn});"
                 f"vmem={cfg.vmem_bytes(p)/2**20:.1f}MiB of "
                 f"{hwmodel.DEFAULT_TPU.vmem_bytes/2**20:.0f}MiB;"
                 f"mxu_eff={terms['mxu_efficiency']:.2f}"))
    sizes = [16 * 2**10, 256 * 2**10, 4 * 2**20, 64 * 2**20]
    lats = {s: _host_pchase(s, steps=60_000) for s in sizes}
    rows.append(("host_cache_pchase_ns",
                 ";".join(f"{s//1024}KiB={l:.1f}" for s, l in lats.items())))
    mono = all(lats[a] <= lats[b] * 1.35
               for a, b in zip(sizes, sizes[1:]))
    rows.append(("host_hierarchy_visible", f"latency_grows={mono}"))
    return rows
